"""Train a ~100M-parameter dense model for a few hundred steps on CPU
with the full substrate: synthetic data pipeline, AdamW + cosine
schedule, grad clipping, remat-free jit step, periodic checkpointing,
and resume.

Run:  PYTHONPATH=src python examples/train_small.py [--steps 200]
"""

import argparse
import dataclasses

from repro.configs.base import ModelConfig
from repro.training.data import DataConfig
from repro.training.optimizer import AdamWConfig
from repro.training.train_loop import TrainLoopConfig, train


def model_100m() -> ModelConfig:
    """~100M params: 12L d=512 8H swiglu, 32k vocab (qwen-family shape)."""
    return ModelConfig(
        name="dense-100m", family="dense", source="examples/train_small",
        num_layers=12, d_model=512, num_heads=8, num_kv_heads=4,
        head_dim=64, d_ff=2048, vocab_size=32_000, mlp_type="swiglu",
        rope_theta=10_000.0,
    )


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_small")
    ap.add_argument("--resume", default=None)
    args = ap.parse_args()

    cfg = model_100m()
    print(f"model: {cfg.name} ~{cfg.param_count()/1e6:.0f}M params")
    history = train(
        cfg,
        data_cfg=DataConfig(vocab_size=cfg.vocab_size, seq_len=args.seq,
                            batch_size=args.batch, seed=0),
        opt_cfg=AdamWConfig(lr=6e-4, warmup_steps=20, total_steps=args.steps),
        loop=TrainLoopConfig(steps=args.steps, log_every=10,
                             ckpt_every=100, ckpt_dir=args.ckpt_dir),
        resume_from=args.resume,
    )
    first, last = history["loss"][0], history["loss"][-1]
    print(f"loss {first:.3f} -> {last:.3f} "
          f"({'improved' if last < first else 'NO IMPROVEMENT'})")


if __name__ == "__main__":
    main()
