"""Cluster serving: Shabari vs the five baselines on an Azure-style
ten-minute trace over a 16-worker cluster (paper Figure 8, one seed).

Run:  PYTHONPATH=src python examples/serve_cluster.py [--rps 5] [--quick]
"""

import argparse

from repro.serving.experiment import run_experiment


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--rps", type=float, default=5.0)
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    dur = 240.0 if args.quick else 600.0

    print(f"trace: rps={args.rps} duration={dur:.0f}s seed={args.seed}")
    print(f"{'policy':18s} {'SLO viol%':>9s} {'idle vCPU p50':>13s} "
          f"{'idle mem p50':>12s} {'cold%':>6s} {'OOM%':>5s}")
    for pol in ("static-medium", "static-large", "parrotfish", "aquatope",
                "cypress", "shabari"):
        r = run_experiment(pol, rps=args.rps, duration_s=dur, seed=args.seed)
        s = r.summary
        print(f"{pol:18s} {s['slo_violation_pct']:9.2f} "
              f"{s['wasted_vcpus_p50']:13.1f} {s['wasted_mem_mb_p50']:10.0f}MB "
              f"{s['cold_start_pct']:6.2f} {s['oom_pct']:5.2f}")


if __name__ == "__main__":
    main()
