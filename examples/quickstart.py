"""Quickstart: serve a model with batched requests through Shabari.

End-to-end on CPU in under a minute:
  1. a REAL reduced qwen-family model generates tokens via the serving
     engine (batched prefill + ring-cache decode);
  2. a stream of differently-sized requests flows through Shabari's
     featurizer -> online allocator -> feedback loop, showing the
     per-invocation right-sizing the paper is about.

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np

from repro.configs import get_reduced_config
from repro.core import Featurizer, ResourceAllocator
from repro.core.cost_functions import Observation
from repro.serving.engine import ServingEngine


def main() -> None:
    # ---------------------------------------------------- 1. real model
    cfg = get_reduced_config("qwen2.5-3b")
    engine = ServingEngine(cfg, cache_window=128, seed=0)
    rng = np.random.default_rng(0)
    prompts = [list(rng.integers(1, cfg.vocab_size, size=n))
               for n in (8, 19, 33)]
    res = engine.generate(prompts, max_new_tokens=16)
    print(f"[engine] generated {len(res.tokens)}x16 tokens | "
          f"prefill {res.prefill_s*1e3:.1f} ms | "
          f"decode {res.decode_s*1e3:.1f} ms | {res.tokens_per_s:,.0f} tok/s")
    print(f"[engine] first continuation: {res.tokens[0][:8]} ...")

    # ------------------------------------- 2. Shabari sizing a workload
    feat = Featurizer()
    alloc = ResourceAllocator()

    def serve_cost(vcpus: int, prompt_len: int) -> float:
        # longer prompts need more parallel slices to hit the latency SLO
        work = 0.004 * prompt_len
        return 0.05 + work / min(vcpus, max(prompt_len // 16, 1))

    slo = 0.25
    print("\n[shabari] learning request-size -> slice-count mapping (SLO 250 ms)")
    for i in range(120):
        n = int(rng.choice([16, 64, 256]))
        x = feat.extract("serve-qwen", "request",
                         {"prompt_tokens": n, "batch": 1,
                          "max_new_tokens": 16, "image_tiles": 0,
                          "audio_seconds": 0})
        a = alloc.allocate("serve-qwen", x)
        t = serve_cost(a.vcpus, n)
        used = min(a.vcpus, max(n // 16, 1))
        alloc.feedback("serve-qwen", x, Observation(
            exec_time_s=t, slo_s=slo, alloc_vcpus=a.vcpus,
            max_vcpus_used=used, alloc_mem_mb=a.mem_mb,
            max_mem_used_mb=32 + 0.5 * n))
    for n in (16, 64, 256):
        x = feat.extract("serve-qwen", "request",
                         {"prompt_tokens": n, "batch": 1,
                          "max_new_tokens": 16, "image_tiles": 0,
                          "audio_seconds": 0})
        a = alloc.allocate("serve-qwen", x)
        t = serve_cost(a.vcpus, n)
        print(f"  prompt={n:4d} tokens -> slices={a.vcpus:2d} "
              f"mem={a.mem_mb:4d}MB  latency={t*1e3:5.1f} ms "
              f"({'meets' if t <= slo else 'MISSES'} SLO)")


if __name__ == "__main__":
    main()
