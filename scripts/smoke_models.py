"""Quick dev smoke: fwd train/prefill/decode for every reduced arch."""
import jax
import jax.numpy as jnp

from repro.configs import ARCH_IDS, get_reduced_config
from repro.models.model import forward_train, forward_prefill, forward_decode, init_params, count_params

key = jax.random.PRNGKey(0)
for arch in ARCH_IDS:
    cfg = get_reduced_config(arch)
    params = init_params(key, cfg)
    B, S = 2, 64
    if cfg.is_encoder_decoder:
        S = min(S, cfg.max_target_positions)
    tokens = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    labels = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    kwargs = {}
    if cfg.family == "vlm":
        kwargs["patch_embeds"] = jnp.zeros((B, cfg.frontend_tokens, cfg.d_model), cfg.dtype)
    if cfg.is_encoder_decoder:
        kwargs["frame_embeds"] = jnp.zeros((B, cfg.encoder_seq, cfg.d_model), cfg.dtype)
    loss, metrics = forward_train(params, cfg, tokens, labels, remat=False, **kwargs)
    assert jnp.isfinite(loss), (arch, loss)
    logits, cache = forward_prefill(params, cfg, tokens, cache_window=32, **kwargs)
    assert jnp.all(jnp.isfinite(logits.astype(jnp.float32))), arch
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    logits2, cache = forward_decode(params, cfg, tok, cache)
    assert jnp.all(jnp.isfinite(logits2.astype(jnp.float32))), arch
    print(f"{arch:20s} params={count_params(params):>12,} loss={float(loss):.3f} ok")
print("ALL OK")
