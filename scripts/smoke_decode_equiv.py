"""Dev check: prefill+decode logits == full-sequence forward logits.

MoE capacity dropping makes token-competition non-causal (GShard
semantics), so we raise CAPACITY_FACTOR to drop-free for this check.
"""
import jax
import jax.numpy as jnp

import repro.models.moe as MOE

MOE.CAPACITY_FACTOR = 16.0  # drop-free for exact equivalence

from repro.configs import ARCH_IDS, get_reduced_config
from repro.models.model import forward_seq, forward_prefill, forward_decode, init_params

key = jax.random.PRNGKey(1)
fails = 0
for arch in ARCH_IDS:
    cfg = get_reduced_config(arch)
    params = init_params(key, cfg)
    B, S = 2, 17
    if cfg.family in ("ssm", "hybrid"):
        S = cfg.ssm_chunk
    kwargs = {}
    if cfg.family == "vlm":
        kwargs["patch_embeds"] = 0.1 * jnp.ones((B, cfg.frontend_tokens, cfg.d_model), cfg.dtype)
    if cfg.is_encoder_decoder:
        kwargs["frame_embeds"] = 0.1 * jnp.ones((B, cfg.encoder_seq, cfg.d_model), cfg.dtype)
    tokens = jax.random.randint(key, (B, S + 1), 0, cfg.vocab_size)

    off0 = cfg.frontend_tokens if cfg.family == "vlm" else 0
    _, cache = forward_prefill(params, cfg, tokens[:, :S], cache_window=max(S + off0, 8), **kwargs)
    logits_dec, _ = forward_decode(params, cfg, tokens[:, S], cache)

    if cfg.family in ("ssm", "hybrid"):
        pad = (-(S + 1)) % cfg.ssm_chunk
        toks_full = jnp.pad(tokens, ((0, 0), (0, pad)))
    else:
        toks_full = tokens
    logits_full, _, _ = forward_seq(params, cfg, toks_full, **kwargs)
    off = cfg.frontend_tokens if cfg.family == "vlm" else 0
    ref = logits_full[:, off + S]
    err = float(jnp.max(jnp.abs(ref.astype(jnp.float32) - logits_dec.astype(jnp.float32))))
    scale = float(jnp.max(jnp.abs(ref.astype(jnp.float32)))) + 1e-6
    ok = err / scale < 0.02
    fails += 0 if ok else 1
    print(f"{'OK ' if ok else 'FAIL'} {arch:20s} max_abs_err={err:.5f} rel={err/scale:.5f}")
raise SystemExit(1 if fails else 0)
