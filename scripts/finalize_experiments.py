"""Regenerate the roofline tables inside EXPERIMENTS.md from the
dry-run artifacts. Idempotent: content between the marker comments is
replaced."""

import re
import sys
from pathlib import Path

sys.path.insert(0, "scripts")
from roofline_table import build_table  # noqa: E402

MARK = "<!-- {name}:{which} -->"


def splice(text: str, name: str, payload: str) -> str:
    start = MARK.format(name=name, which="start")
    end = MARK.format(name=name, which="end")
    block = f"{start}\n{payload}\n{end}"
    if start in text:
        pattern = re.escape(start) + r".*?" + re.escape(end)
        return re.sub(pattern, lambda _: block, text, flags=re.S)
    return text + "\n\n" + block + "\n"


def main():
    d = Path("experiments/dryrun")
    md = Path("EXPERIMENTS.md")
    text = md.read_text()

    sections = [
        ("roofline-pod1", "### Baseline roofline — single-pod 16×16 (256 chips)",
         build_table(d, "pod1")),
        ("roofline-pod2", "### Multi-pod 2×16×16 (512 chips) — dry-run pass",
         build_table(d, "pod2")),
        ("roofline-opt", "### Optimized (--opt: §Perf winners) — single-pod",
         build_table(d, "pod1_opt")),
    ]
    for name, title, table in sections:
        payload = f"{title}\n\n{table}"
        text = splice(text, name, payload)
    md.write_text(text)
    print("EXPERIMENTS.md tables regenerated")


if __name__ == "__main__":
    main()
