"""Regenerate the golden-metrics snapshots in tests/goldens/.

Run this ONLY when a PR intentionally changes simulated behavior
(allocator, scheduler, workload, simulator); commit the diff so the
review shows exactly which metrics moved and by how much. The CI
golden-drift job reruns this script and fails on any uncommitted diff,
so a semantics change can't sail through on stale snapshots.

    PYTHONPATH=src python scripts/refresh_goldens.py [--only a,b]
                                                     [--out-dir DIR]

Besides the per-scenario snapshots, the acquire-on-placement A/B
scenarios (``LEGACY_ACQUIRE_SCENARIOS``) are snapshotted a second time
under ``<out-dir>/legacy-acquire/`` with ``SimConfig(legacy_acquire=
True)``, pinning the pre-reservation accounting independently.
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import os
import sys
from typing import Dict, Optional

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.serving.golden import (  # noqa: E402
    CACHE_DISABLED_SCENARIOS,
    CHAIN_UNIFORM_SCENARIOS,
    ESTIMATE_ROUTING_SCENARIOS,
    GOLDEN_POLICY,
    LEGACY_ACQUIRE_SCENARIOS,
    LEGACY_ENGINE_SCENARIOS,
    LEGACY_EVENT_LOOP_SCENARIOS,
    golden_specs,
    run_golden,
)

GOLDEN_DIR = os.path.join(os.path.dirname(__file__), "..", "tests", "goldens")
LEGACY_SUBDIR = "legacy-acquire"
LEGACY_ENGINE_SUBDIR = "legacy-engine"
LEGACY_EVENT_LOOP_SUBDIR = "legacy-event-loop"
ESTIMATE_SUBDIR = "estimate-routing"
CACHE_DISABLED_SUBDIR = "cache-disabled"
CHAIN_UNIFORM_SUBDIR = "chain-uniform"


def write_snapshot(scenario: str, out_dir: str, *,
                   legacy_acquire: bool = False,
                   legacy_engine: bool = False,
                   estimate_routing: bool = False,
                   legacy_event_loop: bool = False,
                   cache_disabled: bool = False,
                   chain_uniform: bool = False) -> Dict:
    """Run one golden scenario and write its snapshot JSON; returns the
    written document (the schema tests/test_refresh_goldens.py pins)."""
    os.makedirs(out_dir, exist_ok=True)
    doc = {
        "policy": ("shabari-legacy-engine" if legacy_engine
                   else GOLDEN_POLICY),
        "spec": dataclasses.asdict(golden_specs()[scenario]),
        "summary": run_golden(scenario, legacy_acquire=legacy_acquire,
                              legacy_engine=legacy_engine,
                              estimate_routing=estimate_routing,
                              legacy_event_loop=legacy_event_loop,
                              cache_disabled=cache_disabled,
                              chain_uniform=chain_uniform),
    }
    path = os.path.join(out_dir, f"{scenario}.json")
    with open(path, "w") as f:
        json.dump(doc, f, indent=2, sort_keys=True)
        f.write("\n")
    tag = (" (legacy-acquire)" if legacy_acquire
           else " (legacy-engine)" if legacy_engine
           else " (estimate-routing)" if estimate_routing
           else " (legacy-event-loop)" if legacy_event_loop
           else " (cache-disabled)" if cache_disabled
           else " (chain-uniform)" if chain_uniform else "")
    print(f"{scenario:>20}{tag}: n={doc['summary']['n']:.0f} "
          f"slo_viol={doc['summary']['slo_violation_pct']:.2f}% -> {path}")
    return doc


def refresh(out_dir: str = GOLDEN_DIR, only: Optional[set] = None) -> None:
    for scenario in sorted(golden_specs()):
        if only and scenario not in only:
            continue
        write_snapshot(scenario, out_dir)
        if scenario in LEGACY_ACQUIRE_SCENARIOS:
            write_snapshot(scenario, os.path.join(out_dir, LEGACY_SUBDIR),
                           legacy_acquire=True)
        if scenario in LEGACY_ENGINE_SCENARIOS:
            write_snapshot(
                scenario, os.path.join(out_dir, LEGACY_ENGINE_SUBDIR),
                legacy_engine=True)
        if scenario in LEGACY_EVENT_LOOP_SCENARIOS:
            write_snapshot(
                scenario, os.path.join(out_dir, LEGACY_EVENT_LOOP_SUBDIR),
                legacy_event_loop=True)
        if scenario in ESTIMATE_ROUTING_SCENARIOS:
            write_snapshot(
                scenario, os.path.join(out_dir, ESTIMATE_SUBDIR),
                estimate_routing=True)
        if scenario in CACHE_DISABLED_SCENARIOS:
            write_snapshot(
                scenario, os.path.join(out_dir, CACHE_DISABLED_SUBDIR),
                cache_disabled=True)
        if scenario in CHAIN_UNIFORM_SCENARIOS:
            write_snapshot(
                scenario, os.path.join(out_dir, CHAIN_UNIFORM_SUBDIR),
                chain_uniform=True)


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated subset of scenarios")
    ap.add_argument("--out-dir", default=GOLDEN_DIR,
                    help="write snapshots here instead of tests/goldens/")
    args = ap.parse_args(argv)
    only = set(args.only.split(",")) if args.only else None
    if only:
        unknown = only - set(golden_specs())
        if unknown:
            raise SystemExit(f"unknown scenarios: {sorted(unknown)}")
    refresh(args.out_dir, only)


if __name__ == "__main__":
    main()
