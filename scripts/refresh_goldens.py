"""Regenerate the golden-metrics snapshots in tests/goldens/.

Run this ONLY when a PR intentionally changes simulated behavior
(allocator, scheduler, workload, simulator); commit the diff so the
review shows exactly which metrics moved and by how much.

    PYTHONPATH=src python scripts/refresh_goldens.py
"""

from __future__ import annotations

import dataclasses
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.serving.golden import GOLDEN_POLICY, golden_specs, run_golden  # noqa: E402

GOLDEN_DIR = os.path.join(os.path.dirname(__file__), "..", "tests", "goldens")


def main() -> None:
    os.makedirs(GOLDEN_DIR, exist_ok=True)
    for scenario, spec in sorted(golden_specs().items()):
        summary = run_golden(scenario)
        path = os.path.join(GOLDEN_DIR, f"{scenario}.json")
        with open(path, "w") as f:
            json.dump(
                {
                    "policy": GOLDEN_POLICY,
                    "spec": dataclasses.asdict(spec),
                    "summary": summary,
                },
                f, indent=2, sort_keys=True,
            )
            f.write("\n")
        print(f"{scenario:>20}: n={summary['n']:.0f} "
              f"slo_viol={summary['slo_violation_pct']:.2f}% -> {path}")


if __name__ == "__main__":
    main()
