import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
os.environ.setdefault("TF_CPP_MIN_LOG_LEVEL", "2")

"""§Perf hillclimb driver: compile one (arch x shape) under named
variants and report the roofline-term deltas.

Variants (cumulative unless noted):
  base            — paper-faithful baseline (what the sweep recorded)
  constraints     — activation sharding constraints (hidden/logits)
  remat_dots      — + save matmul outputs in the scan body (train only)
  decode_split    — split-softmax decode (decode only; replaces concat)

Usage: PYTHONPATH=src python scripts/hillclimb.py --arch mixtral-8x7b \
           --shape prefill_32k --variants base,constraints
Writes experiments/perf/<arch>__<shape>__<variant>.json
"""

import argparse
import json
import time
from pathlib import Path

from repro.configs import SHAPES, canonical_id, get_config
from repro.launch import dryrun as dr
from repro.launch.mesh import make_production_mesh
import repro.models.model as M


def run_variant(cfg, shape, mesh, variant: str):
    """variant = "base" or "+"-joined flags:
    constraints | remat_dots | decode_split | moe_chunk<N>."""
    import repro.models.moe as MOE

    import repro.models.layers as LYR
    import repro.models.kv_cache as KVC

    flags = set() if variant == "base" else set(variant.split("+"))
    opt = "constraints" in flags
    M.set_remat_policy("dots" if "remat_dots" in flags else "nothing")
    M.set_decode_mode("split" if "decode_split" in flags else "concat")
    LYR.set_gqa_mode("grouped" if "gqa_grouped" in flags else "repeat")
    KVC.set_ring_mode("scatter" if "ring_scatter" in flags else "onehot")
    LYR.set_attn_qtile(0)
    for f in flags:
        if f.startswith("moe_chunk"):
            MOE.set_moe_seq_chunks(int(f[len("moe_chunk"):]))
        if f.startswith("qtile"):
            LYR.set_attn_qtile(int(f[len("qtile"):]))
    try:
        M.set_scan_unroll(1)
        t0 = time.time()
        lowered, compiled = dr.lower_combo(cfg, shape, mesh, opt=opt)
        dt = time.time() - t0
        extra = dr.extrapolate_costs(cfg, shape, mesh, opt=opt)
        rec = dr.analyze(cfg, shape, mesh, lowered, compiled, dt,
                         cost_override=extra)
        rec["variant"] = variant
        return rec
    finally:
        M.set_remat_policy("nothing")
        M.set_decode_mode("concat")
        LYR.set_gqa_mode("repeat")
        KVC.set_ring_mode("onehot")
        MOE.set_moe_seq_chunks(1)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--variants", default="base,constraints")
    ap.add_argument("--out", default="experiments/perf")
    args = ap.parse_args()

    cfg = get_config(canonical_id(args.arch))
    shape = SHAPES[args.shape]
    mesh = make_production_mesh()
    out = Path(args.out)
    out.mkdir(parents=True, exist_ok=True)

    base_terms = None
    for variant in args.variants.split(","):
        rec = run_variant(cfg, shape, mesh, variant)
        rf = rec["roofline"]
        path = out / f"{cfg.name.replace('.', '_')}__{shape.name}__{variant}.json"
        path.write_text(json.dumps(rec, indent=2, default=str))
        line = (f"{variant:14s} compute={rf['compute_s']:.4f}s "
                f"memory={rf['memory_s']:.4f}s collective={rf['collective_s']:.4f}s "
                f"dominant={rf['dominant']} useful={rf['useful_flops_ratio']:.3f} "
                f"temp={rec['memory_analysis'].get('temp_bytes', 0)/2**30:.1f}GiB")
        if base_terms:
            dd = rf[f"{base_terms['dominant']}_s"] / base_terms[f"{base_terms['dominant']}_s"]
            line += f"  [dominant-term x{dd:.3f} vs base]"
        else:
            base_terms = rf
        print(line)


if __name__ == "__main__":
    main()
