"""Generate the §Roofline markdown table from experiments/dryrun/*.json.

Usage: python scripts/roofline_table.py [--dir experiments/dryrun] [--suffix pod1]
Prints a markdown table; with --update, rewrites the marked block in
EXPERIMENTS.md.
"""

import argparse
import json
from pathlib import Path

ARCH_ORDER = [
    "qwen2.5-3b", "mixtral-8x7b", "nemotron-4-15b", "internvl2-76b",
    "mamba2-1.3b", "arctic-480b", "codeqwen1.5-7b", "whisper-tiny",
    "zamba2-7b", "phi3-mini-3.8b",
]
SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def fmt(x, digits=4):
    if x == 0:
        return "0"
    if x < 1e-3:
        return f"{x:.2e}"
    return f"{x:.{digits}f}"


def build_table(d: Path, suffix: str) -> str:
    rows = []
    for arch in ARCH_ORDER:
        for shape in SHAPE_ORDER:
            f = d / f"{arch.replace('.', '_')}__{shape}__{suffix}.json"
            if not f.exists():
                rows.append(f"| {arch} | {shape} | — | — | — | — | — | MISSING |")
                continue
            r = json.loads(f.read_text())
            if r.get("skipped"):
                rows.append(f"| {arch} | {shape} | — | — | — | — | — | skipped: {r['reason']} |")
                continue
            rf = r["roofline"]
            mem = r.get("memory_analysis", {})
            arg_gb = (mem.get("argument_bytes") or 0) / 2**30
            tmp_gb = (mem.get("temp_bytes") or 0) / 2**30
            rows.append(
                f"| {arch} | {shape} | {fmt(rf['compute_s'])} | "
                f"{fmt(rf['memory_s'])} | {fmt(rf['collective_s'])} | "
                f"**{rf['dominant']}** | {rf['useful_flops_ratio']:.3f} | "
                f"args {arg_gb:.2f} GiB, temp {tmp_gb:.2f} GiB |"
            )
    header = (
        "| arch | shape | compute_s | memory_s | collective_s | dominant | "
        "useful | per-device memory |\n"
        "|---|---|---|---|---|---|---|---|"
    )
    return header + "\n" + "\n".join(rows)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="experiments/dryrun")
    ap.add_argument("--suffix", default="pod1")
    args = ap.parse_args()
    print(build_table(Path(args.dir), args.suffix))


if __name__ == "__main__":
    main()
