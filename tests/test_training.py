"""Training substrate tests: optimizer, data pipeline, checkpointing."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.training.checkpoint import load_checkpoint, restore_into, save_checkpoint
from repro.training.data import DataConfig, SyntheticTokenPipeline
from repro.training.optimizer import (
    AdamWConfig,
    adamw_update,
    global_norm,
    init_opt_state,
    lr_schedule,
)


def test_adamw_minimizes_quadratic():
    cfg = AdamWConfig(lr=0.1, weight_decay=0.0, warmup_steps=0,
                      total_steps=10_000, min_lr_ratio=1.0)
    params = {"w": jnp.array([3.0, -2.0])}
    opt = init_opt_state(cfg, params)
    for _ in range(300):
        grads = {"w": 2 * params["w"]}
        params, opt, _ = adamw_update(cfg, params, grads, opt)
    assert float(jnp.max(jnp.abs(params["w"]))) < 1e-2


def test_grad_clip_bounds_update():
    cfg = AdamWConfig(lr=1.0, grad_clip=1.0, warmup_steps=0)
    params = {"w": jnp.zeros(4)}
    opt = init_opt_state(cfg, params)
    huge = {"w": jnp.full(4, 1e9)}
    _, _, m = adamw_update(cfg, params, huge, opt)
    assert float(m["grad_norm"]) > 1e8  # reported pre-clip


def test_lr_schedule_warmup_and_decay():
    cfg = AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100,
                      min_lr_ratio=0.1)
    assert float(lr_schedule(cfg, jnp.array(5))) == pytest.approx(0.5)
    assert float(lr_schedule(cfg, jnp.array(10))) == pytest.approx(1.0, abs=1e-3)
    assert float(lr_schedule(cfg, jnp.array(100))) == pytest.approx(0.1, abs=1e-3)


def test_bf16_moments_dtype():
    cfg = AdamWConfig(moment_dtype="bfloat16")
    params = {"w": jnp.zeros((4, 4), jnp.bfloat16)}
    opt = init_opt_state(cfg, params)
    assert opt["m"]["w"].dtype == jnp.bfloat16
    p2, o2, _ = adamw_update(cfg, params, {"w": jnp.ones((4, 4), jnp.bfloat16)}, opt)
    assert o2["v"]["w"].dtype == jnp.bfloat16
    assert p2["w"].dtype == jnp.bfloat16


def test_data_pipeline_deterministic_and_seekable():
    cfg = DataConfig(vocab_size=128, seq_len=16, batch_size=4, seed=9)
    pipe = SyntheticTokenPipeline(cfg)
    b5 = pipe.batch_at(5)
    pipe2 = SyntheticTokenPipeline(cfg)
    b5b = pipe2.batch_at(5)
    assert np.array_equal(b5["tokens"], b5b["tokens"])
    assert np.array_equal(b5["labels"], b5b["labels"])
    # labels are next tokens
    b = pipe.batch_at(0)
    assert b["tokens"].shape == (4, 16) and b["labels"].shape == (4, 16)
    assert not np.array_equal(pipe.batch_at(0)["tokens"], pipe.batch_at(1)["tokens"])


def test_checkpoint_roundtrip(tmp_path):
    params = {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
              "nested": {"b": jnp.ones((3,), jnp.bfloat16)}}
    opt = {"step": jnp.array(7, jnp.int32),
           "m": jax.tree_util.tree_map(jnp.zeros_like, params)}
    path = tmp_path / "ckpt.msgpack"
    save_checkpoint(str(path), step=7, params=params, opt_state=opt,
                    extra={"note": "x"})
    bundle = load_checkpoint(str(path))
    assert bundle["step"] == 7 and bundle["extra"]["note"] == "x"
    restored = restore_into(params, bundle["params"])
    for k in ("a",):
        assert np.array_equal(np.asarray(restored[k]), np.asarray(params[k]))
    ropt = restore_into(opt, bundle["opt_state"])
    assert int(ropt["step"]) == 7
