"""Scenario-engine tests: registry coverage, trace shape, determinism,
and the incremental-core equivalence/dynamic-contention properties."""

import dataclasses

import numpy as np
import pytest

from repro.serving.experiment import expand_function_clones, run_scenario
from repro.serving.profiles import base_function, build_input_pool, build_profiles
from repro.serving.simulator import SimConfig
from repro.serving.workload import (
    ScenarioSpec,
    generate_scenario,
    list_scenarios,
)

SMALL_CFG = dict(
    n_workers=4, vcpus_per_worker=32, physical_cores=32,
    mem_mb_per_worker=16 * 1024, vcpu_limit=32, seed=0,
    # bound the retry backlog so saturating shapes stay test-sized
    retry_interval_s=1.0, queue_timeout_s=45.0,
)


def _fns_and_counts():
    profiles = build_profiles()
    pool = build_input_pool()
    return sorted(profiles), {f: len(pool[f]) for f in profiles}


def test_registry_has_required_scenarios():
    names = list_scenarios()
    assert len(names) >= 7
    for required in ("azure", "poisson-steady", "flash-crowd", "diurnal",
                     "heavy-tail-inputs", "cold-storm", "oversubscribe"):
        assert required in names


def test_unknown_scenario_raises():
    fns, counts = _fns_and_counts()
    with pytest.raises(KeyError, match="unknown scenario"):
        generate_scenario(ScenarioSpec(scenario="nope"), fns, counts)


@pytest.mark.parametrize("scenario", list_scenarios())
def test_traces_well_formed_and_deterministic(scenario):
    """Same ScenarioSpec + seed => the identical Arrival list (ids
    included), sorted by time, within the window, with valid inputs."""
    fns, counts = _fns_and_counts()
    spec = ScenarioSpec(scenario=scenario, rps=2.0, duration_s=90.0, seed=11)
    t1 = generate_scenario(spec, fns, counts)
    t2 = generate_scenario(spec, fns, counts)
    assert t1 == t2
    assert [a.invocation_id for a in t1] == list(range(len(t1)))
    assert all(t1[i].t <= t1[i + 1].t for i in range(len(t1) - 1))
    # azure inherits generate_trace's whole-minute granularity, so the
    # window rounds up to the next minute boundary
    window = 60.0 * np.ceil(spec.duration_s / 60.0)
    for a in t1:
        assert 0.0 <= a.t < window
        assert 0 <= a.input_idx < counts[a.function]


def test_different_seeds_differ():
    fns, counts = _fns_and_counts()
    a = generate_scenario(
        ScenarioSpec(scenario="poisson-steady", rps=3.0, duration_s=120.0,
                     seed=0), fns, counts)
    b = generate_scenario(
        ScenarioSpec(scenario="poisson-steady", rps=3.0, duration_s=120.0,
                     seed=1), fns, counts)
    assert [x.t for x in a] != [x.t for x in b]


def test_flash_crowd_spikes():
    fns, counts = _fns_and_counts()
    spec = ScenarioSpec(scenario="flash-crowd", rps=2.0, duration_s=300.0,
                        seed=0, params={"spike_start_frac": 0.4,
                                        "spike_duration_s": 60.0,
                                        "spike_mult": 8.0})
    trace = generate_scenario(spec, fns, counts)
    t0, t1 = 120.0, 180.0
    in_spike = sum(1 for a in trace if t0 <= a.t < t1)
    outside = len(trace) - in_spike
    spike_rate = in_spike / 60.0
    base_rate = outside / 240.0
    assert spike_rate > 4.0 * base_rate  # ~8x nominally


def test_heavy_tail_skews_large():
    fns, counts = _fns_and_counts()
    base = generate_scenario(
        ScenarioSpec(scenario="poisson-steady", rps=4.0, duration_s=300.0,
                     seed=2), fns, counts)
    heavy = generate_scenario(
        ScenarioSpec(scenario="heavy-tail-inputs", rps=4.0, duration_s=300.0,
                     seed=2), fns, counts)

    def mean_frac(trace):
        return np.mean([a.input_idx / max(counts[a.function] - 1, 1)
                        for a in trace])

    assert mean_frac(heavy) > mean_frac(base) + 0.2


def test_scenario_simulation_deterministic():
    """Same spec + seed => identical summarize() metrics across two
    fresh Simulator runs, for three scenario shapes (satellite req)."""
    for scenario in ("poisson-steady", "flash-crowd", "cold-storm"):
        spec = ScenarioSpec(scenario=scenario, rps=2.0, duration_s=90.0,
                            seed=4)
        s1 = run_scenario("shabari", spec, sim_cfg=SimConfig(**SMALL_CFG))
        s2 = run_scenario("shabari", spec, sim_cfg=SimConfig(**SMALL_CFG))
        assert s1.summary == s2.summary, scenario


def test_incremental_matches_legacy_scans():
    """The incremental per-worker aggregates + warm-container index are
    a pure fast path: metrics identical to the pre-refactor scans."""
    spec = ScenarioSpec(scenario="flash-crowd", rps=2.0, duration_s=90.0,
                        seed=0)
    fast = run_scenario(
        "shabari", spec, sim_cfg=SimConfig(**SMALL_CFG)).summary
    legacy = run_scenario(
        "shabari", spec,
        sim_cfg=SimConfig(**SMALL_CFG, legacy_scans=True)).summary
    assert fast == legacy


def test_dynamic_contention_mode():
    """contention_mode="dynamic" re-times co-runners instead of fixing
    the start-time snapshot; it must stay deterministic, account for
    every arrival, and keep result invariants intact."""
    spec = ScenarioSpec(scenario="flash-crowd", rps=2.0, duration_s=90.0,
                        seed=0)
    # vcpu_limit > physical_cores (the §6 userCPU knob): co-runner
    # demand must be able to exceed the cores for contention to exist
    # at all. With acquire-on-placement accounting, fits() caps
    # committed vCPUs at vcpu_limit, so at vcpu_limit == cores no
    # worker ever runs contended and dynamic == snapshot trivially.
    over_cfg = {**SMALL_CFG, "vcpu_limit": 44}
    cfg = SimConfig(**over_cfg, contention_mode="dynamic")
    r1 = run_scenario("shabari", spec, sim_cfg=cfg, keep_results=True)
    r2 = run_scenario("shabari", spec, sim_cfg=cfg)
    assert r1.summary == r2.summary
    assert r1.summary["n"] == len(r1.results)
    for x in r1.results:
        if not x.timed_out:
            assert x.finish_t >= x.start_t >= x.arrival_t - 1e-9
            assert abs((x.finish_t - x.start_t) - x.exec_s) < 1e-6
    # and it actually differs from the snapshot semantics
    snap = run_scenario(
        "shabari", spec, sim_cfg=SimConfig(**over_cfg)).summary
    assert r1.summary != snap


def test_expand_function_clones_aliases():
    profiles = build_profiles()
    pool = build_input_pool()
    slo = {(fn, i): 1.0 for fn in profiles for i in range(len(pool[fn]))}
    P, L, S = expand_function_clones(profiles, pool, slo, clones=3)
    assert len(P) == 3 * len(profiles)
    assert P["matmult::2"] is profiles["matmult"]
    assert base_function("matmult::2") == "matmult"
    assert S[("matmult::2", 0)] == slo[("matmult", 0)]
    # clones == 1 is the identity
    P1, L1, S1 = expand_function_clones(profiles, pool, slo, clones=1)
    assert P1 is profiles and L1 is pool and S1 is slo
