"""Serving engine integration: batched prefill + greedy decode."""

import jax
import numpy as np
import pytest

from repro.configs import get_reduced_config
from repro.serving.engine import ServingEngine


def test_generate_shapes_and_determinism():
    cfg = get_reduced_config("qwen2_5_3b")
    eng = ServingEngine(cfg, cache_window=64, seed=0)
    rng = np.random.default_rng(0)
    prompts = [list(rng.integers(1, cfg.vocab_size, size=n)) for n in (5, 11)]
    r1 = eng.generate(prompts, max_new_tokens=6)
    r2 = eng.generate(prompts, max_new_tokens=6)
    assert [len(t) for t in r1.tokens] == [6, 6]
    assert r1.tokens == r2.tokens  # greedy decode is deterministic
    assert all(0 <= t < cfg.vocab_size for seq in r1.tokens for t in seq)


def test_generate_ssm_family():
    cfg = get_reduced_config("mamba2_1_3b")
    eng = ServingEngine(cfg, cache_window=64, seed=0)
    r = eng.generate([[1, 2, 3, 4]], max_new_tokens=4)
    assert len(r.tokens[0]) == 4


def test_generate_encdec_family():
    cfg = get_reduced_config("whisper_tiny")
    eng = ServingEngine(cfg, cache_window=64, seed=0)
    r = eng.generate([[1, 2]], max_new_tokens=3)
    assert len(r.tokens[0]) == 3


def test_workload_zipf_popularity():
    """A few functions should dominate the trace (Azure characteristic)."""
    from repro.serving.workload import generate_trace

    fns = [f"f{i}" for i in range(12)]
    trace = generate_trace(
        rps=10.0, functions=fns, inputs_per_function={f: 3 for f in fns},
        duration_s=300.0, seed=0,
    )
    counts = {}
    for a in trace:
        counts[a.function] = counts.get(a.function, 0) + 1
    top3 = sum(sorted(counts.values())[-3:])
    assert top3 / len(trace) > 0.45  # heavy-tailed
