"""Unit tests for the HLO collective parser and roofline math."""

import pytest

from repro.launch import hlo_analysis as ha


def test_parse_simple_all_reduce():
    hlo = """
  %all-reduce.1 = f32[256,1024]{1,0} all-reduce(%add.5), channel_id=1, replica_groups={{0,1,2,3}}, to_apply=%sum
"""
    st = ha.collective_stats(hlo, default_group=16)
    assert st.op_counts == {"all-reduce": 1}
    expected = 2 * 256 * 1024 * 4 * 3 / 4  # 2*T*(n-1)/n, n=4
    assert st.per_device_traffic_bytes == pytest.approx(expected)


def test_parse_iota_replica_groups():
    hlo = "%ag = bf16[16,512]{1,0} all-gather(%x), replica_groups=[16,16]<=[256], dimensions={0}\n"
    st = ha.collective_stats(hlo, default_group=99)
    n = 16
    expected = 16 * 512 * 2 * (n - 1) / n
    assert st.per_device_traffic_bytes == pytest.approx(expected)


def test_start_done_counted_once():
    hlo = """
  %ar-start = f32[8,8]{1,0} all-reduce-start(%x), replica_groups={{0,1}}
  %ar-done = f32[8,8]{1,0} all-reduce-done(%ar-start)
"""
    st = ha.collective_stats(hlo, default_group=2)
    assert st.op_counts.get("all-reduce", 0) == 1


def test_reduce_scatter_factor():
    hlo = "%rs = f32[64]{0} reduce-scatter(%x), replica_groups={{0,1,2,3}}, dimensions={0}\n"
    st = ha.collective_stats(hlo, default_group=4)
    assert st.per_device_traffic_bytes == pytest.approx(64 * 4 * 3)  # R*(n-1)


def test_collective_permute():
    hlo = "%cp = bf16[32,32]{1,0} collective-permute(%x), source_target_pairs={{0,1}}\n"
    st = ha.collective_stats(hlo, default_group=2)
    assert st.per_device_traffic_bytes == pytest.approx(32 * 32 * 2)


def test_roofline_terms_and_dominant():
    rf = ha.roofline_terms(
        per_device_flops=197e12,        # exactly 1s of compute
        per_device_bytes=819e9 * 2,     # 2s of memory
        per_device_collective_bytes=50e9 * 0.5,  # 0.5s
        chips=256, model_flops=197e12 * 256 * 0.5,
        peak_flops=197e12, hbm_bw=819e9, link_bw=50e9,
    )
    assert rf.compute_s == pytest.approx(1.0)
    assert rf.memory_s == pytest.approx(2.0)
    assert rf.collective_s == pytest.approx(0.5)
    assert rf.dominant == "memory"
    assert rf.useful_flops_ratio == pytest.approx(0.5)


def test_model_flops_estimate_kinds():
    from repro.configs import get_config, SHAPES

    cfg = get_config("qwen2_5_3b")
    n = cfg.active_param_count()
    t = ha.model_flops_estimate(cfg, SHAPES["train_4k"])
    assert t == pytest.approx(6.0 * n * 256 * 4096)
    d = ha.model_flops_estimate(cfg, SHAPES["decode_32k"])
    assert d == pytest.approx(2.0 * n * 128)
