"""Per-kernel validation: interpret-mode Pallas vs pure-jnp oracle,
sweeping shapes and dtypes (hypothesis for the shape grids)."""

import jax
import jax.numpy as jnp
import pytest

hypothesis = pytest.importorskip("hypothesis")
st = pytest.importorskip("hypothesis.strategies")
given, settings = hypothesis.given, hypothesis.settings

from repro.kernels import ops, ref
from repro.kernels.decode_attention import decode_attention
from repro.kernels.flash_attention import flash_attention
from repro.kernels.moe_gmm import moe_gmm
from repro.kernels.ssd_scan import ssd_scan
from repro.models.kv_cache import ring_positions, ring_valid

TOL = {jnp.float32: 2e-5, jnp.bfloat16: 3e-2}


def _tol(dt, ref_val):
    scale = float(jnp.max(jnp.abs(ref_val.astype(jnp.float32)))) + 1e-6
    return TOL[dt] * max(scale, 1.0)


@settings(max_examples=12, deadline=None)
@given(
    B=st.integers(1, 2),
    S=st.sampled_from([64, 96, 128, 200]),
    hkv=st.sampled_from([1, 2, 4]),
    group=st.sampled_from([1, 2, 4]),
    D=st.sampled_from([64, 128]),
    causal=st.booleans(),
    window=st.sampled_from([None, 32, 100]),
    dt=st.sampled_from([jnp.float32, jnp.bfloat16]),
)
def test_flash_attention_matches_oracle(B, S, hkv, group, D, causal, window, dt):
    H = hkv * group
    key = jax.random.PRNGKey(B * 1000 + S + H)
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (B, S, H, D), dt)
    k = jax.random.normal(ks[1], (B, S, hkv, D), dt)
    v = jax.random.normal(ks[2], (B, S, hkv, D), dt)
    out = flash_attention(q, k, v, causal=causal, window=window,
                          block_q=64, block_kv=64, interpret=True)
    expected = ref.flash_attention_ref(q, k, v, causal=causal, window=window)
    err = float(jnp.max(jnp.abs(out.astype(jnp.float32)
                                - expected.astype(jnp.float32))))
    assert err <= _tol(dt, expected), (err, _tol(dt, expected))


@settings(max_examples=12, deadline=None)
@given(
    B=st.integers(1, 3),
    W=st.sampled_from([64, 96, 130]),
    hkv=st.sampled_from([1, 2, 8]),
    group=st.sampled_from([1, 4]),
    D=st.sampled_from([64, 128]),
    pos_ratio=st.sampled_from([0.5, 1.0, 2.5]),
    dt=st.sampled_from([jnp.float32, jnp.bfloat16]),
)
def test_decode_attention_matches_oracle(B, W, hkv, group, D, pos_ratio, dt):
    H = hkv * group
    key = jax.random.PRNGKey(W + H)
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (B, 1, H, D), dt)
    k = jax.random.normal(ks[1], (B, W, hkv, D), dt)
    v = jax.random.normal(ks[2], (B, W, hkv, D), dt)
    pos = jnp.full((B,), max(1, int(W * pos_ratio)), jnp.int32)
    kvp, kvv = ring_positions(pos, W), ring_valid(pos, W)
    out = decode_attention(q, k, v, kvp, kvv, pos, block_kv=64, interpret=True)
    expected = ref.decode_attention_ref(q, k, v, kvp, kvv, pos)
    err = float(jnp.max(jnp.abs(out.astype(jnp.float32)
                                - expected.astype(jnp.float32))))
    assert err <= _tol(dt, expected), (err, _tol(dt, expected))


@settings(max_examples=10, deadline=None)
@given(
    B=st.integers(1, 2),
    nc=st.integers(1, 4),
    Q=st.sampled_from([32, 64]),
    H=st.sampled_from([2, 4]),
    P=st.sampled_from([32, 64]),
    N=st.sampled_from([32, 128]),
    with_init=st.booleans(),
)
def test_ssd_scan_matches_oracles(B, nc, Q, H, P, N, with_init):
    S = nc * Q
    key = jax.random.PRNGKey(S + H + N)
    ks = jax.random.split(key, 6)
    x = jax.random.normal(ks[0], (B, S, H, P), jnp.float32) * 0.5
    dtv = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.3)
    B_ = jax.random.normal(ks[3], (B, S, N)) * 0.5
    C_ = jax.random.normal(ks[4], (B, S, N)) * 0.5
    init = (jax.random.normal(ks[5], (B, H, P, N)) * 0.2) if with_init else None
    y, st_out = ssd_scan(x, dtv, A, B_, C_, Q, init, interpret=True)
    y_ref, st_ref = ref.ssd_scan_ref(x, dtv, A, B_, C_, Q, init)
    assert float(jnp.max(jnp.abs(y - y_ref))) < 1e-4
    assert float(jnp.max(jnp.abs(st_out - st_ref))) < 1e-4
    # and both equal the sequential ground truth
    y_seq, st_seq = ref.ssd_scan_sequential_ref(x, dtv, A, B_, C_, init)
    assert float(jnp.max(jnp.abs(y - y_seq))) < 5e-3
    assert float(jnp.max(jnp.abs(st_out - st_seq))) < 5e-3


@settings(max_examples=10, deadline=None)
@given(
    E=st.sampled_from([2, 4, 8]),
    C=st.sampled_from([16, 100, 128]),
    D=st.sampled_from([64, 130]),
    F=st.sampled_from([64, 96]),
    dt=st.sampled_from([jnp.float32, jnp.bfloat16]),
)
def test_moe_gmm_matches_oracle(E, C, D, F, dt):
    key = jax.random.PRNGKey(E * C + D)
    ks = jax.random.split(key, 2)
    buf = jax.random.normal(ks[0], (E, C, D), dt)
    w = jax.random.normal(ks[1], (E, D, F), dt) * (D ** -0.5)
    out = moe_gmm(buf, w, block_c=32, block_d=64, block_f=64, interpret=True)
    expected = ref.moe_gmm_ref(buf, w)
    err = float(jnp.max(jnp.abs(out.astype(jnp.float32)
                                - expected.astype(jnp.float32))))
    assert err <= _tol(dt, expected), (err, _tol(dt, expected))


def test_model_use_pallas_matches_reference():
    """End-to-end: model forward with Pallas kernels == reference path."""
    from repro.configs import get_reduced_config
    from repro.models.model import forward_seq, init_params

    key = jax.random.PRNGKey(0)
    for arch in ("qwen2_5_3b", "mamba2_1_3b"):
        cfg = get_reduced_config(arch)
        params = init_params(key, cfg)
        S = cfg.ssm_chunk if cfg.family == "ssm" else 64
        toks = jax.random.randint(key, (2, S), 0, cfg.vocab_size)
        l1, _, _ = forward_seq(params, cfg, toks, use_pallas=False)
        l2, _, _ = forward_seq(params, cfg, toks, use_pallas=True)
        scale = float(jnp.max(jnp.abs(l1.astype(jnp.float32)))) + 1e-6
        err = float(jnp.max(jnp.abs(l1.astype(jnp.float32)
                                    - l2.astype(jnp.float32)))) / scale
        assert err < 0.02, (arch, err)
