"""Round-trip test for the golden-refresh script itself.

scripts/refresh_goldens.py is the glue the CI golden-drift job depends
on: it must emit snapshots in exactly the schema golden.py/
test_goldens.py consume, or the drift check degenerates into a
confusing golden-assert failure. Run one scenario through the script
into a tmpdir and pin the emitted JSON against the committed snapshot
(same schema, same metrics within golden tolerance).
"""

import dataclasses
import json
import math
import os
import subprocess
import sys

from repro.serving.golden import ATOL, GOLDEN_POLICY, RTOL, golden_specs

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SCRIPT = os.path.join(REPO, "scripts", "refresh_goldens.py")
GOLDEN_DIR = os.path.join(REPO, "tests", "goldens")
SCENARIO = "poisson-steady"  # cheapest member of LEGACY_ACQUIRE_SCENARIOS


def _assert_matches_committed(emitted_path: str, committed_path: str) -> dict:
    with open(emitted_path) as f:
        emitted = json.load(f)
    with open(committed_path) as f:
        committed = json.load(f)
    # exact snapshot schema golden.py / test_goldens.py consume
    assert set(emitted) == {"policy", "spec", "summary"}
    assert emitted["policy"] == GOLDEN_POLICY
    assert emitted["spec"] == dataclasses.asdict(golden_specs()[SCENARIO])
    assert set(emitted["summary"]) == set(committed["summary"])
    for key, want in committed["summary"].items():
        got = emitted["summary"][key]
        assert math.isclose(got, want, rel_tol=RTOL, abs_tol=ATOL), (
            f"{os.path.basename(emitted_path)}: {key} got {got!r}, "
            f"committed {want!r}"
        )
    return emitted


def test_refresh_goldens_round_trip(tmp_path):
    proc = subprocess.run(
        [sys.executable, SCRIPT, "--only", SCENARIO,
         "--out-dir", str(tmp_path)],
        capture_output=True, text=True, cwd=REPO,
        env={**os.environ, "JAX_PLATFORMS": "cpu"},
    )
    assert proc.returncode == 0, proc.stderr
    assert SCENARIO in proc.stdout

    _assert_matches_committed(
        str(tmp_path / f"{SCENARIO}.json"),
        os.path.join(GOLDEN_DIR, f"{SCENARIO}.json"),
    )
    # the acquire-on-placement A/B snapshot rides along for this scenario
    _assert_matches_committed(
        str(tmp_path / "legacy-acquire" / f"{SCENARIO}.json"),
        os.path.join(GOLDEN_DIR, "legacy-acquire", f"{SCENARIO}.json"),
    )


def test_refresh_goldens_rejects_unknown_scenario(tmp_path):
    proc = subprocess.run(
        [sys.executable, SCRIPT, "--only", "no-such-scenario",
         "--out-dir", str(tmp_path)],
        capture_output=True, text=True, cwd=REPO,
    )
    assert proc.returncode != 0
    assert "no-such-scenario" in proc.stderr
    assert not list(tmp_path.iterdir())  # nothing written on bad input
