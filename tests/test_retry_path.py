"""Retry/timeout-path tests: the policy is consulted exactly once per
invocation regardless of retries, timed-out invocations never touch it,
queue accounting is recorded, and the legacy A/B toggle restores the
pre-fix behavior."""

from repro.core.allocator import Allocation
from repro.serving import baselines as B
from repro.serving.profiles import build_input_pool, build_profiles
from repro.serving.simulator import Policy, SimConfig, Simulator, summarize
from repro.serving.workload import Arrival

FN = "lrtrain"  # ~2.5 s at 8 vCPUs on its smallest input


class CountingPolicy(Policy):
    """Static allocation + per-invocation allocate-call counter."""

    name = "counting"
    uses_shabari_scheduler = True
    placement = "hashing"

    def __init__(self, vcpus=8, mem_mb=1024):
        self.vcpus, self.mem_mb = vcpus, mem_mb
        self.calls = {}

    def allocate(self, arrival, meta, sim):
        self.calls[arrival.invocation_id] = (
            self.calls.get(arrival.invocation_id, 0) + 1
        )
        return Allocation(self.vcpus, self.mem_mb)


def _one_worker_cfg(**over):
    """One 8-vCPU worker; an 8-vCPU allocation serializes the cluster."""
    base = dict(
        n_workers=1, vcpus_per_worker=8, physical_cores=8,
        mem_mb_per_worker=4096, vcpu_limit=8,
        retry_interval_s=0.5, queue_timeout_s=300.0, seed=0,
    )
    base.update(over)
    return SimConfig(**base)


def _run(policy, arrivals, cfg):
    profiles = build_profiles()
    pool = build_input_pool(seed=0)
    slo = B.build_slo_table(profiles, pool)
    sim = Simulator(policy=policy, profiles=profiles, input_pool=pool,
                    slo_table=slo, cfg=cfg)
    return sim, sim.run(arrivals)


def test_exactly_one_allocate_per_invocation_despite_retries():
    pol = CountingPolicy()
    # one invocation takes the worker; five more arrive while it runs
    # and retry every 0.5 s until the worker frees up
    arrivals = [Arrival(0, 0.0, FN, 0)] + [
        Arrival(i, 1.5, FN, 0) for i in range(1, 6)
    ]
    sim, results = _run(pol, arrivals, _one_worker_cfg())
    assert len(results) == 6
    assert not any(r.timed_out for r in results)
    assert any(r.queued_s > 0 for r in results)  # retries really happened
    assert sim.events_processed > 2 * len(arrivals)  # incl. retry events
    assert pol.calls == {i: 1 for i in range(6)}


def test_timed_out_invocations_use_cached_alloc_and_skip_policy():
    pol = CountingPolicy()
    # queue_timeout shorter than the retry interval: every queued
    # invocation times out on its first retry
    cfg = _one_worker_cfg(queue_timeout_s=0.4)
    arrivals = [Arrival(0, 0.0, FN, 0)] + [
        Arrival(i, 1.5, FN, 0) for i in range(1, 8)
    ]
    sim, results = _run(pol, arrivals, cfg)
    timed = [r for r in results if r.timed_out]
    assert len(results) == 8 and len(timed) == 7
    for r in timed:
        # queue accounting: the full wait is recorded, past the timeout
        assert r.queued_s > cfg.queue_timeout_s
        assert r.queued_s == r.finish_t - r.arrival_t
        assert r.slo_violated
        # the cached first-attempt allocation is what gets reported
        assert (r.alloc_vcpus, r.alloc_mem_mb) == (8, 1024)
    # the policy was consulted exactly once per invocation — retries and
    # the timeout path never re-entered it
    assert pol.calls == {i: 1 for i in range(8)}


def test_timed_out_invocations_release_cached_features():
    """ShabariPolicy caches a feature vector per allocate; the timeout
    path must release it via Policy.forget (feedback never fires for a
    timed-out invocation, so without forget the entry leaks)."""
    pol = B.ShabariPolicy()
    # shabari's learning-phase default is 10 vCPUs; a 12-vCPU worker
    # fits exactly one such invocation at a time
    cfg = _one_worker_cfg(queue_timeout_s=0.4, vcpus_per_worker=12,
                          vcpu_limit=12, physical_cores=12)
    arrivals = [Arrival(0, 0.0, FN, 0)] + [
        Arrival(i, 1.5, FN, 0) for i in range(1, 8)
    ]
    _, results = _run(pol, arrivals, cfg)
    assert sum(r.timed_out for r in results) == 7
    assert not pol._features


def test_legacy_retry_alloc_restores_per_retry_predicts():
    pol = CountingPolicy()
    cfg = _one_worker_cfg(legacy_retry_alloc=True)
    arrivals = [Arrival(0, 0.0, FN, 0)] + [
        Arrival(i, 1.5, FN, 0) for i in range(1, 6)
    ]
    _, results = _run(pol, arrivals, cfg)
    assert len(results) == 6
    # the pre-fix path re-runs allocate on every retry
    assert max(pol.calls.values()) > 1


def test_retry_cache_metric_neutral_for_non_queued_invocations():
    """With a deterministic-allocation policy the fix is a pure fast
    path: metrics identical to the legacy retry path even under
    saturation (same alloc on every retry), and trivially so when
    nothing ever queues."""
    for arrivals in (
        [Arrival(i, 10.0 * i, FN, 0) for i in range(4)],      # no queueing
        [Arrival(0, 0.0, FN, 0)] + [
            Arrival(i, 1.5, FN, 0) for i in range(1, 6)       # retry storm
        ],
    ):
        summaries = []
        for legacy in (False, True):
            pol = CountingPolicy()
            cfg = _one_worker_cfg(legacy_retry_alloc=legacy)
            _, results = _run(pol, arrivals, cfg)
            summaries.append(summarize(results))
        assert summaries[0] == summaries[1]
