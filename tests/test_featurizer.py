"""Featurizer tests: Table 2 schemas, background path, standardization."""

import numpy as np
import pytest

from repro.core.featurizer import FEATURE_SCHEMAS, Featurizer


def test_schemas_match_table2():
    assert FEATURE_SCHEMAS["image"] == [
        "width", "height", "channels", "dpi_x", "dpi_y", "file_size"]
    assert FEATURE_SCHEMAS["matrix"] == ["rows", "cols", "density"]
    assert set(FEATURE_SCHEMAS["video"]) >= {
        "width", "height", "duration", "bitrate", "fps", "encoding"}
    assert set(FEATURE_SCHEMAS["audio"]) >= {
        "channels", "sample_rate", "duration", "bitrate", "is_flac"}


def test_unknown_type_falls_back_to_payload():
    f = Featurizer()
    x = f.raw_features("mystery", {"payload": 42.0})
    assert x.shape == (1,)


def test_background_persist_then_lookup():
    f = Featurizer()
    f.persist_object("obj1", "image",
                     {"width": 100, "height": 50, "channels": 3,
                      "dpi_x": 72, "dpi_y": 72, "file_size": 5000})
    assert f.has_object("obj1")
    x = f.extract("fn", "image", {}, object_id="obj1")
    assert x.shape == (6,)


def test_standardization_converges():
    f = Featurizer()
    rng = np.random.default_rng(0)
    xs = []
    for _ in range(200):
        size = float(rng.uniform(1e3, 1e7))
        xs.append(f.extract("fn", "file", {"file_size": size}))
    tail = np.array(xs[50:])
    # standardized features are zero-mean-ish, unit-scale-ish
    assert abs(tail.mean()) < 0.5
    assert 0.3 < tail.std() < 3.0


def test_encoding_enum():
    f = Featurizer()
    a = f.raw_features("video", {"width": 1, "height": 1, "duration": 1,
                                 "bitrate": 1, "fps": 1, "encoding": "mp4"})
    b = f.raw_features("video", {"width": 1, "height": 1, "duration": 1,
                                 "bitrate": 1, "fps": 1, "encoding": "av1"})
    assert a[-1] != b[-1]
