"""Simulator-wide property tests: invariants that must hold for EVERY
(scenario, routing, admission, fleet) cell, not just the golden-pinned
ones, plus the full-registry determinism sweep.

Invariants (checked after a full run, with the release/unreserve
asserts inside repro.core.cluster guarding the during-run half):

* accounting — every trace invocation terminates exactly once
  (completed / shed / timed-out / OOM); chain runs additionally
  account every SPAWNED stage invocation, with ids disjoint from the
  trace block;
* capacity — no worker ends over its vcpu/memory limits or below
  zero, cluster aggregates equal the sum over their workers, and the
  §5 active-demand aggregates drain back to zero;
* reservations — every acquire-on-placement reservation is released
  by completion, cancellation, or timeout: reserved vcpus/memory are
  zero fleet-wide at the end;
* image-cache refs — reaping every surviving container leaves no
  in-use image and no layer with a nonzero refcount.

The determinism sweep runs every registered scenario twice per
routing x admission cell assignment and requires byte-identical
summaries — the nondeterminism class of bug goldens only catch on the
cells they pin.

Property tests use hypothesis when available and a seeded parametrize
sweep when not (same pattern as test_agent_arena)."""

import dataclasses
import json

import pytest

try:  # property tests use hypothesis when present, seeded sweeps if not
    import hypothesis
    from hypothesis import strategies as st
    given, settings = hypothesis.given, hypothesis.settings
except ModuleNotFoundError:  # pragma: no cover
    hypothesis = None


def _prop(argnames, hyp_strategies, fallback_cases, max_examples=30):
    """@given(**hyp_strategies) under hypothesis; otherwise a seeded
    pytest.mark.parametrize over ``fallback_cases``."""
    def deco(fn):
        if hypothesis is not None:
            return given(**hyp_strategies)(
                settings(max_examples=max_examples, deadline=None)(fn))
        return pytest.mark.parametrize(argnames, fallback_cases)(fn)
    return deco


from repro.core.router import ADMISSION_POLICIES, ROUTING_POLICIES
from repro.serving import baselines as B
from repro.serving.experiment import make_policy, run_scenario
from repro.serving.golden import GOLDEN_POLICY, golden_sim_config
from repro.serving.profiles import build_input_pool, build_profiles
from repro.serving.simulator import Simulator
from repro.serving.workload import (
    ScenarioSpec,
    generate_scenario,
    list_scenarios,
)


@pytest.fixture(scope="module")
def stack():
    profiles = build_profiles()
    pool = build_input_pool(seed=0)
    slo_table = B.build_slo_table(profiles, pool)
    return profiles, pool, slo_table


def _cell(seed):
    """Deterministic (scenario, routing, admission, n_workers) draw —
    the seed is the only free variable so hypothesis shrinking and the
    seeded fallback explore one shared space."""
    names = sorted(list_scenarios())
    return (names[seed % len(names)],
            ROUTING_POLICIES[(seed // 3) % len(ROUTING_POLICIES)],
            ADMISSION_POLICIES[(seed // 7) % len(ADMISSION_POLICIES)],
            2 + 2 * (seed % 2))


def _run_cell(stack, seed, duration_s=40.0):
    profiles, pool, slo_table = stack
    scenario, routing, admission, n_workers = _cell(seed)
    cfg = dataclasses.replace(
        golden_sim_config(scenario), routing=routing, admission=admission)
    if cfg.fleet is None:
        # fleet dimension: shrink the uniform fleet on odd seeds
        # (explicit FleetSpec scenarios keep their pinned hardware)
        cfg = dataclasses.replace(cfg, n_workers=n_workers)
    spec = ScenarioSpec(scenario=scenario, rps=2.0, duration_s=duration_s,
                        seed=seed)
    trace = generate_scenario(
        spec, functions=sorted(profiles),
        inputs_per_function={f: len(pool[f]) for f in profiles})
    pol = make_policy(GOLDEN_POLICY, profiles, pool, slo_table, seed=0)
    sim = Simulator(policy=pol, profiles=profiles, input_pool=pool,
                    slo_table=slo_table, cfg=cfg)
    return sim, trace, sim.run(trace)


def _assert_invariants(sim, trace, results):
    # ---- accounting: every invocation terminates exactly once
    ids = [r.invocation_id for r in results]
    assert len(ids) == len(set(ids)), "an invocation terminated twice"
    got = set(ids)
    trace_ids = {a.invocation_id for a in trace}
    assert trace_ids <= got, (
        f"trace invocations unaccounted: {sorted(trace_ids - got)[:5]}")
    extra = got - trace_ids
    if sim._chains is None:
        assert not extra, f"phantom invocations: {sorted(extra)[:5]}"
    else:
        # chain stage spawns mint ids above the trace's 0..n-1 block,
        # and every spawned stage must itself terminate exactly once
        assert all(i >= len(trace) for i in extra)
        assert len(extra) == sim._chains.stage_spawned
    for r in results:
        assert not (r.shed and r.timed_out), r
        if r.shed or r.timed_out:
            assert not r.oom_killed and r.exec_s == 0.0, r

    # ---- capacity + reservations + §5 aggregates drain
    for cl in sim.clusters:
        for w in cl.workers:
            assert 0 <= w.used_vcpus <= w.vcpu_limit, (w.wid, w.used_vcpus)
            assert 0 <= w.used_mem_mb <= w.total_mem_mb
            assert w.reserved_vcpus == 0 and w.reserved_mem_mb == 0, (
                "reservation leaked on worker", w.wid)
            assert w.active_demand_vcpus == pytest.approx(0.0, abs=1e-6)
            assert w.active_net_gbps == pytest.approx(0.0, abs=1e-9)
            for c in w.containers.values():
                assert not c.busy, ("busy container at sim end", c.cid)
        assert cl.reserved_vcpus == 0 and cl.reserved_mem_mb == 0
        assert cl.used_vcpus == sum(w.used_vcpus for w in cl.workers)
        assert cl.used_mem_mb == sum(w.used_mem_mb for w in cl.workers)

    # ---- image-cache refs: reap everything -> no refs survive
    for cl in sim.clusters:
        for w in cl.workers:
            for c in list(w.containers.values()):
                cl.remove_container(c)
            ic = w.image_cache
            if ic is not None:
                assert not ic._inuse_images, (
                    "image refs leaked", dict(ic._inuse_images))
                assert all(rec[2] == 0 for rec in ic._layers.values()), (
                    "layer refcount leaked")


@_prop("seed",
       dict(seed=st.integers(0, 10_000)) if hypothesis else None,
       [0, 1, 2, 3, 4, 5, 8, 12],
       max_examples=12)
def test_invariants_hold_across_random_cells(stack, seed):
    sim, trace, results = _run_cell(stack, seed)
    _assert_invariants(sim, trace, results)


def test_invariants_hold_on_chain_scenarios_explicitly(stack):
    """The randomized draw may or may not land on the chain scenarios;
    pin them (both slack modes) so the accounting invariant always
    covers simulator-spawned invocations."""
    names = sorted(list_scenarios())
    for scenario in ("chain-pipeline", "fan-out-join"):
        seed = names.index(scenario)  # lands _cell on this scenario
        sim, trace, results = _run_cell(stack, seed)
        assert sim._chains is not None and sim._chains.stage_spawned > 0
        _assert_invariants(sim, trace, results)


# -------------------------------------------------- determinism sweep
def test_determinism_sweep_full_registry_and_matrix():
    """Every registered scenario runs twice under the same seed on its
    assigned routing x admission cells; both passes must serialize to
    byte-identical summaries (including the chain block). Cells are
    dealt round-robin so all 16 combinations and all scenarios are
    exercised without running the full cross product."""
    cells = [(ro, ad) for ro in ROUTING_POLICIES for ad in ADMISSION_POLICIES]
    names = sorted(list_scenarios())
    n = max(len(cells), len(names))
    for i in range(n):
        scenario = names[i % len(names)]
        routing, admission = cells[i % len(cells)]
        cfg = dataclasses.replace(
            golden_sim_config(scenario), routing=routing,
            admission=admission)
        spec = ScenarioSpec(scenario=scenario, rps=1.5, duration_s=60.0,
                            seed=3)
        docs = []
        for _ in range(2):
            res = run_scenario(GOLDEN_POLICY, spec, sim_cfg=cfg)
            docs.append(json.dumps(
                {"summary": res.summary, "chain": res.chain_summary},
                sort_keys=True))
        assert docs[0] == docs[1], (
            f"nondeterminism: {scenario} routing={routing} "
            f"admission={admission}")
