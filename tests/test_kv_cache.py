"""Property tests for the ring-buffer KV cache (hypothesis)."""

import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
st = pytest.importorskip("hypothesis.strategies")
given, settings = hypothesis.given, hypothesis.settings

from repro.models.kv_cache import (
    ring_positions,
    ring_valid,
    ring_write,
    write_prefill,
)


@given(
    W=st.integers(2, 64),
    pos=st.integers(0, 300),
)
@settings(max_examples=80, deadline=None)
def test_ring_positions_properties(W, pos):
    p = jnp.array([pos], jnp.int32)
    rp = np.array(ring_positions(p, W))[0]
    rv = np.array(ring_valid(p, W))[0]
    for slot in range(W):
        ap = rp[slot]
        if rv[slot]:
            # the most recent write to this slot: largest x < pos, x%W==slot
            assert ap % W == slot
            assert 0 <= ap < pos
            assert ap + W >= pos  # nothing newer fits in the same slot
        else:
            assert ap < 0  # never written


@given(
    W=st.integers(2, 16),
    n_writes=st.integers(1, 40),
)
@settings(max_examples=40, deadline=None)
def test_ring_write_matches_simulation(W, n_writes):
    B, D = 2, 3
    buf = jnp.zeros((B, W, D))
    expect = np.zeros((B, W, D))
    for t in range(n_writes):
        val = np.full((B, D), float(t + 1))
        buf = ring_write(buf, jnp.asarray(val), jnp.full((B,), t, jnp.int32))
        expect[:, t % W] = val
    assert np.allclose(np.array(buf), expect)


@given(S=st.integers(1, 48), W=st.integers(2, 16))
@settings(max_examples=40, deadline=None)
def test_write_prefill_equals_sequential_writes(S, W):
    B, D = 1, 2
    new = jnp.arange(S, dtype=jnp.float32)[None, :, None] + 1.0
    new = jnp.broadcast_to(new, (B, S, D))
    bulk = write_prefill(jnp.zeros((B, W, D)), new)
    seq = jnp.zeros((B, W, D))
    for t in range(S):
        seq = ring_write(seq, new[:, t], jnp.full((B,), t, jnp.int32))
    assert np.allclose(np.array(bulk), np.array(seq))
