"""Integration: prefill + one decode step must equal the full-sequence
forward at the next position, for every architecture family.

MoE capacity dropping is token-competition-dependent (GShard semantics),
so MoE runs drop-free (high capacity factor) for exactness.
"""

import jax
import jax.numpy as jnp
import pytest

import repro.models.moe as MOE
from repro.configs import ARCH_IDS, get_reduced_config
from repro.models.model import (
    forward_decode,
    forward_prefill,
    forward_seq,
    init_params,
)


@pytest.fixture(autouse=True)
def _dropfree_moe(monkeypatch):
    monkeypatch.setattr(MOE, "CAPACITY_FACTOR", 16.0)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_decode_matches_full_forward(arch):
    cfg = get_reduced_config(arch)
    key = jax.random.PRNGKey(1)
    params = init_params(key, cfg)
    B, S = 2, 17
    if cfg.family in ("ssm", "hybrid"):
        S = cfg.ssm_chunk
    kwargs = {}
    if cfg.family == "vlm":
        kwargs["patch_embeds"] = 0.1 * jnp.ones(
            (B, cfg.frontend_tokens, cfg.d_model), cfg.dtype)
    if cfg.is_encoder_decoder:
        kwargs["frame_embeds"] = 0.1 * jnp.ones(
            (B, cfg.encoder_seq, cfg.d_model), cfg.dtype)
    tokens = jax.random.randint(key, (B, S + 1), 0, cfg.vocab_size)

    off = cfg.frontend_tokens if cfg.family == "vlm" else 0
    _, cache = forward_prefill(
        params, cfg, tokens[:, :S], cache_window=S + off + 4, **kwargs
    )
    logits_dec, _ = forward_decode(params, cfg, tokens[:, S], cache)

    if cfg.family in ("ssm", "hybrid"):
        pad = (-(S + 1)) % cfg.ssm_chunk
        toks_full = jnp.pad(tokens, ((0, 0), (0, pad)))
    else:
        toks_full = tokens
    logits_full, _, _ = forward_seq(params, cfg, toks_full, **kwargs)
    ref = logits_full[:, off + S].astype(jnp.float32)
    got = logits_dec.astype(jnp.float32)
    scale = float(jnp.max(jnp.abs(ref))) + 1e-6
    err = float(jnp.max(jnp.abs(ref - got))) / scale
    assert err < 0.02, (arch, err)


def test_decode_is_deterministic():
    cfg = get_reduced_config("qwen2_5_3b")
    key = jax.random.PRNGKey(2)
    params = init_params(key, cfg)
    tokens = jax.random.randint(key, (2, 16), 0, cfg.vocab_size)
    _, cache = forward_prefill(params, cfg, tokens, cache_window=24)
    nxt = jnp.zeros((2,), jnp.int32)
    l1, c1 = forward_decode(params, cfg, nxt, cache)
    l2, c2 = forward_decode(params, cfg, nxt, cache)
    assert jnp.array_equal(l1, l2)
    for k in cache:
        assert jnp.array_equal(c1[k], c2[k]), k
