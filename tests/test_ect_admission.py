"""Per-input ECT estimation + SLO-native admission tests (PR 6).

Pins the three metrics/estimator bugfixes that ride this PR —
summarize() counting never-ran invocations as waste, warm larger-
container binds priced at the request's size, and OOM-killed runs
inflating the calibration feed — plus the new behavior: the
per-function online regressor over the invocation's cached feature
vector (repro.core.ect) and ``admission="slo"`` shedding exactly the
invocations whose best fleet-wide completion-time estimate exceeds
their remaining SLO budget.
"""

import numpy as np
import pytest

from repro.core.allocator import Allocation
from repro.core.cluster import Cluster
from repro.core.ect import ECT_SHED_OBS, ECT_WARMUP_OBS, ECTRegressor
from repro.core.fleet import MachineType
from repro.core.router import DEFAULT_EXEC_ESTIMATE_S, Router
from repro.core.scheduler import ShabariScheduler
from repro.serving import baselines as B
from repro.serving.experiment import run_scenario
from repro.serving.profiles import build_input_pool, build_profiles
from repro.serving.simulator import (
    InvocationResult,
    SimConfig,
    Simulator,
    summarize,
)
from repro.serving.workload import Arrival, ScenarioSpec

ALLOC = Allocation(4, 512)


def _mk(n_clusters=2, physical_cores=None, **kwargs):
    # hardware rides on each worker's MachineType (repro.core.fleet)
    machines = None
    if physical_cores is not None:
        machines = [MachineType(physical_cores=physical_cores, vcpus=16,
                                mem_mb=8192)] * 2
    clusters = [
        Cluster(n_workers=2, vcpus_per_worker=16, mem_mb_per_worker=8192,
                vcpu_limit=16, machines=machines)
        for _ in range(n_clusters)
    ]
    scheds = [ShabariScheduler(c) for c in clusters]
    return clusters, Router(clusters, scheds, **kwargs)


# ------------------------------------------------- summarize() truthfulness
def _ran(wasted_v, wasted_m):
    """An invocation that ran, allocated 8 vCPUs / 1024 MB, wasting the
    given amounts."""
    return InvocationResult(
        invocation_id=0, function="f", arrival_t=0.0, start_t=0.0,
        finish_t=1.0, slo_s=10.0, alloc_vcpus=8, alloc_mem_mb=1024,
        used_vcpus=8 - wasted_v, used_mem_mb=1024 - wasted_m,
    )


def _never_ran(**kw):
    """A shed/timed-out record: real alloc_*, used_*=0 (what
    _record_terminal emits)."""
    return InvocationResult(
        invocation_id=1, function="f", arrival_t=0.0, start_t=0.0,
        finish_t=0.0, slo_s=10.0, alloc_vcpus=8, alloc_mem_mb=1024, **kw
    )


def test_summarize_excludes_never_ran_from_waste_and_util():
    """Shed/timed-out records must not contribute phantom waste or
    depressed utilization — hand computation over the ran subset."""
    results = [
        _ran(0.0, 0.0),     # fully used
        _ran(2.0, 256.0),   # wasted 2 vCPUs / 256 MB
        _never_ran(shed=True),
        _never_ran(timed_out=True),
    ]
    s = summarize(results)
    # percentiles over the TWO ran records only
    assert s["wasted_vcpus_p50"] == pytest.approx(1.0)  # median of [0, 2]
    assert s["wasted_mem_mb_p50"] == pytest.approx(128.0)
    assert s["cpu_util_p50"] == pytest.approx((1.0 + 0.75) / 2)
    assert s["mem_util_p50"] == pytest.approx((1.0 + 0.75) / 2)
    # shed/timeout still count in the rate metrics
    assert s["n"] == 4
    assert s["shed_pct"] == pytest.approx(25.0)
    assert s["timeout_pct"] == pytest.approx(25.0)
    assert s["slo_violation_pct"] == pytest.approx(50.0)


def test_summarize_all_shed_reports_zero_waste():
    """A run where nothing executed has no waste/utilization to report
    (and must not crash on empty percentile arrays)."""
    s = summarize([_never_ran(shed=True), _never_ran(shed=True)])
    assert s["shed_pct"] == 100.0
    assert s["wasted_vcpus_p50"] == 0.0
    assert s["wasted_mem_mb_p95"] == 0.0
    assert s["cpu_util_p50"] == 0.0 and s["mem_util_p50"] == 0.0


# --------------------------------------------- warm-bind contention pricing
def test_warm_larger_bind_priced_at_container_size():
    """_estimate's warm case must forecast contention with the warm
    candidate's ACTUAL size (the invocation runs at c.vcpus, which a
    case-(2) bind can make larger than the request), not the request's."""
    clusters, r = _mk(n_clusters=1, physical_cores=8)
    w = clusters[0].workers[0]
    c = clusters[0].new_container(w, "f", 8, 1024, now=0.0, warm_at=0.0)
    w.add_active(8.0, 0.0)  # co-runner demand so the sizes diverge
    est, kind, payload = r._estimate(0, "f", ALLOC, now=1.0)
    assert kind == "warm" and payload is c
    # slowdown at the container's 8 vCPUs: (8 + 8) / 8 = 2.0; pricing at
    # the request's 4 would give 1.5
    want = r.sched_overhead_s + 2.0 * DEFAULT_EXEC_ESTIMATE_S
    assert est == pytest.approx(want)
    assert r._slowdown(w, "f", c.vcpus) == pytest.approx(2.0)
    assert r._slowdown(w, "f", ALLOC.vcpus) == pytest.approx(1.5)


# ------------------------------------------------- OOM calibration skipping
@pytest.fixture(scope="module")
def stack():
    profiles = build_profiles()
    pool = build_input_pool(seed=0)
    slo_table = B.build_slo_table(profiles, pool)
    return profiles, pool, slo_table


def _static_sim(stack, mem_mb, **cfg_overrides):
    profiles, pool, slo_table = stack
    cfg = SimConfig(n_workers=2, vcpus_per_worker=16, physical_cores=16,
                    mem_mb_per_worker=8 * 1024, vcpu_limit=10_000, seed=0,
                    **cfg_overrides)
    policy = B.StaticPolicy(12, mem_mb, "static-test")
    return Simulator(policy=policy, profiles=profiles, input_pool=pool,
                     slo_table=slo_table, cfg=cfg), sorted(profiles)[0]


def test_oom_completions_leave_estimator_untouched(stack):
    """An OOM-killed run executed only a fraction of base_exec; feeding
    the full figure would inflate the exec EWMA — OOM completions must
    not calibrate."""
    sim, fn = _static_sim(stack, mem_mb=1)  # 1 MB: everything OOMs
    results = sim.run([Arrival(0, 0.0, fn, 0)])
    assert len(results) == 1 and results[0].oom_killed
    assert sim.router._exec_ewma == {}


def test_healthy_completions_still_calibrate(stack):
    sim, fn = _static_sim(stack, mem_mb=6 * 1024)
    results = sim.run([Arrival(0, 0.0, fn, 0)])
    assert len(results) == 1 and not results[0].oom_killed
    assert fn in sim.router._exec_ewma
    assert sim.router._exec_ewma[fn] > 0.0


# --------------------------------------------------- SLO-native admission
def test_slo_admission_sheds_doomed_invocation_shed_mode_admits():
    """An invocation whose best fleet-wide estimate already exceeds its
    SLO budget: admission="slo" sheds it at the front door while the
    load-headroom test (empty fleet!) happily admits it."""
    _, r_slo = _mk(admission="slo")
    _, r_shed = _mk(admission="shed", admission_headroom=0.5)
    for r in (r_slo, r_shed):
        for _ in range(ECT_SHED_OBS):  # maturely calibrated: ~100 s/run
            r.observe_exec("f", 100.0)
    rd = r_slo.route("f", ALLOC, 0.0, slo_s=1.0)
    assert rd.shed
    assert r_slo.admission_slo_shed == 1 and r_slo.admission_shed == 1
    # the headroom test sees an idle fleet and admits the doomed work
    rd = r_shed.route("f", ALLOC, 0.0, slo_s=1.0)
    assert not rd.shed and not rd.decision.queued


def test_slo_admission_admits_servable_invocation_shed_mode_drops():
    """The converse: a loaded-but-capable fleet. Load-headroom admission
    sheds servable work; the SLO test sees the fast estimate and admits."""
    clusters_slo, r_slo = _mk(admission="slo")
    clusters_shed, r_shed = _mk(admission="shed", admission_headroom=0.5)
    for clusters, r in ((clusters_slo, r_slo), (clusters_shed, r_shed)):
        r.observe_exec("f", 0.05)  # calibrated fast function
        for cl in clusters:  # every cluster at exactly the 0.5 headroom
            cl.workers[0].reserve(16, 1024)
    rd = r_shed.route("f", ALLOC, 0.0, slo_s=10.0)
    assert rd.shed  # load says overloaded, sheds servable work
    rd = r_slo.route("f", ALLOC, 0.0, slo_s=10.0)
    assert not rd.shed and not rd.decision.queued  # capacity remains
    assert r_slo.admission_slo_shed == 0


def test_slo_admission_nonpositive_budget_sheds_unconditionally():
    """A retry whose queueing already burned the whole SLO budget is
    dead work regardless of calibration state."""
    _, r = _mk(admission="slo")
    rd = r.route("uncalibrated-fn", ALLOC, 5.0, slo_s=0.0)
    assert rd.shed and r.admission_slo_shed == 1


def test_slo_admission_never_sheds_on_bare_prior():
    """No calibration yet -> always admit (the default prior must not
    shed anything)."""
    _, r = _mk(admission="slo")
    rd = r.route("never-seen-fn", ALLOC, 0.0, slo_s=1e-6)
    assert not rd.shed and r.admission_slo_shed == 0


def test_slo_admission_requires_mature_calibration():
    """Below ECT_SHED_OBS completions even a doomed-looking estimate
    admits: a few heavy first draws hold the early EWMA far above its
    steady state, and a shed is irreversible."""
    _, r = _mk(admission="slo")
    for _ in range(ECT_SHED_OBS - 1):
        r.observe_exec("f", 100.0)
    assert not r.route("f", ALLOC, 0.0, slo_s=1.0).shed  # one obs short
    r.observe_exec("f", 100.0)
    assert r.route("f", ALLOC, 0.0, slo_s=1.0).shed  # bar met -> sheds


def test_slo_admission_saturated_fleet_falls_through_to_queue():
    """An infinite estimate means nothing can be placed RIGHT NOW — not
    that the SLO is unmeetable. Fall through to normal queue/retry."""
    clusters, r = _mk(admission="slo")
    r.observe_exec("f", 0.05)
    for cl in clusters:
        for w in cl.workers:
            w.acquire(w.vcpu_limit, 0)
    rd = r.route("f", ALLOC, 0.0, slo_s=10.0)
    assert not rd.shed and rd.decision.queued


# ------------------------------------------------- per-input ECT regression
def test_regressor_learns_input_dependence():
    """After warmup the regressor must rank a large input's exec above a
    small input's — the per-input signal the EWMA cannot carry."""
    reg = ECTRegressor()
    feats = np.zeros(3)
    rng = np.random.default_rng(0)
    for _ in range(200):
        mb = float(rng.uniform(1.0, 100.0))
        # time linear in size; residual learned off the prior
        reg.observe("f", feats, mb, exec_s=0.1 * mb, prior_s=5.0)
    small = reg.predict("f", feats, 2.0, prior_s=5.0)
    large = reg.predict("f", feats, 80.0, prior_s=5.0)
    assert small is not None and large is not None
    assert large > small
    assert small < 5.0 < large  # straddles the input-blind prior


def test_regressor_warmup_abstains_and_clamps():
    reg = ECTRegressor()
    feats = np.zeros(2)
    for i in range(ECT_WARMUP_OBS - 1):
        reg.observe("f", feats, 1.0, exec_s=1.0, prior_s=1.0)
    assert reg.predict("f", feats, 1.0, prior_s=1.0) is None  # warming up
    reg.observe("f", feats, 1.0, exec_s=1.0, prior_s=1.0)
    est = reg.predict("f", feats, 1.0, prior_s=1.0)
    assert est is not None
    # clamp: predictions stay within ECT_CLAMP x of the prior
    lo = reg.predict("f", feats, 1.0, prior_s=1e-6)
    from repro.core.ect import ECT_CLAMP
    assert lo <= 1e-6 * ECT_CLAMP + 1e-18


def test_estimate_features_off_restores_ewma_estimator():
    """Router(estimate_features=False): the A/B fallback must return the
    EWMA exactly, features or not."""
    _, r = _mk(admission="none", estimate_features=False)
    feats = np.zeros(3)
    for mb, t in ((1.0, 0.1), (100.0, 10.0)) * 10:
        r.observe_exec("f", t, features=feats, input_mb=mb)
    ewma = r._exec_ewma["f"]
    assert r._exec_estimate("f", feats, 1.0) == ewma
    assert r._exec_estimate("f", feats, 100.0) == ewma
    assert r._ect.observations("f") == 0  # the regressor never trained


def test_router_per_input_estimates_diverge_with_features():
    _, r = _mk(admission="none")
    feats = np.zeros(3)
    rng = np.random.default_rng(1)
    for _ in range(100):
        mb = float(rng.uniform(1.0, 100.0))
        r.observe_exec("f", 0.1 * mb, features=feats, input_mb=mb)
    small = r._exec_estimate("f", feats, 2.0)
    large = r._exec_estimate("f", feats, 80.0)
    assert large > small  # per-input, unlike the flat EWMA
    assert r._exec_estimate("f") == r._exec_ewma["f"]  # no features -> EWMA


def test_simulator_gates_aux_features_on_config(stack):
    profiles, pool, slo_table = stack
    aux = (np.zeros(3, np.float32), 42.0)
    for flag, want in ((True, (aux[0], 42.0)), (False, (None, None))):
        cfg = SimConfig(seed=0, estimate_features=flag)
        sim = Simulator(policy=B.StaticPolicy(4, 512, "s"),
                        profiles=profiles, input_pool=pool,
                        slo_table=slo_table, cfg=cfg)
        got = sim._aux_features(aux)
        assert (got[0] is want[0]) and got[1] == want[1]
        assert sim.router.estimate_features is flag
    # non-feature aux (other policies' caches) pass through as absent
    assert sim._aux_features(None) == (None, None)
    assert sim._aux_features({"opaque": 1}) == (None, None)


# ------------------------------------------------------------------- e2e
def _overload_cfg(**overrides):
    return SimConfig(n_workers=8, n_clusters=2, routing="spill-over",
                     vcpus_per_worker=44, physical_cores=32,
                     mem_mb_per_worker=16 * 1024, vcpu_limit=44,
                     retry_interval_s=1.0, queue_timeout_s=60.0, seed=0,
                     **overrides)


def test_slo_admission_end_to_end_sheds_only_doomed_work():
    """A saturating flash crowd: admission="slo" sheds work — every
    record it sheds is a genuine SLO casualty — and beats load-headroom
    shedding on BOTH axes (fewer violations from fewer sheds)."""
    spec = ScenarioSpec(scenario="flash-crowd", rps=2.0, duration_s=180.0,
                        seed=1, params={"spike_mult": 8.0})
    slo = run_scenario("shabari", spec, sim_cfg=_overload_cfg(admission="slo"),
                       keep_results=True)
    shed = run_scenario(
        "shabari", spec,
        sim_cfg=_overload_cfg(admission="shed", admission_headroom=0.9),
        keep_results=True,
    )
    assert slo.summary["shed_pct"] > 0
    assert all(r.slo_violated for r in slo.results if r.shed)
    assert (slo.summary["slo_violation_pct"]
            < shed.summary["slo_violation_pct"])
    assert slo.summary["shed_pct"] < shed.summary["shed_pct"]
