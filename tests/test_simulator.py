"""Simulator integration tests: determinism, accounting, and the
qualitative paper results as regression guards."""

import numpy as np
import pytest

from repro.serving.experiment import run_experiment
from repro.serving.profiles import build_input_pool, build_profiles
from repro.serving.workload import generate_trace


def test_trace_deterministic_and_sorted():
    profiles = build_profiles()
    pool = build_input_pool()
    kwargs = dict(
        rps=3.0,
        functions=sorted(profiles),
        inputs_per_function={f: len(pool[f]) for f in profiles},
        duration_s=120.0,
        seed=7,
    )
    t1 = generate_trace(**kwargs)
    t2 = generate_trace(**kwargs)
    assert [(a.t, a.function, a.input_idx) for a in t1] == [
        (a.t, a.function, a.input_idx) for a in t2
    ]
    assert all(t1[i].t <= t1[i + 1].t for i in range(len(t1) - 1))
    assert abs(len(t1) - 3.0 * 120.0) < 1  # RPS honored


def test_simulation_deterministic():
    r1 = run_experiment("shabari", rps=3.0, duration_s=120.0, seed=3)
    r2 = run_experiment("shabari", rps=3.0, duration_s=120.0, seed=3)
    assert r1.summary == r2.summary


def test_all_arrivals_accounted():
    r = run_experiment("static-medium", rps=3.0, duration_s=120.0, seed=1,
                       keep_results=True)
    assert r.summary["n"] == len(r.results)
    assert abs(r.summary["n"] - 3.0 * 120.0) < 1
    for x in r.results:
        if not x.timed_out:
            assert x.finish_t >= x.start_t >= x.arrival_t - 1e-9
            assert x.used_vcpus <= x.alloc_vcpus + 1e-9
            assert x.used_mem_mb <= x.alloc_mem_mb + 1e-9


@pytest.mark.slow
def test_shabari_beats_input_agnostic_baselines_at_load():
    """Regression guard for the headline: at RPS 5-6 Shabari has fewer
    SLO violations than parrotfish/cypress AND wastes less memory than
    every baseline (paper Fig. 8)."""
    res = {
        pol: run_experiment(pol, rps=5.0, duration_s=300.0, seed=0).summary
        for pol in ("shabari", "parrotfish", "cypress", "aquatope",
                    "static-large")
    }
    s = res["shabari"]
    assert s["slo_violation_pct"] < res["parrotfish"]["slo_violation_pct"]
    assert s["slo_violation_pct"] < res["cypress"]["slo_violation_pct"]
    assert s["wasted_vcpus_p50"] == 0.0
    for pol in ("parrotfish", "cypress", "aquatope", "static-large"):
        assert s["wasted_mem_mb_p50"] < res[pol]["wasted_mem_mb_p50"]
    assert s["oom_pct"] < 1.5


@pytest.mark.slow
def test_scheduler_halves_cold_starts():
    a = run_experiment("shabari", rps=5.0, duration_s=300.0, seed=0).summary
    b = run_experiment("shabari-openwhisk-sched", rps=5.0, duration_s=300.0,
                       seed=0).summary
    assert a["cold_start_pct"] < 0.75 * b["cold_start_pct"]
