"""Acquire-on-placement reservation + router admission-control tests.

Covers the resource-lifecycle change (capacity reserved when a cold
start is PLACED, not when it starts): worker/cluster accounting,
``Worker.fits`` and ``Router._load`` seeing committed-but-warming
capacity, conversion/cancellation of reservations, the
``SimConfig(legacy_acquire=True)`` A/B (pinned against the
tests/goldens/legacy-acquire/ snapshots), and front-door admission
control (shed / queue) under fleet-wide overload.
"""

import json
import math
import os

import pytest

from repro.core.allocator import Allocation
from repro.core.cluster import Cluster
from repro.core.router import Router
from repro.core.scheduler import ShabariScheduler
from repro.serving import baselines as B
from repro.serving.experiment import make_policy, run_scenario
from repro.serving.golden import (
    ATOL,
    LEGACY_ACQUIRE_SCENARIOS,
    RTOL,
    run_golden,
)
from repro.serving.profiles import build_input_pool, build_profiles
from repro.serving.simulator import SimConfig, Simulator
from repro.serving.workload import Arrival, ScenarioSpec

LEGACY_GOLDEN_DIR = os.path.join(
    os.path.dirname(__file__), "goldens", "legacy-acquire"
)


# ------------------------------------------------- worker-level accounting
def _worker(cluster=None):
    cl = cluster or Cluster(n_workers=1, vcpus_per_worker=16,
                            mem_mb_per_worker=8192, vcpu_limit=16)
    return cl, cl.workers[0]


def test_reserve_counts_against_fits():
    _, w = _worker()
    assert w.fits(12, 1024)
    w.reserve(12, 1024)
    assert w.used_vcpus == 12 and w.reserved_vcpus == 12
    assert not w.fits(12, 1024)  # warming capacity is committed capacity
    assert w.fits(4, 1024)


def test_commit_keeps_load_until_release():
    _, w = _worker()
    w.reserve(8, 512)
    w.commit_reservation(8, 512)
    # still held — it converted to a running acquisition, not freed
    assert w.used_vcpus == 8 and w.used_mem_mb == 512
    assert w.reserved_vcpus == 0 and w.reserved_mem_mb == 0
    w.release(8, 512)
    assert w.used_vcpus == 0 and w.used_mem_mb == 0


def test_cancel_reservation_frees_capacity():
    _, w = _worker()
    w.reserve(8, 512)
    w.cancel_reservation(8, 512)
    assert w.used_vcpus == 0 and w.used_mem_mb == 0
    assert w.reserved_vcpus == 0 and w.reserved_mem_mb == 0


def test_cluster_aggregates_track_reservations():
    cl, w = _worker()
    w.reserve(8, 512)
    assert (cl.used_vcpus, cl.reserved_vcpus) == (8, 8)
    assert (cl.used_mem_mb, cl.reserved_mem_mb) == (512, 512)
    w.commit_reservation(8, 512)
    assert (cl.used_vcpus, cl.reserved_vcpus) == (8, 0)
    w.release(8, 512)
    assert (cl.used_vcpus, cl.used_mem_mb) == (0, 0)


def test_router_load_sees_reservations():
    clusters = [
        Cluster(n_workers=2, vcpus_per_worker=16, mem_mb_per_worker=8192,
                vcpu_limit=16)
        for _ in range(2)
    ]
    r = Router(clusters, [ShabariScheduler(c) for c in clusters])
    assert r._load(0) == 0.0
    clusters[0].workers[0].reserve(16, 1024)
    assert r._load(0) == pytest.approx(0.5)  # 16 of 32 vCPUs committed
    clusters[0].workers[0].cancel_reservation(16, 1024)
    assert r._load(0) == 0.0


# ------------------------------------------------------- simulator lifecycle
@pytest.fixture(scope="module")
def stack():
    profiles = build_profiles()
    pool = build_input_pool(seed=0)
    slo_table = B.build_slo_table(profiles, pool)
    return profiles, pool, slo_table


def _sim(stack, **cfg_overrides):
    profiles, pool, slo_table = stack
    cfg = SimConfig(n_workers=2, vcpus_per_worker=16, physical_cores=16,
                    mem_mb_per_worker=8 * 1024, vcpu_limit=16, seed=0,
                    **cfg_overrides)
    # static-medium: a deterministic 12-vCPU allocation, no jax dispatch
    policy = make_policy("static-medium", profiles, pool, slo_table, seed=0)
    return Simulator(policy=policy, profiles=profiles, input_pool=pool,
                     slo_table=slo_table, cfg=cfg), sorted(profiles)[0]


def test_cold_placement_reserves_immediately(stack):
    sim, fn = _sim(stack)
    sim._on_arrival(Arrival(0, 0.0, fn, 0), 0.0)
    # the invocation hasn't STARTED (container still warming), but its
    # capacity is already committed
    assert sim.cluster.used_vcpus == 12
    assert sim.cluster.reserved_vcpus == 12
    (c,) = [c for w in sim.cluster.workers for c in w.containers.values()]
    assert c.reserved and c.busy


def test_second_cold_start_not_stacked_onto_reserved_worker(stack):
    sim, fn = _sim(stack)
    sim._on_arrival(Arrival(0, 0.0, fn, 0), 0.0)
    sim._on_arrival(Arrival(1, 0.0, fn, 0), 0.0)
    workers = {c.worker.wid
               for w in sim.cluster.workers for c in w.containers.values()}
    assert len(workers) == 2  # fits() saw the reservation and spread out
    assert sim.cluster.reserved_vcpus == 24


def test_legacy_acquire_defers_to_start_and_stacks(stack):
    sim, fn = _sim(stack, legacy_acquire=True)
    sim._on_arrival(Arrival(0, 0.0, fn, 0), 0.0)
    assert sim.cluster.used_vcpus == 0  # free-looking while warming
    sim._on_arrival(Arrival(1, 0.0, fn, 0), 0.0)
    workers = {c.worker.wid
               for w in sim.cluster.workers for c in w.containers.values()}
    assert len(workers) == 1  # both cold starts herd onto the home worker


def test_reservation_converts_and_releases_through_full_run(stack):
    sim, fn = _sim(stack)
    results = sim.run([Arrival(0, 0.0, fn, 0), Arrival(1, 0.5, fn, 1)])
    assert len(results) == 2
    assert all(r.cold_start and not r.timed_out for r in results)
    assert sim.cluster.reserved_vcpus == 0 and sim.cluster.reserved_mem_mb == 0
    assert sim.cluster.used_vcpus == 0 and sim.cluster.used_mem_mb == 0


def test_reservation_released_when_cold_start_outlives_timeout(stack):
    # queue timeout shorter than any cold-start latency: the warm_start
    # event must cancel the reservation instead of running the invocation
    sim, fn = _sim(stack, queue_timeout_s=0.05)
    results = sim.run([Arrival(0, 0.0, fn, 0)])
    assert len(results) == 1 and results[0].timed_out
    assert results[0].queued_s > 0.05
    assert sim.cluster.reserved_vcpus == 0 and sim.cluster.used_vcpus == 0
    # the warmed container survives as idle warm capacity
    (c,) = [c for w in sim.cluster.workers for c in w.containers.values()]
    assert not c.busy and not c.reserved


def test_legacy_acquire_runs_late_cold_start(stack):
    # same sub-cold-latency timeout under legacy accounting: no
    # reservation exists, so the invocation still runs (the pre-change
    # semantics the A/B switch must preserve)
    sim, fn = _sim(stack, queue_timeout_s=0.05, legacy_acquire=True)
    results = sim.run([Arrival(0, 0.0, fn, 0)])
    assert len(results) == 1 and not results[0].timed_out


# --------------------------------------------------------- admission control
def _fleet(n_clusters=2, admission="shed", headroom=0.5):
    clusters = [
        Cluster(n_workers=2, vcpus_per_worker=16, mem_mb_per_worker=8192,
                vcpu_limit=16)
        for _ in range(n_clusters)
    ]
    scheds = [ShabariScheduler(c) for c in clusters]
    return clusters, Router(clusters, scheds, admission=admission,
                            admission_headroom=headroom)


def test_admission_sheds_when_every_cluster_over_headroom():
    clusters, r = _fleet()
    for cl in clusters:
        cl.workers[0].reserve(16, 1024)  # both clusters at 0.5 occupancy
    rd = r.route("f", Allocation(4, 512), 0.0)
    assert rd.shed and rd.decision.queued
    assert r.admission_shed == 1


def test_admission_admits_while_any_cluster_under_headroom():
    clusters, r = _fleet()
    clusters[0].workers[0].reserve(16, 1024)  # only one cluster loaded
    rd = r.route("f", Allocation(4, 512), 0.0)
    assert not rd.shed and not rd.decision.queued
    assert r.admission_shed == 0


def test_admission_queue_mode_holds_without_shedding():
    clusters, r = _fleet(admission="queue")
    for cl in clusters:
        cl.workers[0].reserve(16, 1024)
    rd = r.route("f", Allocation(4, 512), 0.0)
    assert not rd.shed and rd.decision.queued
    assert r.admission_queue_events == 1 and r.admission_shed == 0


def test_invalid_admission_rejected():
    clusters = [Cluster(n_workers=1)]
    with pytest.raises(AssertionError):
        Router(clusters, [ShabariScheduler(clusters[0])],
               admission="drop-everything")


def _overload_cfg(**overrides):
    return SimConfig(n_workers=2, n_clusters=2, vcpus_per_worker=16,
                     physical_cores=16, mem_mb_per_worker=8 * 1024,
                     vcpu_limit=16, retry_interval_s=1.0,
                     queue_timeout_s=30.0, seed=0, **overrides)


def test_admission_shed_end_to_end():
    spec = ScenarioSpec(scenario="oversubscribe", rps=3.0, duration_s=60.0,
                        seed=0, params={"load_mult": 3.0})
    res = run_scenario(
        "shabari", spec,
        sim_cfg=_overload_cfg(admission="shed", admission_headroom=0.5),
        keep_results=True,
    )
    assert res.summary["shed_pct"] > 0
    assert res.summary["n"] == len(res.results)
    shed = [r for r in res.results if r.shed]
    assert all(r.slo_violated and not r.timed_out for r in shed)


def test_admission_queue_end_to_end_sheds_nothing():
    spec = ScenarioSpec(scenario="oversubscribe", rps=3.0, duration_s=60.0,
                        seed=0, params={"load_mult": 3.0})
    res = run_scenario(
        "shabari", spec,
        sim_cfg=_overload_cfg(admission="queue", admission_headroom=0.5),
    )
    assert res.summary["shed_pct"] == 0.0
    assert res.summary["n"] > 0


# ----------------------------------------------------- legacy golden pinning
@pytest.mark.parametrize("scenario", LEGACY_ACQUIRE_SCENARIOS)
def test_legacy_acquire_reproduces_legacy_goldens(scenario):
    """SimConfig(legacy_acquire=True) must keep reproducing the
    pre-reservation metrics, pinned under tests/goldens/legacy-acquire/
    (regenerated alongside the main goldens by refresh_goldens.py)."""
    path = os.path.join(LEGACY_GOLDEN_DIR, f"{scenario}.json")
    assert os.path.exists(path), (
        f"missing legacy-acquire snapshot {path}; run refresh_goldens.py"
    )
    with open(path) as f:
        want = json.load(f)["summary"]
    got = run_golden(scenario, legacy_acquire=True)
    assert set(got) == set(want)
    for key, expect in want.items():
        assert math.isclose(got[key], expect, rel_tol=RTOL, abs_tol=ATOL), (
            f"legacy-acquire {scenario}.{key}: got {got[key]!r}, "
            f"golden {expect!r}"
        )
