"""End-to-end behaviour tests for the whole Shabari system."""

import numpy as np
import pytest

from repro.serving.experiment import run_experiment


def test_e2e_shabari_pipeline_runs_and_learns():
    """One full trace through featurizer -> allocator -> scheduler ->
    simulator -> daemon feedback; allocations must specialize."""
    r = run_experiment("shabari", rps=4.0, duration_s=240.0, seed=0,
                       keep_results=True)
    assert r.summary["n"] > 500
    # invocations complete and at least a few functions saw enough
    # traffic for predictions to kick in (unique container sizes > 1)
    multi = [fn for fn, n in r.container_sizes.items() if n > 1]
    assert len(multi) >= 3
    # wasted vCPUs shrink over time (learning): compare halves
    res = sorted(r.results, key=lambda x: x.arrival_t)
    half = len(res) // 2
    w1 = np.mean([x.wasted_vcpus for x in res[:half]])
    w2 = np.mean([x.wasted_vcpus for x in res[half:]])
    assert w2 < w1


def test_e2e_formulation_study_specialization():
    """Figure 6 signature: the one-hot single-model formulation cannot
    specialize per function (its allocations pin to a narrow band, 9-13
    vCPUs in the paper) while per-function agents spread out."""

    def per_fn_alloc_spread(policy):
        r = run_experiment(policy, rps=4.0, duration_s=240.0, seed=0,
                           keep_results=True)
        means = {}
        for x in r.results:
            means.setdefault(x.function, []).append(x.alloc_vcpus)
        return np.std([np.mean(v) for v in means.values()])

    spread_perfn = per_fn_alloc_spread("shabari")
    spread_onehot = per_fn_alloc_spread("shabari-one-hot")
    assert spread_perfn > spread_onehot
