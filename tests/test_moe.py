"""MoE block tests: dispatch exactness, capacity drops, aux loss."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.models.moe as MOE
from repro.configs import get_reduced_config
from repro.models.moe import init_moe, moe_block, moe_capacity, moe_decode


def _setup(E=4, K=2, D=32, F=64):
    cfg = get_reduced_config("mixtral_8x7b")
    cfg = type(cfg)(**{**cfg.__dict__, "num_experts": E, "experts_per_token": K,
                       "d_model": D, "d_ff": F})
    p = init_moe(jax.random.PRNGKey(0), cfg)
    return cfg, p


def _dense_reference(p, cfg, x):
    """Compute every expert on every token (no capacity) — ground truth."""
    T = x.shape[0] * x.shape[1]
    xt = x.reshape(T, -1).astype(jnp.float32)
    logits = xt @ p["router"]
    probs = jax.nn.softmax(logits, -1)
    vals, idx = jax.lax.top_k(probs, cfg.experts_per_token)
    vals = vals / vals.sum(-1, keepdims=True)
    outs = []
    for e in range(cfg.num_experts):
        h = jax.nn.silu(xt @ p["wg"][e].astype(jnp.float32)) * (
            xt @ p["wu"][e].astype(jnp.float32))
        outs.append(h @ p["wd"][e].astype(jnp.float32))
    outs = jnp.stack(outs, 1)  # (T, E, D)
    gate = jnp.zeros((T, cfg.num_experts))
    for j in range(cfg.experts_per_token):
        gate = gate + jax.nn.one_hot(idx[:, j], cfg.num_experts) * vals[:, j:j+1]
    y = jnp.einsum("te,ted->td", gate, outs)
    return y.reshape(x.shape)


def test_dropfree_dispatch_matches_dense_reference():
    cfg, p = _setup()
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, cfg.d_model), jnp.float32)
    y, aux = moe_block(p, cfg, x, capacity=64)  # way above demand
    ref = _dense_reference(p, cfg, x)
    assert float(jnp.max(jnp.abs(y - ref))) < 1e-4


def test_capacity_drops_tokens():
    cfg, p = _setup()
    x = jnp.broadcast_to(
        jax.random.normal(jax.random.PRNGKey(2), (1, 1, cfg.d_model)), (1, 32, cfg.d_model)
    )  # identical tokens -> all route to the same experts
    y_tight, _ = moe_block(p, cfg, x, capacity=8)
    # tokens beyond slot 8 were dropped -> zero output rows exist
    norms = jnp.linalg.norm(y_tight[0], axis=-1)
    assert float(jnp.min(norms)) == 0.0
    assert float(jnp.max(norms)) > 0.0  # first tokens survived


def test_top1_priority_over_top2_on_overflow():
    cfg, p = _setup()
    x = jnp.broadcast_to(
        jax.random.normal(jax.random.PRNGKey(3), (1, 1, cfg.d_model)), (1, 8, cfg.d_model)
    )
    # capacity 8 = exactly the top-1 demand; all top-1 kept, top-2 dropped
    y, _ = moe_block(p, cfg, x, capacity=8)
    norms = jnp.linalg.norm(y[0], axis=-1)
    assert float(jnp.min(norms)) > 0.0  # every token kept its top-1 expert


def test_aux_loss_bounds():
    cfg, p = _setup()
    x = jax.random.normal(jax.random.PRNGKey(4), (2, 64, cfg.d_model))
    _, aux = moe_block(p, cfg, x)
    # Switch LB loss: 1 (balanced) .. E (collapsed)
    assert 0.9 <= float(aux) <= cfg.num_experts + 1e-3


def test_moe_decode_matches_block():
    cfg, p = _setup()
    x = jax.random.normal(jax.random.PRNGKey(5), (4, cfg.d_model))
    y1 = moe_decode(p, cfg, x)
    y2, _ = moe_block(p, cfg, x[:, None, :])
    assert float(jnp.max(jnp.abs(y1 - y2[:, 0]))) < 1e-5


def test_capacity_rounding():
    cfg, _ = _setup()
    assert moe_capacity(cfg, 1024) % 8 == 0
    assert moe_capacity(cfg, 1) == 8
