"""Golden-metrics regression harness.

Each registered scenario has a tiny fixed-seed run whose ``summarize()``
output is snapshotted in tests/goldens/<scenario>.json. A behavioral
change anywhere in the workload -> allocator -> scheduler -> simulator
stack shows up as a golden diff here. Refresh intentionally with
``PYTHONPATH=src python scripts/refresh_goldens.py`` and commit the
result.
"""

import json
import math
import os

import pytest

from repro.serving.golden import ATOL, RTOL, golden_specs, run_golden
from repro.serving.workload import list_scenarios

GOLDEN_DIR = os.path.join(os.path.dirname(__file__), "goldens")


def _load(scenario):
    path = os.path.join(GOLDEN_DIR, f"{scenario}.json")
    assert os.path.exists(path), (
        f"missing golden snapshot {path}; run scripts/refresh_goldens.py"
    )
    with open(path) as f:
        return json.load(f)


def test_registry_fully_snapshotted():
    """Every registered scenario has a committed snapshot, and vice
    versa — adding a scenario without a golden (or orphaning one) fails."""
    assert len(list_scenarios()) >= 7
    on_disk = {f[:-5] for f in os.listdir(GOLDEN_DIR) if f.endswith(".json")}
    assert on_disk == set(list_scenarios())


@pytest.mark.parametrize("scenario", list_scenarios())
def test_golden_metrics(scenario):
    golden = _load(scenario)
    spec = golden_specs()[scenario]
    import dataclasses
    assert golden["spec"] == dataclasses.asdict(spec), (
        "golden was generated from a different spec; refresh goldens"
    )
    got = run_golden(scenario)
    want = golden["summary"]
    assert set(got) == set(want)
    for key, expect in want.items():
        actual = got[key]
        assert math.isclose(actual, expect, rel_tol=RTOL, abs_tol=ATOL), (
            f"{scenario}.{key}: got {actual!r}, golden {expect!r}"
        )
