"""Per-architecture smoke tests (deliverable f).

For each of the 10 assigned architectures: instantiate the REDUCED
variant of the same family (<=2 layers, d_model<=512, <=4 experts), run
one forward and one train step on CPU, assert output shapes and no
NaNs. The FULL configs are exercised only via the dry-run.
"""

import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCH_IDS, get_config, get_reduced_config, SHAPES, input_specs, shape_applicable
from repro.models.model import (
    count_params,
    count_params_analytic,
    forward_prefill,
    forward_decode,
    forward_train,
    init_params,
)


def _batch_kwargs(cfg, B, S, key):
    kwargs = {}
    if cfg.family == "vlm":
        kwargs["patch_embeds"] = 0.1 * jnp.ones((B, cfg.frontend_tokens, cfg.d_model), cfg.dtype)
    if cfg.is_encoder_decoder:
        kwargs["frame_embeds"] = 0.1 * jnp.ones((B, cfg.encoder_seq, cfg.d_model), cfg.dtype)
    return kwargs


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_reduced_forward_and_train_step(arch):
    cfg = get_reduced_config(arch)
    assert cfg.num_layers <= 2 and cfg.d_model <= 512 and cfg.num_experts <= 4
    key = jax.random.PRNGKey(0)
    params = init_params(key, cfg)
    B, S = 2, 64
    if cfg.family in ("ssm", "hybrid"):
        S = cfg.ssm_chunk
    tokens = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    labels = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    kwargs = _batch_kwargs(cfg, B, S, key)

    loss, metrics = forward_train(params, cfg, tokens, labels, remat=False, **kwargs)
    assert loss.shape == ()
    assert jnp.isfinite(loss), (arch, loss)

    # one SGD-free grad step sanity: grads finite
    g = jax.grad(lambda p: forward_train(p, cfg, tokens, labels, remat=False, **kwargs)[0])(params)
    gnorm = sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree_util.tree_leaves(g))
    assert jnp.isfinite(gnorm), arch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_reduced_prefill_decode_shapes(arch):
    cfg = get_reduced_config(arch)
    key = jax.random.PRNGKey(1)
    params = init_params(key, cfg)
    B, S = 2, 32
    if cfg.family in ("ssm", "hybrid"):
        S = cfg.ssm_chunk
    tokens = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    kwargs = _batch_kwargs(cfg, B, S, key)
    W = S + cfg.frontend_tokens + 8
    logits, cache = forward_prefill(params, cfg, tokens, cache_window=W, **kwargs)
    assert logits.shape == (B, cfg.vocab_size)
    assert jnp.all(jnp.isfinite(logits.astype(jnp.float32))), arch
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    logits2, cache2 = forward_decode(params, cfg, tok, cache)
    assert logits2.shape == (B, cfg.vocab_size)
    assert jnp.all(jnp.isfinite(logits2.astype(jnp.float32))), arch
    assert int(cache2["pos"][0]) == int(cache["pos"][0]) + 1


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_param_count_matches_analytic(arch):
    cfg = get_reduced_config(arch)
    params = init_params(jax.random.PRNGKey(0), cfg)
    assert count_params(params) == count_params_analytic(cfg)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_full_config_specs(arch):
    """Full configs: exact assigned dims + ShapeDtypeStruct specs only."""
    cfg = get_config(arch)
    cfg.validate()
    for shape in SHAPES.values():
        if not shape_applicable(cfg, shape):
            assert arch == "whisper_tiny" and shape.name == "long_500k"
            continue
        specs = input_specs(cfg, shape)
        for leaf in jax.tree_util.tree_leaves(specs):
            assert isinstance(leaf, jax.ShapeDtypeStruct)
        if shape.kind == "train":
            assert specs["tokens"].shape[0] == shape.global_batch
        if shape.kind == "decode":
            assert specs["token"].shape == (shape.global_batch,)
            assert "cache" in specs
