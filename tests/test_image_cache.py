"""Locality-aware cold starts (repro.core.image_cache).

Four layers of coverage:

* NodeImageCache units — LRU eviction order, pinned and in-use layers
  exempt, hit/miss/evict counters, registry pull pricing;
* the catalog contract — clone aliases (fn::k) share every layer of
  their base function's image except the tiny per-alias config layer,
  so one alias's pull warms its siblings;
* scheduler/router integration — cache-affinity cold placement prefers
  the worker with the smallest residual pull (degenerating to the plain
  walk on a free registry), the runtime pulls ONLY on container
  creation (the warm path never touches the registry), and the
  cache-disabled A/B snapshot under tests/goldens/cache-disabled/ pins
  the flat-constant cold model on the registry-storm trace;
* the estimator/runtime jitter contract — the router prices the cold
  curve times E[lognormal jitter] (COLD_JITTER_MEAN), and the
  simulator's draws average to exactly that, so the two can't silently
  diverge.
"""

import dataclasses
import json
import math
import os

import numpy as np
import pytest

from repro.core.allocator import Allocation
from repro.core.cluster import Cluster
from repro.core.fleet import (
    COLD_JITTER_MEAN,
    COLD_JITTER_SIGMA,
    ClusterSpec,
    FleetSpec,
    MachineType,
)
from repro.core.image_cache import (
    ALIAS_LAYER_MB,
    BASE_LAYERS,
    ImageCacheSpec,
    ImageSpec,
    NodeImageCache,
    default_images,
)
from repro.core.scheduler import ShabariScheduler
from repro.serving import baselines as B
from repro.serving.experiment import make_policy, run_scenario
from repro.serving.golden import (
    ATOL,
    CACHE_DISABLED_SCENARIOS,
    RTOL,
    run_golden,
)
from repro.serving.profiles import build_input_pool, build_profiles
from repro.serving.simulator import SimConfig, Simulator
from repro.serving.workload import Arrival, ScenarioSpec

ALLOC = Allocation(vcpus=4, mem_mb=2048)


def _img(name, *layers):
    return ImageSpec(name=name, layers=tuple(layers))


# ------------------------------------------------------ cache units
def test_pull_charges_only_missing_bytes():
    cache = NodeImageCache(store_mb=10_000, registry_gbps=1.0)
    a = _img("a", ("base", 500.0), ("app-a", 250.0))
    b = _img("b", ("base", 500.0), ("app-b", 125.0))
    # 750 MB over 1 Gbps = 6 s
    assert cache.pull(a) == pytest.approx(750.0 * 0.008)
    # base already resident: b pays only its 125 MB app layer
    assert cache.missing_mb(b) == pytest.approx(125.0)
    assert cache.pull(b) == pytest.approx(125.0 * 0.008)
    # full hit: free
    assert cache.pull(a) == 0.0
    assert cache.hits == 3 and cache.misses == 3
    assert cache.used_mb == pytest.approx(875.0)


def test_lru_evicts_oldest_idle_layer_first():
    cache = NodeImageCache(store_mb=1000, registry_gbps=10.0)
    a = _img("a", ("la", 400.0))
    b = _img("b", ("lb", 400.0))
    c = _img("c", ("lc", 400.0))
    cache.pull(a)
    cache.pull(b)
    cache.release("a")
    cache.release("b")
    cache.pull(a)  # refresh a's recency, then idle it again
    cache.release("a")
    cache.pull(c)  # needs 400 MB; store holds 800/1000 -> evict LRU = lb
    assert not cache.resident("lb")
    assert cache.resident("la") and cache.resident("lc")
    assert cache.evictions == 1


def test_pinned_and_in_use_layers_are_eviction_exempt():
    cache = NodeImageCache(store_mb=1000, registry_gbps=10.0,
                           pinned=("pin",))
    cache.pull(_img("p", ("pin", 300.0)))
    cache.release("p")  # idle AND oldest, but pinned
    busy = _img("busy", ("lb", 300.0))
    cache.pull(busy)  # stays referenced: in-use
    cache.pull(_img("idle", ("li", 300.0)))
    cache.release("idle")
    # 900/1000 used; a 300 MB pull must skip pinned + in-use and evict
    # the idle unpinned layer only
    cache.pull(_img("new", ("ln", 300.0)))
    assert cache.resident("pin") and cache.resident("lb")
    assert not cache.resident("li")
    assert cache.evictions == 1


def test_overflow_when_nothing_evictable():
    cache = NodeImageCache(store_mb=500, registry_gbps=10.0)
    cache.pull(_img("a", ("la", 400.0)))  # in-use, never released
    cache.pull(_img("b", ("lb", 400.0)))  # cannot fit, cannot evict
    # the pull proceeds anyway (a fetch in flight can't be refused) and
    # the store overflows until references drop
    assert cache.resident("la") and cache.resident("lb")
    assert cache.used_mb == pytest.approx(800.0)
    assert cache.evictions == 0


def test_release_makes_layers_evictable_per_refcount():
    cache = NodeImageCache(store_mb=500, registry_gbps=10.0)
    a = _img("a", ("la", 400.0))
    cache.pull(a)
    cache.pull(a)  # two containers share the layers
    cache.release("a")
    cache.pull(_img("b", ("lb", 400.0)))  # la still referenced once
    assert cache.resident("la")
    cache.release("a")
    cache.release("b")
    cache.pull(_img("c", ("lc", 400.0)))
    assert not cache.resident("la")  # now idle -> LRU victim


def test_free_registry_prices_zero():
    cache = NodeImageCache(store_mb=1000, registry_gbps=float("inf"))
    a = _img("a", ("la", 400.0))
    assert cache.residual_pull_s(a) == 0.0
    assert cache.pull(a) == 0.0


# ------------------------------------------------- catalog contract
def test_clone_aliases_share_base_layers():
    cat = default_images(["fn", "fn::1", "fn::2", "other"])
    base = set(cat["fn"].digests)
    alias = set(cat["fn::1"].digests)
    # the alias stacks exactly one extra (tiny) layer on its base image
    assert base < alias and len(alias - base) == 1
    # distinct base functions share ONLY the universal OS/runtime base
    assert set(cat["fn"].digests) & set(cat["other"].digests) == {
        d for d, _ in BASE_LAYERS}


def test_alias_pull_warms_siblings():
    cat = default_images(["fn::0", "fn::1"])
    cache = NodeImageCache(store_mb=100_000, registry_gbps=1.0)
    cache.pull(cat["fn::0"])
    # the sibling misses only its own 2 MB alias layer
    assert cache.missing_mb(cat["fn::1"]) == pytest.approx(ALIAS_LAYER_MB)
    assert cache.residual_pull_s(cat["fn::1"]) == pytest.approx(
        ALIAS_LAYER_MB * 0.008)


# ------------------------------------------- scheduler cache-affinity
def _affinity_cluster(registry_gbps=2.0):
    machine = MachineType(physical_cores=32, vcpus=32, mem_mb=16 * 1024,
                          registry_gbps=registry_gbps)
    cluster = Cluster(n_workers=2, vcpus_per_worker=32,
                      mem_mb_per_worker=16 * 1024, vcpu_limit=32,
                      machines=(machine, machine))
    cat = default_images(["f"])
    for w in cluster.workers:
        w.image_cache = NodeImageCache(100_000, registry_gbps)
    sched = ShabariScheduler(cluster, image_resolver=cat.__getitem__)
    return cluster, sched, cat


def test_affinity_prefers_layer_resident_worker():
    cluster, sched, cat = _affinity_cluster()
    home = sched._home_worker("f")
    other = cluster.workers[1 - home]
    other.image_cache.pull(cat["f"])
    # walk order would pick the home worker; affinity overrides it
    # because the other worker already holds every layer
    assert sched._pick_cold_worker("f", 4, 2048) is other


def test_affinity_crowded_resident_worker_priced_as_cold():
    cluster, sched, cat = _affinity_cluster()
    home = sched._home_worker("f")
    other = cluster.workers[1 - home]
    other.image_cache.pull(cat["f"])
    # saturate the resident worker past CROWD_FRAC: its stranded warm
    # pool would be unusable, so the rank must fall back to the walk
    # choice even though every layer sits on `other`
    other.acquire(28, 4096)
    assert sched._pick_cold_worker("f", 4, 2048) is cluster.workers[home]
    # below the crowding threshold locality wins again
    other.release(28, 4096)
    assert sched._pick_cold_worker("f", 4, 2048) is other


def test_affinity_free_registry_degenerates_to_walk_order():
    cluster, sched, cat = _affinity_cluster(registry_gbps=float("inf"))
    home = sched._home_worker("f")
    other = cluster.workers[1 - home]
    other.image_cache.pull(cat["f"])
    # zero pull cost everywhere -> pure walk order, exactly the plain
    # (cache-blind) pick
    assert sched._pick_cold_worker("f", 4, 2048) is cluster.workers[home]


# ------------------------------------------------ simulator integration
def _cache_cfg(**kw):
    return SimConfig(
        n_workers=4, vcpus_per_worker=32, physical_cores=32,
        mem_mb_per_worker=16 * 1024, vcpu_limit=32, seed=0,
        image_cache=ImageCacheSpec(), **kw)


def _run_registry_storm(cfg, duration_s=40.0):
    spec = ScenarioSpec(scenario="registry-storm", rps=2.0,
                        duration_s=duration_s, seed=1)
    return run_scenario("shabari", spec, sim_cfg=cfg, keep_results=True)


def test_disabled_path_attaches_nothing():
    profiles = build_profiles()
    pool = build_input_pool(seed=0)
    slo = B.build_slo_table(profiles, pool)
    policy = make_policy("shabari", profiles, pool, slo, seed=0)
    sim = Simulator(policy=policy, profiles=profiles, input_pool=pool,
                    slo_table=slo, cfg=SimConfig(n_workers=2))
    assert not sim._image_cache_active and sim._images is None
    for w in sim.cluster.workers:
        assert w.image_cache is None
    assert sim.scheduler.image_resolver is None
    assert sim.router.image_resolver is None


def test_warm_path_never_pulls(monkeypatch):
    """The registry is touched exactly once per container CREATION —
    warm hits, retries, and queue waits never pull."""
    pulls = []
    creations = []
    real_pull = NodeImageCache.pull
    real_new = Cluster.new_container

    def spy_pull(self, image):
        pulls.append(image.name)
        return real_pull(self, image)

    def spy_new(self, *a, **kw):
        c = real_new(self, *a, **kw)
        creations.append(c.function)
        return c

    monkeypatch.setattr(NodeImageCache, "pull", spy_pull)
    monkeypatch.setattr(Cluster, "new_container", spy_new)
    out = _run_registry_storm(_cache_cfg())
    warm_hits = sum(1 for r in out.results
                    if not r.cold_start and not r.shed and not r.timed_out)
    assert warm_hits > 0  # the trace actually exercised the warm path
    assert len(pulls) == len(creations) > 0
    assert pulls == creations  # one pull per creation, in order


def test_cold_latency_includes_residual_pull():
    """With a punishingly slow registry, observed cold latencies exceed
    the classic jittered curve — the pull dominates the overlap."""
    machine = MachineType(physical_cores=32, vcpus=32, mem_mb=16 * 1024,
                          registry_gbps=0.25)
    fleet = FleetSpec(clusters=(ClusterSpec(machines=((machine, 4),)),))
    out = _run_registry_storm(_cache_cfg(fleet=fleet))
    colds = [r for r in out.results if r.cold_start]
    assert colds
    # classic curve ceiling: cold_base + per_gb * 16 GB, jitter < 2x
    ceiling = 2.0 * (0.45 + 0.12 * 16.0)
    assert max(c.cold_latency_s for c in colds) > ceiling


def test_cache_disabled_snapshot_pinned():
    """The flat-constant A/B arm stays independently regression-pinned
    under tests/goldens/cache-disabled/ (regenerated alongside the main
    goldens by refresh_goldens.py)."""
    for scenario in CACHE_DISABLED_SCENARIOS:
        path = os.path.join(os.path.dirname(__file__), "goldens",
                            "cache-disabled", f"{scenario}.json")
        assert os.path.exists(path), (
            f"missing cache-disabled snapshot {path}; run "
            "PYTHONPATH=src python scripts/refresh_goldens.py")
        with open(path) as f:
            want = json.load(f)["summary"]
        got = run_golden(scenario, cache_disabled=True)
        assert set(got) == set(want)
        for k, v in want.items():
            assert got[k] == pytest.approx(v, rel=RTOL, abs=ATOL), (
                f"{scenario}[cache-disabled] {k}: {got[k]} != {v}")


# ------------------------------------- estimator/runtime jitter pin
def test_cold_jitter_mean_is_lognormal_expectation():
    assert COLD_JITTER_MEAN == pytest.approx(
        math.exp(0.5 * COLD_JITTER_SIGMA ** 2))


def test_simulator_draws_average_to_priced_expectation():
    """The runtime's jittered cold_latency draws converge on the value
    the router prices (cold curve x COLD_JITTER_MEAN) — the two sides
    of the satellite-2 contract."""
    profiles = build_profiles()
    pool = build_input_pool(seed=0)
    slo = B.build_slo_table(profiles, pool)
    policy = make_policy("shabari", profiles, pool, slo, seed=0)
    sim = Simulator(policy=policy, profiles=profiles, input_pool=pool,
                    slo_table=slo, cfg=SimConfig(n_workers=1, seed=3))
    m = sim.cluster.workers[0].machine
    draws = np.array([sim.cold_latency(ALLOC.vcpus, ALLOC.mem_mb, m)
                      for _ in range(20000)])
    assert draws.mean() == pytest.approx(
        m.cold_latency_s(ALLOC.mem_mb) * COLD_JITTER_MEAN, rel=5e-3)


def test_router_estimate_prices_residual_pull():
    """Estimate mode sees 'far-but-layers-resident': the cold estimate
    rises by the candidate's residual pull when it dominates the
    classic curve, and affinity placement steers to the warmed node."""
    from repro.core.router import Router
    machine = MachineType(physical_cores=32, vcpus=32, mem_mb=16 * 1024,
                          registry_gbps=0.5)
    cluster = Cluster(n_workers=1, vcpus_per_worker=32,
                      mem_mb_per_worker=16 * 1024, vcpu_limit=32,
                      machines=(machine,))
    cat = default_images(["f"])
    w = cluster.workers[0]
    w.image_cache = NodeImageCache(100_000, 0.5)
    sched = ShabariScheduler(cluster, image_resolver=cat.__getitem__)
    r = Router([cluster], [sched], routing="estimate",
               image_resolver=cat.__getitem__)
    est_cold_cache, kind, _ = r._estimate(0, "f", ALLOC, 0.0)
    assert kind == "cold"
    blind = Router([cluster], [ShabariScheduler(cluster)],
                   routing="estimate")
    est_blind, _, _ = blind._estimate(0, "f", ALLOC, 0.0)
    pull = w.image_cache.residual_pull_s(cat["f"])
    classic = machine.cold_latency_s(ALLOC.mem_mb) * COLD_JITTER_MEAN
    assert pull > classic  # 0.5 Gbps: the pull dominates
    assert est_cold_cache - est_blind == pytest.approx(pull - classic)
    # once the layers are resident the cache-aware estimate collapses
    # back to the classic priced curve
    w.image_cache.pull(cat["f"])
    est_warm_cache, _, _ = r._estimate(0, "f", ALLOC, 0.0)
    assert est_warm_cache == pytest.approx(est_blind)
