"""Front-door router tests: home-cluster affinity, cold-start-aware
spill-over, routing-policy behavior, and end-to-end determinism."""

import pytest

from repro.core.allocator import Allocation
from repro.core.cluster import Cluster
from repro.core.router import Router
from repro.core.scheduler import ShabariScheduler
from repro.serving.experiment import run_scenario
from repro.serving.simulator import SimConfig
from repro.serving.workload import ScenarioSpec

ALLOC = Allocation(4, 512)


def _mk(n_clusters=2, routing="spill-over", n_workers=2, seed=0):
    clusters = [
        Cluster(n_workers=n_workers, vcpus_per_worker=16,
                mem_mb_per_worker=8192, vcpu_limit=16)
        for _ in range(n_clusters)
    ]
    scheds = [ShabariScheduler(c) for c in clusters]
    return clusters, Router(clusters, scheds, routing=routing, seed=seed)


def _saturate(cluster):
    for w in cluster.workers:
        w.acquire(w.vcpu_limit, 0)


# ------------------------------------------------------------- affinity
def test_home_cluster_affinity():
    clusters, r = _mk()
    home = r.home_cluster("f")
    rd = r.route("f", ALLOC, 0.0)
    assert rd.cluster_idx == home and not rd.spilled
    assert rd.decision.cold_start
    # the hash is a pure function of the name
    assert r.home_cluster("f") == home


def test_no_spill_while_home_has_headroom():
    clusters, r = _mk()
    home = r.home_cluster("f")
    # home is loaded (but fits) and the remote is empty: locality wins
    clusters[home].workers[0].acquire(12, 0)
    rd = r.route("f", ALLOC, 0.0)
    assert rd.cluster_idx == home and not rd.spilled


def test_home_warm_container_preferred_over_remote_warm():
    clusters, r = _mk()
    home = r.home_cluster("f")
    remote = 1 - home
    c_home = clusters[home].new_container(
        clusters[home].workers[0], "f", 4, 512, now=0.0, warm_at=0.0)
    clusters[remote].new_container(
        clusters[remote].workers[0], "f", 4, 512, now=0.0, warm_at=0.0)
    rd = r.route("f", ALLOC, 1.0)
    assert rd.cluster_idx == home and rd.decision.container is c_home


# ------------------------------------------------------------ spill-over
def test_remote_warm_beats_local_cold_start():
    clusters, r = _mk()
    home = r.home_cluster("f")
    remote = 1 - home
    c = clusters[remote].new_container(
        clusters[remote].workers[0], "f", 4, 512, now=0.0, warm_at=0.0)
    # home has capacity but is busier than the remote and would
    # cold-start; the warm container on the lighter remote wins
    clusters[home].workers[0].acquire(8, 0)
    rd = r.route("f", ALLOC, 1.0)
    assert rd.spilled and rd.cluster_idx == remote
    assert rd.decision.container is c and not rd.decision.cold_start
    assert r.spills_warm == 1


def test_idle_home_prefers_local_pool_over_remote_warm():
    """An idle home cluster cold-starts locally even when a remote has a
    warm container: spilling without load pressure would smear the
    function's warm pool across clusters."""
    clusters, r = _mk()
    home = r.home_cluster("f")
    remote = 1 - home
    clusters[remote].new_container(
        clusters[remote].workers[0], "f", 4, 512, now=0.0, warm_at=0.0)
    rd = r.route("f", ALLOC, 1.0)
    assert rd.cluster_idx == home and not rd.spilled
    assert rd.decision.cold_start


def test_spill_over_picks_least_loaded_remote_when_home_saturated():
    clusters, r = _mk(n_clusters=3)
    home = r.home_cluster("f")
    _saturate(clusters[home])
    remotes = [ci for ci in range(3) if ci != home]
    clusters[remotes[0]].workers[0].acquire(12, 0)  # more loaded remote
    rd = r.route("f", ALLOC, 0.0)
    assert rd.spilled and rd.cluster_idx == remotes[1]
    assert rd.decision.cold_start and not rd.decision.queued
    assert r.spills_cold == 1


def test_no_spill_without_saturation_or_remote_warm():
    clusters, r = _mk(n_clusters=3)
    home = r.home_cluster("f")
    rd = r.route("f", ALLOC, 0.0)  # everything empty -> home cold start
    assert rd.cluster_idx == home and not rd.spilled
    assert r.routed_home == 1 and r.spills_warm == 0 and r.spills_cold == 0


def test_cold_spill_counter_attribution():
    """A saturated home spilling onto a remote that serves a WARM
    container counts as a warm spill, not a cold one — even when the
    remote's load kept it out of the load-guarded warm pass."""
    clusters, r = _mk(n_clusters=2)
    home = r.home_cluster("f")
    remote = 1 - home
    _saturate(clusters[home])
    # remote busier than home (load guard skips it) but holding a warm
    # container on a worker with headroom
    clusters[remote].workers[0].acquire(16, 0)
    c = clusters[remote].new_container(
        clusters[remote].workers[1], "f", 4, 512, now=0.0, warm_at=0.0)
    rd = r.route("f", ALLOC, 1.0)
    assert rd.spilled and rd.decision.container is c
    assert r.spills_warm == 1 and r.spills_cold == 0


def test_queued_only_when_every_cluster_saturated():
    clusters, r = _mk(n_clusters=2)
    for cl in clusters:
        _saturate(cl)
    rd = r.route("f", ALLOC, 0.0)
    assert rd.decision.queued
    assert rd.cluster_idx == r.home_cluster("f")
    # counters record placements only — a queued attempt is not a route
    assert r.routed_home == r.spills_warm == r.spills_cold == 0


# ------------------------------------------------------- other routings
def test_hashing_routing_pins_home_even_when_saturated():
    clusters, r = _mk(routing="hashing")
    home = r.home_cluster("f")
    _saturate(clusters[home])
    rd = r.route("f", ALLOC, 0.0)
    assert rd.cluster_idx == home and rd.decision.queued


def test_random_routing_deterministic_under_fixed_seed():
    _, r1 = _mk(n_clusters=4, routing="random", seed=7)
    _, r2 = _mk(n_clusters=4, routing="random", seed=7)
    picks1 = [r1.route(f"f{i}", ALLOC, 0.0).cluster_idx for i in range(32)]
    picks2 = [r2.route(f"f{i}", ALLOC, 0.0).cluster_idx for i in range(32)]
    assert picks1 == picks2
    assert len(set(picks1)) > 1  # actually spreads load
    # counters account for every (non-queued) random placement too
    assert r1.routed_home + r1.spills_warm + r1.spills_cold == 32
    assert r1.spills_cold > 0  # ~3/4 of uniform picks land off-home


def test_single_cluster_router_is_transparent():
    clusters, r = _mk(n_clusters=1)
    rd = r.route("f", ALLOC, 0.0)
    assert rd.cluster_idx == 0 and not rd.spilled


def test_invalid_routing_rejected():
    clusters = [Cluster(n_workers=1)]
    scheds = [ShabariScheduler(clusters[0])]
    with pytest.raises(AssertionError):
        Router(clusters, scheds, routing="round-robin")


# ------------------------------------------------------------ end-to-end
MULTI_CFG = dict(
    n_workers=2, n_clusters=2, vcpus_per_worker=32, physical_cores=32,
    mem_mb_per_worker=16 * 1024, vcpu_limit=32, seed=0,
    retry_interval_s=1.0, queue_timeout_s=45.0,
)


def test_multi_cluster_simulation_deterministic_and_accounted():
    spec = ScenarioSpec(scenario="multi-cluster", rps=2.0, duration_s=90.0,
                        seed=5)
    r1 = run_scenario("shabari", spec, sim_cfg=SimConfig(**MULTI_CFG),
                      keep_results=True)
    r2 = run_scenario("shabari", spec, sim_cfg=SimConfig(**MULTI_CFG))
    assert r1.summary == r2.summary
    assert r1.summary["n"] == len(r1.results)
    for x in r1.results:
        if not x.timed_out:
            assert x.finish_t >= x.start_t >= x.arrival_t - 1e-9


@pytest.mark.parametrize("routing", ["hashing", "spill-over", "random"])
def test_routing_policies_run_and_account_all_arrivals(routing):
    spec = ScenarioSpec(scenario="multi-cluster", rps=2.0, duration_s=60.0,
                        seed=3)
    cfg = SimConfig(**{**MULTI_CFG, "routing": routing})
    res = run_scenario("shabari", spec, sim_cfg=cfg, keep_results=True)
    assert res.summary["n"] == len(res.results) > 0
