"""Front-door router tests: home-cluster affinity, cold-start-aware
spill-over, completion-time-estimate routing (warming-soon visibility,
calibration, golden pin), routing-policy behavior, and end-to-end
determinism."""

import json
import math
import os

import pytest

from repro.core.allocator import Allocation
from repro.core.cluster import Cluster
from repro.core.fleet import COLD_JITTER_MEAN, MachineType
from repro.core.router import DEFAULT_EXEC_ESTIMATE_S, Router
from repro.core.scheduler import ShabariScheduler
from repro.serving.experiment import run_scenario
from repro.serving.simulator import SimConfig
from repro.serving.workload import ScenarioSpec

ALLOC = Allocation(4, 512)


def _mk(n_clusters=2, routing="spill-over", n_workers=2, seed=0,
        physical_cores=None, **kwargs):
    # hardware now rides on each worker's MachineType (repro.core.fleet)
    # rather than Router constructor constants
    machines = None
    if physical_cores is not None:
        machines = [MachineType(physical_cores=physical_cores, vcpus=16,
                                mem_mb=8192)] * n_workers
    clusters = [
        Cluster(n_workers=n_workers, vcpus_per_worker=16,
                mem_mb_per_worker=8192, vcpu_limit=16, machines=machines)
        for _ in range(n_clusters)
    ]
    scheds = [ShabariScheduler(c) for c in clusters]
    return clusters, Router(clusters, scheds, routing=routing, seed=seed,
                            **kwargs)


def _cold_estimate(clusters, alloc):
    """Mean-field cold-start latency on these (uniform) test fleets —
    the per-machine curve scaled by the lognormal jitter's expectation,
    exactly what the router prices."""
    return (clusters[0].workers[0].machine.cold_latency_s(alloc.mem_mb)
            * COLD_JITTER_MEAN)


def _saturate(cluster):
    for w in cluster.workers:
        w.acquire(w.vcpu_limit, 0)


# ------------------------------------------------------------- affinity
def test_home_cluster_affinity():
    clusters, r = _mk()
    home = r.home_cluster("f")
    rd = r.route("f", ALLOC, 0.0)
    assert rd.cluster_idx == home and not rd.spilled
    assert rd.decision.cold_start
    # the hash is a pure function of the name
    assert r.home_cluster("f") == home


def test_no_spill_while_home_has_headroom():
    clusters, r = _mk()
    home = r.home_cluster("f")
    # home is loaded (but fits) and the remote is empty: locality wins
    clusters[home].workers[0].acquire(12, 0)
    rd = r.route("f", ALLOC, 0.0)
    assert rd.cluster_idx == home and not rd.spilled


def test_home_warm_container_preferred_over_remote_warm():
    clusters, r = _mk()
    home = r.home_cluster("f")
    remote = 1 - home
    c_home = clusters[home].new_container(
        clusters[home].workers[0], "f", 4, 512, now=0.0, warm_at=0.0)
    clusters[remote].new_container(
        clusters[remote].workers[0], "f", 4, 512, now=0.0, warm_at=0.0)
    rd = r.route("f", ALLOC, 1.0)
    assert rd.cluster_idx == home and rd.decision.container is c_home


# ------------------------------------------------------------ spill-over
def test_remote_warm_beats_local_cold_start():
    clusters, r = _mk()
    home = r.home_cluster("f")
    remote = 1 - home
    c = clusters[remote].new_container(
        clusters[remote].workers[0], "f", 4, 512, now=0.0, warm_at=0.0)
    # home has capacity but is busier than the remote and would
    # cold-start; the warm container on the lighter remote wins
    clusters[home].workers[0].acquire(8, 0)
    rd = r.route("f", ALLOC, 1.0)
    assert rd.spilled and rd.cluster_idx == remote
    assert rd.decision.container is c and not rd.decision.cold_start
    assert r.spills_warm == 1


def test_idle_home_prefers_local_pool_over_remote_warm():
    """An idle home cluster cold-starts locally even when a remote has a
    warm container: spilling without load pressure would smear the
    function's warm pool across clusters."""
    clusters, r = _mk()
    home = r.home_cluster("f")
    remote = 1 - home
    clusters[remote].new_container(
        clusters[remote].workers[0], "f", 4, 512, now=0.0, warm_at=0.0)
    rd = r.route("f", ALLOC, 1.0)
    assert rd.cluster_idx == home and not rd.spilled
    assert rd.decision.cold_start


def test_spill_over_picks_least_loaded_remote_when_home_saturated():
    clusters, r = _mk(n_clusters=3)
    home = r.home_cluster("f")
    _saturate(clusters[home])
    remotes = [ci for ci in range(3) if ci != home]
    clusters[remotes[0]].workers[0].acquire(12, 0)  # more loaded remote
    rd = r.route("f", ALLOC, 0.0)
    assert rd.spilled and rd.cluster_idx == remotes[1]
    assert rd.decision.cold_start and not rd.decision.queued
    assert r.spills_cold == 1


def test_no_spill_without_saturation_or_remote_warm():
    clusters, r = _mk(n_clusters=3)
    home = r.home_cluster("f")
    rd = r.route("f", ALLOC, 0.0)  # everything empty -> home cold start
    assert rd.cluster_idx == home and not rd.spilled
    assert r.routed_home == 1 and r.spills_warm == 0 and r.spills_cold == 0


def test_cold_spill_counter_attribution():
    """A saturated home spilling onto a remote that serves a WARM
    container counts as a warm spill, not a cold one — even when the
    remote's load kept it out of the load-guarded warm pass."""
    clusters, r = _mk(n_clusters=2)
    home = r.home_cluster("f")
    remote = 1 - home
    _saturate(clusters[home])
    # remote busier than home (load guard skips it) but holding a warm
    # container on a worker with headroom
    clusters[remote].workers[0].acquire(16, 0)
    c = clusters[remote].new_container(
        clusters[remote].workers[1], "f", 4, 512, now=0.0, warm_at=0.0)
    rd = r.route("f", ALLOC, 1.0)
    assert rd.spilled and rd.decision.container is c
    assert r.spills_warm == 1 and r.spills_cold == 0


def test_queued_only_when_every_cluster_saturated():
    clusters, r = _mk(n_clusters=2)
    for cl in clusters:
        _saturate(cl)
    rd = r.route("f", ALLOC, 0.0)
    assert rd.decision.queued
    assert rd.cluster_idx == r.home_cluster("f")
    # counters record placements only — a queued attempt is not a route
    assert r.routed_home == r.spills_warm == r.spills_cold == 0


# ------------------------------------------------------ estimate routing
def test_warming_soon_inside_horizon_is_estimate_target():
    """A container still warming, with warm_at inside the estimate
    horizon, is a placement target in estimate mode: the invocation
    binds to it (Decision.pending) instead of cold-starting a new one."""
    clusters, r = _mk(routing="estimate", estimate_horizon_s=1.5)
    home = r.home_cluster("f")
    c = clusters[home].new_container(
        clusters[home].workers[0], "f", 4, 512, now=0.0, warm_at=0.2)
    rd = r.route("f", ALLOC, 0.0)
    assert rd.cluster_idx == home and not rd.spilled
    assert rd.decision.pending is c
    assert rd.decision.container is None and not rd.decision.cold_start
    # the estimate charges the residual warm-up, not a full cold start
    assert rd.est_s is not None and rd.est_s < _cold_estimate(clusters, ALLOC) \
        + DEFAULT_EXEC_ESTIMATE_S
    assert r.routed_home == 1


def test_warming_outside_horizon_is_not_estimate_target():
    """The same container with warm_at beyond the horizon is invisible:
    the router cold-starts rather than waiting past its horizon."""
    clusters, r = _mk(routing="estimate", estimate_horizon_s=1.5)
    home = r.home_cluster("f")
    clusters[home].new_container(
        clusters[home].workers[0], "f", 4, 512, now=0.0, warm_at=5.0)
    rd = r.route("f", ALLOC, 0.0)
    assert rd.decision.pending is None
    assert rd.decision.cold_start and not rd.decision.queued


def test_warming_horizon_boundary():
    """warm_at exactly at now + horizon still qualifies as a candidate;
    just past it does not (the predicate is warm_at <= now + horizon).
    Whether the candidate WINS the route is a separate estimate
    comparison — here we pin the visibility predicate itself."""
    cl = Cluster(n_workers=1, vcpus_per_worker=16, mem_mb_per_worker=8192,
                 vcpu_limit=16)
    c = cl.new_container(cl.workers[0], "f", 4, 512, now=0.0, warm_at=1.5)
    assert cl.warming_soon("f", 0.0, 1.5, 4, 512) is c
    c.warm_at = 1.5001
    assert cl.warming_soon("f", 0.0, 1.5, 4, 512) is None
    # already-warm containers belong to idle_warm, not warming_soon
    c.warm_at = 0.0
    assert cl.warming_soon("f", 0.0, 1.5, 4, 512) is None
    assert cl.idle_warm("f", 0.0) == [c]


def test_warming_committed_container_never_rebound():
    """A busy warming container (a cold start already committed to
    another invocation) is NOT a warming-soon candidate."""
    clusters, r = _mk(routing="estimate")
    home = r.home_cluster("f")
    c = clusters[home].new_container(
        clusters[home].workers[0], "f", 4, 512, now=0.0, warm_at=0.2)
    c.busy = True
    rd = r.route("f", ALLOC, 0.0)
    assert rd.decision.pending is None and rd.decision.cold_start


def test_estimate_single_cluster_binds_warming():
    """Estimate mode does not degenerate at n_clusters=1: a warming
    container inside the horizon still short-circuits the cold start
    the single-cluster path would otherwise take."""
    clusters, r = _mk(n_clusters=1, routing="estimate")
    c = clusters[0].new_container(
        clusters[0].workers[0], "f", 4, 512, now=0.0, warm_at=0.2)
    rd = r.route("f", ALLOC, 0.0)
    assert rd.cluster_idx == 0 and rd.decision.pending is c
    assert r.binds_warming == 1


def test_warming_soon_fits_checked_per_container():
    """A soonest-warming container that no longer fits its worker must
    not hide a later-warming one that does (fits is part of the
    per-container predicate, not a post-selection filter)."""
    cl = Cluster(n_workers=1, vcpus_per_worker=16, mem_mb_per_worker=8192,
                 vcpu_limit=16)
    w = cl.workers[0]
    w.acquire(10, 0)  # 6 vCPUs of headroom left
    cl.new_container(w, "f", 8, 512, now=0.0, warm_at=0.2)   # won't fit
    fits = cl.new_container(w, "f", 4, 512, now=0.0, warm_at=0.5)
    assert cl.warming_soon("f", 0.0, 1.5, 4, 512) is fits


def test_warming_soon_too_small_is_skipped():
    """A warming container smaller than the predicted allocation cannot
    serve the invocation and is not a candidate."""
    clusters, r = _mk(routing="estimate")
    home = r.home_cluster("f")
    clusters[home].new_container(
        clusters[home].workers[0], "f", 2, 256, now=0.0, warm_at=0.2)
    rd = r.route("f", ALLOC, 0.0)
    assert rd.decision.pending is None and rd.decision.cold_start


def test_estimate_prefers_idle_remote_over_contended_home_warm():
    """The §5 contention term: a warm container on a slammed home worker
    loses to a remote cold start once slowdown * exec exceeds the
    cold-start price — the case load-ranked spill-over can never take
    (it always keeps a local warm hit)."""
    clusters, r = _mk(routing="estimate", physical_cores=16)
    home = r.home_cluster("f")
    remote = 1 - home
    clusters[home].new_container(
        clusters[home].workers[0], "f", 4, 512, now=0.0, warm_at=0.0)
    # calibrate: f runs ~10 s uncontended; home worker is 4x overloaded
    r.observe_exec("f", 10.0)
    for w in clusters[home].workers:
        w.add_active(64.0, 0.0)
    rd = r.route("f", ALLOC, 1.0)
    assert rd.spilled and rd.cluster_idx == remote
    assert rd.decision.cold_start
    # spill-over, same state: stays home on the warm hit
    clusters2, r2 = _mk(routing="spill-over", physical_cores=16)
    clusters2[home].new_container(
        clusters2[home].workers[0], "f", 4, 512, now=0.0, warm_at=0.0)
    for w in clusters2[home].workers:
        w.add_active(64.0, 0.0)
    assert r2.route("f", ALLOC, 1.0).cluster_idx == home


def test_estimate_home_tie_break_and_est_s():
    """Empty fleet: every cluster estimates the same cold start; the
    home cluster wins the tie and est_s reports the winning forecast."""
    clusters, r = _mk(n_clusters=3, routing="estimate")
    rd = r.route("f", ALLOC, 0.0)
    assert rd.cluster_idx == r.home_cluster("f") and not rd.spilled
    expected = _cold_estimate(clusters, ALLOC) + r.sched_overhead_s \
        + r._slowdown(clusters[0].workers[0], "f", ALLOC.vcpus) \
        * DEFAULT_EXEC_ESTIMATE_S
    assert rd.est_s == pytest.approx(expected)


def test_estimate_queues_only_when_everything_saturated():
    clusters, r = _mk(routing="estimate")
    for cl in clusters:
        _saturate(cl)
    rd = r.route("f", ALLOC, 0.0)
    assert rd.decision.queued and rd.est_s is None


def test_observe_exec_ewma_calibration():
    _, r = _mk(routing="estimate")
    assert r._exec_estimate("f") == DEFAULT_EXEC_ESTIMATE_S
    r.observe_exec("f", 4.0)
    assert r._exec_estimate("f") == pytest.approx(4.0)
    r.observe_exec("f", 2.0)
    assert r._exec_estimate("f") == pytest.approx(0.7 * 4.0 + 0.3 * 2.0)
    r.observe_exec("f", -1.0)  # non-positive observations are ignored
    assert r._exec_estimate("f") == pytest.approx(0.7 * 4.0 + 0.3 * 2.0)


def test_estimate_routing_deterministic_under_fixed_seed():
    """Two estimate-mode runs of the same seeded scenario — including
    the online estimator calibration — produce identical metrics."""
    spec = ScenarioSpec(scenario="multi-cluster", rps=2.0, duration_s=90.0,
                        seed=5)
    cfg = SimConfig(**{**MULTI_CFG, "routing": "estimate"})
    r1 = run_scenario("shabari", spec, sim_cfg=cfg, keep_results=True)
    r2 = run_scenario("shabari", spec, sim_cfg=cfg)
    assert r1.summary == r2.summary
    assert r1.summary["n"] == len(r1.results)


def test_estimate_golden_pinned():
    """SimConfig(routing='estimate') metrics are regression-pinned under
    tests/goldens/estimate-routing/ (regenerated alongside the main
    goldens by refresh_goldens.py), independently of the spill-over
    snapshots the default goldens pin."""
    from repro.serving.golden import (
        ATOL,
        ESTIMATE_ROUTING_SCENARIOS,
        RTOL,
        run_golden,
    )
    for scenario in ESTIMATE_ROUTING_SCENARIOS:
        path = os.path.join(
            os.path.dirname(__file__), "goldens", "estimate-routing",
            f"{scenario}.json")
        assert os.path.exists(path), (
            f"missing estimate-routing snapshot {path}; run "
            "scripts/refresh_goldens.py")
        with open(path) as f:
            want = json.load(f)["summary"]
        got = run_golden(scenario, estimate_routing=True)
        assert set(got) == set(want)
        for key, expect in want.items():
            assert math.isclose(got[key], expect, rel_tol=RTOL,
                                abs_tol=ATOL), (
                f"estimate-routing {scenario}.{key}: got {got[key]!r}, "
                f"golden {expect!r}")


# ------------------------------------------------------- other routings
def test_hashing_routing_pins_home_even_when_saturated():
    clusters, r = _mk(routing="hashing")
    home = r.home_cluster("f")
    _saturate(clusters[home])
    rd = r.route("f", ALLOC, 0.0)
    assert rd.cluster_idx == home and rd.decision.queued


def test_random_routing_deterministic_under_fixed_seed():
    _, r1 = _mk(n_clusters=4, routing="random", seed=7)
    _, r2 = _mk(n_clusters=4, routing="random", seed=7)
    picks1 = [r1.route(f"f{i}", ALLOC, 0.0).cluster_idx for i in range(32)]
    picks2 = [r2.route(f"f{i}", ALLOC, 0.0).cluster_idx for i in range(32)]
    assert picks1 == picks2
    assert len(set(picks1)) > 1  # actually spreads load
    # counters account for every (non-queued) random placement too
    assert r1.routed_home + r1.spills_warm + r1.spills_cold == 32
    assert r1.spills_cold > 0  # ~3/4 of uniform picks land off-home


def test_single_cluster_router_is_transparent():
    clusters, r = _mk(n_clusters=1)
    rd = r.route("f", ALLOC, 0.0)
    assert rd.cluster_idx == 0 and not rd.spilled


def test_invalid_routing_rejected():
    clusters = [Cluster(n_workers=1)]
    scheds = [ShabariScheduler(clusters[0])]
    with pytest.raises(AssertionError):
        Router(clusters, scheds, routing="round-robin")


# ------------------------------------------------------------ end-to-end
MULTI_CFG = dict(
    n_workers=2, n_clusters=2, vcpus_per_worker=32, physical_cores=32,
    mem_mb_per_worker=16 * 1024, vcpu_limit=32, seed=0,
    retry_interval_s=1.0, queue_timeout_s=45.0,
)


def test_multi_cluster_simulation_deterministic_and_accounted():
    spec = ScenarioSpec(scenario="multi-cluster", rps=2.0, duration_s=90.0,
                        seed=5)
    r1 = run_scenario("shabari", spec, sim_cfg=SimConfig(**MULTI_CFG),
                      keep_results=True)
    r2 = run_scenario("shabari", spec, sim_cfg=SimConfig(**MULTI_CFG))
    assert r1.summary == r2.summary
    assert r1.summary["n"] == len(r1.results)
    for x in r1.results:
        if not x.timed_out:
            assert x.finish_t >= x.start_t >= x.arrival_t - 1e-9


@pytest.mark.parametrize("routing", ["hashing", "spill-over", "random"])
def test_routing_policies_run_and_account_all_arrivals(routing):
    spec = ScenarioSpec(scenario="multi-cluster", rps=2.0, duration_s=60.0,
                        seed=3)
    cfg = SimConfig(**{**MULTI_CFG, "routing": routing})
    res = run_scenario("shabari", spec, sim_cfg=cfg, keep_results=True)
    assert res.summary["n"] == len(res.results) > 0
