"""Docs gate: the repo-level documentation cannot silently rot.

README.md and docs/ARCHITECTURE.md are first-class deliverables — this
tier-1 test pins the invariants that keep them truthful: the files
exist and are cross-linked, the tier-1 verify command in the README
matches pytest.ini, every SimConfig flag and routing policy is
documented in the architecture page, and the scenario table there is
exactly the registered scenario set (so adding a scenario without
documenting it — or documenting a ghost — fails CI, just like adding
one without a golden does)."""

import configparser
import dataclasses
import os
import re

from repro.core.router import ROUTING_POLICIES
from repro.serving.simulator import SimConfig
from repro.serving.workload import list_scenarios

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
README = os.path.join(REPO, "README.md")
ARCHITECTURE = os.path.join(REPO, "docs", "ARCHITECTURE.md")


def _read(path: str) -> str:
    assert os.path.exists(path), f"missing {os.path.relpath(path, REPO)}"
    with open(path) as f:
        return f.read()


def test_readme_and_architecture_exist_and_are_linked():
    readme = _read(README)
    arch = _read(ARCHITECTURE)
    assert "docs/ARCHITECTURE.md" in readme, (
        "README must link to docs/ARCHITECTURE.md")
    assert "benchmarks/README.md" in arch, (
        "ARCHITECTURE must point at the benchmarks guide")


def test_readme_tier1_command_matches_pytest_ini():
    """The verify command the README advertises must be the command
    pytest.ini actually configures: src on the import path and the fast
    (not-slow) suite by default."""
    readme = _read(README)
    assert "PYTHONPATH=src python -m pytest -x -q" in readme, (
        "README must state the tier-1 verify command")
    ini = configparser.ConfigParser()
    ini.read(os.path.join(REPO, "pytest.ini"))
    assert ini["pytest"]["pythonpath"].strip() == "src"
    assert 'not slow' in ini["pytest"]["addopts"], (
        "tier-1 deselects slow tests; README documents that split")


def test_architecture_documents_every_simconfig_flag():
    arch = _read(ARCHITECTURE)
    missing = [
        f.name for f in dataclasses.fields(SimConfig)
        if f"`{f.name}" not in arch
    ]
    assert not missing, (
        f"SimConfig flags missing from docs/ARCHITECTURE.md: {missing}")


def test_architecture_documents_every_routing_policy():
    arch = _read(ARCHITECTURE)
    missing = [p for p in ROUTING_POLICIES if f"`{p}`" not in arch]
    assert not missing, (
        f"routing policies missing from docs/ARCHITECTURE.md: {missing}")


def test_architecture_scenario_table_matches_registry():
    """The scenario-registry table in ARCHITECTURE lists exactly the
    registered scenarios (first backticked cell of each table row under
    the registry heading)."""
    arch = _read(ARCHITECTURE)
    section = arch.split("## Scenario registry", 1)
    assert len(section) == 2, (
        "docs/ARCHITECTURE.md must keep a '## Scenario registry' section")
    documented = set(re.findall(r"^\| `([\w-]+)` \|", section[1], re.M))
    registered = set(list_scenarios())
    assert registered >= {"azure", "multi-cluster"}  # sanity: registry loaded
    assert documented == registered, (
        f"ARCHITECTURE scenario table drifted from the registry: "
        f"undocumented={sorted(registered - documented)}, "
        f"ghosts={sorted(documented - registered)}")
