"""Unit + property tests for the CSOAA allocator and cost functions."""

import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
st = pytest.importorskip("hypothesis.strategies")
given, settings = hypothesis.given, hypothesis.settings

from repro.core.allocator import Allocation, OnlineCSC, ResourceAllocator
from repro.core.cost_functions import (
    Observation,
    absolute_vcpu_costs,
    memory_costs,
    proportional_vcpu_costs,
)


def _obs(exec_s, slo_s, alloc_v, used_v, alloc_m=2048, used_m=1024, oom=False):
    return Observation(
        exec_time_s=exec_s, slo_s=slo_s, alloc_vcpus=alloc_v,
        max_vcpus_used=used_v, alloc_mem_mb=alloc_m, max_mem_used_mb=used_m,
        oom_killed=oom,
    )


# ----------------------------------------------------------------- costs
@given(
    exec_s=st.floats(0.05, 120.0),
    slo_s=st.floats(0.1, 120.0),
    alloc_v=st.integers(1, 32),
    used_frac=st.floats(0.01, 1.0),
    n=st.sampled_from([16, 32]),
    fn=st.sampled_from([absolute_vcpu_costs, proportional_vcpu_costs]),
)
@settings(max_examples=200, deadline=None)
def test_vcpu_cost_vector_invariants(exec_s, slo_s, alloc_v, used_frac, n, fn):
    obs = _obs(exec_s, slo_s, alloc_v, max(used_frac * alloc_v, 0.01))
    costs = fn(obs, n)
    assert costs.shape == (n,)
    assert np.min(costs) == 1.0  # lowest cost is exactly one
    t = int(np.argmin(costs))
    # costs grow linearly and monotonically away from the target
    assert np.all(np.diff(costs[t:]) >= 0)
    assert np.all(np.diff(costs[: t + 1]) <= 0)
    # underprediction is penalized more steeply than overprediction
    if t >= 1 and t + 1 < n:
        under = costs[t - 1] - costs[t]
        over = costs[t + 1] - costs[t]
        assert under >= over


def test_absolute_met_slo_descends_to_used():
    # allocated 16, used 2, met SLO comfortably -> target near 2 or below
    costs = absolute_vcpu_costs(_obs(1.0, 10.0, 16, 2.0), 32)
    assert int(np.argmin(costs)) <= 1  # index 1 == 2 vCPUs


def test_absolute_violation_low_util_targets_used():
    # violation but only 40% utilized: external causes — do NOT inflate
    costs = absolute_vcpu_costs(_obs(5.0, 2.0, 10, 4.0), 32)
    assert int(np.argmin(costs)) == 3  # 4 vCPUs


def test_absolute_violation_high_util_increases():
    costs = absolute_vcpu_costs(_obs(5.0, 2.0, 8, 8.0), 32)
    assert int(np.argmin(costs)) > 7


@given(
    used_m=st.floats(10.0, 6000.0),
    n=st.sampled_from([40, 64]),
)
@settings(max_examples=100, deadline=None)
def test_memory_cost_targets_observed_use(used_m, n):
    costs = memory_costs(_obs(1.0, 2.0, 4, 2.0, alloc_m=8192, used_m=used_m), n)
    t = int(np.argmin(costs))
    target_mb = (t + 1) * 128
    assert target_mb >= min(used_m, n * 128) - 1e-6
    assert target_mb - 128 < used_m or t == 0


def test_memory_cost_oom_pushes_above_allocation():
    costs = memory_costs(_obs(1.0, 2.0, 4, 2.0, alloc_m=1024, oom=True), 40)
    assert (int(np.argmin(costs)) + 1) * 128 > 1024


# ----------------------------------------------------------------- CSOAA
def test_csoaa_learns_feature_dependent_target():
    rng = np.random.default_rng(0)
    model = OnlineCSC(n_classes=16, dim=1)
    for _ in range(300):
        z = float(rng.choice([-1.0, 1.0]))
        target = 2 if z < 0 else 12
        costs = 1.0 + np.abs(np.arange(16) - target) * np.where(
            np.arange(16) < target, 3.0, 1.0
        )
        model.update(np.array([z], np.float32), costs.astype(np.float32))
    assert abs(model.predict(np.array([-1.0], np.float32)) - 2) <= 1
    assert abs(model.predict(np.array([1.0], np.float32)) - 12) <= 1


# ------------------------------------------------------------- allocator
def test_confidence_thresholds_gate_predictions():
    alloc = ResourceAllocator(vcpu_confidence=3, mem_confidence=6)
    x = np.array([0.5, -0.5], np.float32)
    a = alloc.allocate("f", x)
    assert not a.predicted and a.vcpus == alloc.default_vcpus
    assert not a.vcpu_predicted and not a.mem_predicted
    obs = _obs(1.0, 2.0, 10, 2.0, used_m=500.0)
    for i in range(3):
        alloc.feedback("f", x, obs)
    a = alloc.allocate("f", x)
    assert a.vcpu_predicted  # vCPU agent past threshold
    # memory still at default until 6 observations (2x rule) — so the
    # aggregate must NOT claim the allocation is predicted yet
    assert not a.mem_predicted and not a.predicted
    assert a.mem_mb == alloc.default_mem_class * 128
    for _ in range(3):
        alloc.feedback("f", x, obs)
    a2 = alloc.allocate("f", x)
    assert a2.mem_predicted and a2.predicted
    assert a2.mem_mb != alloc.default_mem_class * 128 or a2.mem_mb == 512


def test_memory_floor_safeguard():
    alloc = ResourceAllocator(vcpu_confidence=0, mem_confidence=1)
    x = np.array([0.0], np.float32)
    alloc.feedback("f", x, _obs(1.0, 2.0, 4, 1.0, used_m=100.0))
    # predicted ~128-256MB, but the input object is 1 GB -> default max,
    # and the served memory is a default, not a prediction
    a = alloc.allocate("f", x, input_size_mb=1000.0)
    assert a.mem_mb == alloc.default_mem_class * 128
    assert not a.mem_predicted and not a.predicted
    assert a.vcpu_predicted  # vCPU side unaffected by the memory floor
    # without the floor the same agent state IS a served prediction
    b = alloc.allocate("f", x, input_size_mb=0.0)
    assert b.mem_predicted and b.predicted
