"""Sharding rule tests + a small-mesh lowering test in a subprocess
(XLA device count must be set before jax initializes)."""

import json
import os
import subprocess
import sys
import textwrap

import jax
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import SHAPES, get_config, get_reduced_config, input_specs
from repro.distributed import sharding as sh
from repro.launch.steps import eval_param_shapes

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _fake_mesh(shape=(16, 16), axes=("data", "model")):
    """AbstractMesh carries shape/axis info without real devices."""
    try:  # jax >= 0.5: AbstractMesh(axis_sizes, axis_names)
        return jax.sharding.AbstractMesh(shape, axes)
    except TypeError:  # jax 0.4.x: AbstractMesh(((name, size), ...))
        return jax.sharding.AbstractMesh(tuple(zip(axes, shape)))


def test_param_specs_cover_tree_and_respect_divisibility():
    mesh = _fake_mesh()
    for arch in ("qwen2_5_3b", "whisper_tiny", "mixtral_8x7b", "mamba2_1_3b"):
        cfg = get_config(arch)
        pshapes = eval_param_shapes(cfg)
        specs = sh.param_spec_tree(cfg, mesh, "train", pshapes)
        flat_p = jax.tree_util.tree_leaves_with_path(pshapes)
        flat_s = jax.tree_util.tree_leaves(
            specs, is_leaf=lambda x: isinstance(x, P))
        assert len(flat_p) == len(flat_s)
        for (path, leaf), spec in zip(flat_p, flat_s):
            assert len(spec) <= len(leaf.shape)
            # every sharded dim divides the axis product
            for dim, ax in enumerate(spec):
                if ax is None:
                    continue
                axes = (ax,) if isinstance(ax, str) else ax
                total = 1
                for a in axes:
                    total *= mesh.shape[a]
                assert leaf.shape[dim] % total == 0, (arch, path, spec, leaf.shape)


def test_gqa_kv_not_split_within_heads():
    """qwen kv=2 on a 16-wide model axis: wk/wv must not shard their
    output dim (would split inside a head -> per-layer K/V gathers)."""
    mesh = _fake_mesh()
    cfg = get_config("qwen2_5_3b")
    pshapes = eval_param_shapes(cfg)
    specs = sh.param_spec_tree(cfg, mesh, "serve", pshapes)
    wk_spec = specs["blocks"]["attn"]["wk"]
    assert wk_spec[-1] is None
    # q heads (16) divide the axis -> wq IS sharded
    assert specs["blocks"]["attn"]["wq"][-1] == "model"


def test_moe_expert_sharding_rules():
    mesh = _fake_mesh()
    arctic = get_config("arctic_480b")  # 128 experts % 16 == 0
    sp = sh.param_spec_tree(arctic, mesh, "train", eval_param_shapes(arctic))
    assert sp["blocks"]["moe"]["wg"][-3] == "model"  # expert dim
    mix = get_config("mixtral_8x7b")  # 8 experts, not divisible
    sp2 = sh.param_spec_tree(mix, mesh, "train", eval_param_shapes(mix))
    assert sp2["blocks"]["moe"]["wg"][-3] is None
    assert sp2["blocks"]["moe"]["wg"][-1] == "model"  # FFN dim instead


def test_cache_specs_match_cache_tree():
    mesh = _fake_mesh()
    for arch in ("qwen2_5_3b", "mamba2_1_3b", "zamba2_7b", "whisper_tiny"):
        cfg = get_config(arch)
        shape = SHAPES["decode_32k"]
        specs = input_specs(cfg, shape)
        ctree = sh.cache_spec_tree(cfg, mesh, specs["cache"])
        assert set(ctree) == set(specs["cache"])


@pytest.mark.slow
def test_reduced_arch_lowering_on_small_mesh():
    """Lower+compile a reduced arch train step on an 8-device (2,4) mesh
    in a subprocess (device count is locked at first jax init)."""
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, jax.numpy as jnp
        from jax.sharding import PartitionSpec as P
        from repro.configs import get_reduced_config
        from repro.distributed import sharding as sh
        from repro.launch.steps import make_train_step, eval_param_shapes, eval_opt_shapes
        cfg = get_reduced_config("mixtral_8x7b")
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        pshapes = eval_param_shapes(cfg)
        praw = sh.param_spec_tree(cfg, mesh, "train", pshapes)
        pspecs = sh.named(mesh, praw)
        oshapes = eval_opt_shapes(cfg, pshapes)
        ospecs = sh.named(mesh, sh.opt_state_specs(praw))
        step = make_train_step(cfg)
        B, S = 4, 128
        batch = {
            "tokens": jax.ShapeDtypeStruct((B, S), jnp.int32),
            "labels": jax.ShapeDtypeStruct((B, S), jnp.int32),
        }
        bspec = sh.named(mesh, {"tokens": P("data", None), "labels": P("data", None)})
        with mesh:
            comp = jax.jit(step, in_shardings=(pspecs, ospecs, bspec),
                           out_shardings=(pspecs, ospecs, None),
                           donate_argnums=(0, 1)).lower(pshapes, oshapes, batch).compile()
        print("COMPILED_OK", comp.cost_analysis().get("flops", 0) > 0 if not isinstance(comp.cost_analysis(), list) else True)
    """)
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    env.pop("JAX_PLATFORMS", None)
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, env=env, timeout=600)
    assert "COMPILED_OK" in out.stdout, out.stderr[-2000:]
