"""Heterogeneous fleet + topology tests (repro.core.fleet).

Three layers of coverage:

* the homogeneous-default EQUIVALENCE contract — an explicit uniform
  FleetSpec with free links reproduces the committed goldens
  byte-for-byte (the same A/B discipline as legacy_scans/legacy_acquire,
  here asserted with exact equality, not tolerance);
* unit behavior of the new vocabulary — Topology transfer math,
  per-machine cold curves, per-worker §5 contention/NIC denominators,
  exec-speed factors, preemptible-last cold placement, clone-pooled
  calibration, per-cluster SLO-admission costs;
* runtime transfer charging — remote placements over non-free links
  start later by the payload's link time; local placements don't.
"""

import json
import os

import pytest

from repro.core.allocator import Allocation
from repro.core.cluster import Cluster
from repro.core.fleet import (
    COLD_JITTER_MEAN,
    ClusterSpec,
    FleetSpec,
    Link,
    MachineType,
    Topology,
)
from repro.core.router import DEFAULT_EXEC_ESTIMATE_S, Router
from repro.core.scheduler import ShabariScheduler
from repro.serving import baselines as B
from repro.serving.experiment import make_policy, run_scenario
from repro.serving.golden import golden_sim_config, golden_specs
from repro.serving.profiles import (
    base_function,
    build_input_pool,
    build_profiles,
)
from repro.serving.simulator import NIC_GBPS, SimConfig, Simulator
from repro.serving.workload import Arrival

ALLOC = Allocation(4, 512)


# ------------------------------------------------------------- vocabulary
def test_link_transfer_math():
    # 1000 MB over 1 Gbps = 8000 Mb / 1000 Mb/s = 8 s, plus latency
    assert Link(gbps=1.0, latency_s=0.05).transfer_s(1000.0) == pytest.approx(
        8.05)
    assert Link(gbps=10.0).transfer_s(125.0) == pytest.approx(0.1)
    # the default link is free
    assert Link().transfer_s(10_000.0) == 0.0
    # zero payload pays only the link latency
    assert Link(gbps=1.0, latency_s=0.02).transfer_s(0.0) == 0.02


def test_topology_lookup_symmetric_with_default_fallback():
    fast = Link(gbps=10.0)
    topo = Topology(default_link=Link(gbps=1.0, latency_s=0.1),
                    links=(((0, 1), fast),))
    assert topo.link(0, 1) is fast
    assert topo.link(1, 0) is fast  # symmetric
    assert topo.link(0, 2).gbps == 1.0  # unlisted pair -> default
    # intra-cluster transfer is always free
    assert topo.transfer_s(1, 1, 1e9) == 0.0
    assert topo.transfer_s(0, 2, 100.0) == pytest.approx(0.1 + 0.8)


def test_topology_is_free_detection():
    assert Topology().is_free()
    assert not Topology(default_link=Link(gbps=1.0)).is_free()
    assert not Topology(links=(((0, 1), Link(latency_s=0.01)),)).is_free()


def test_machine_cold_curve_and_limit():
    m = MachineType(cold_base_s=0.5, cold_per_gb_s=0.2)
    assert m.cold_latency_s(2048) == pytest.approx(0.5 + 0.4)
    assert MachineType(vcpus=64).limit == 64
    assert MachineType(vcpus=64, vcpu_limit=90).limit == 90


def test_fleet_spec_composition():
    a, b = MachineType(name="a"), MachineType(name="b")
    spec = ClusterSpec(machines=((a, 2), (b, 1)))
    assert spec.n_workers == 3
    assert [m.name for m in spec.worker_machines()] == ["a", "a", "b"]
    fleet = FleetSpec.uniform(3, 4, a)
    assert fleet.n_clusters == 3
    assert all(cl.n_workers == 4 for cl in fleet.clusters)
    assert fleet.topology.is_free()
    priced = FleetSpec(clusters=(
        ClusterSpec(machines=((MachineType(price_per_hour=2.0), 2),)),
        ClusterSpec(machines=((MachineType(price_per_hour=0.5), 4),)),
    ))
    assert priced.price_per_hour() == pytest.approx(6.0)


def test_cluster_builds_workers_from_machines():
    small = MachineType(physical_cores=8, vcpus=8, mem_mb=4096, vcpu_limit=12)
    big = MachineType(physical_cores=96, vcpus=90)
    cl = Cluster(machines=[small, big])
    assert [w.total_vcpus for w in cl.workers] == [8, 90]
    assert [w.vcpu_limit for w in cl.workers] == [12, 90]
    assert cl.workers[0].total_mem_mb == 4096
    assert cl.workers[0].machine is small and cl.workers[1].machine is big
    # the legacy uniform path still mirrors the scalar args
    legacy = Cluster(n_workers=2, vcpus_per_worker=16,
                     mem_mb_per_worker=8192, vcpu_limit=20)
    assert all(w.machine.vcpus == 16 and w.vcpu_limit == 20
               for w in legacy.workers)


# ------------------------------------------- homogeneous-default equivalence
@pytest.mark.parametrize("scenario", ["poisson-steady", "multi-cluster"])
def test_explicit_uniform_fleet_matches_golden_exactly(scenario):
    """SimConfig(fleet=<uniform, free links>) must reproduce the
    committed golden summary EXACTLY (==, not tolerance): the fleet
    layer's default arithmetic is inert, the same guarantee the
    byte-identical golden refresh enforces for fleet=None."""
    cfg = golden_sim_config(scenario)
    machine = MachineType(
        physical_cores=cfg.physical_cores,
        vcpus=cfg.vcpus_per_worker,
        mem_mb=cfg.mem_mb_per_worker,
        nic_gbps=NIC_GBPS,
        cold_base_s=cfg.cold_base_s,
        cold_per_gb_s=cfg.cold_per_gb_s,
        vcpu_limit=cfg.vcpu_limit,
    )
    fleet = FleetSpec.uniform(cfg.n_clusters, cfg.n_workers, machine)
    import dataclasses
    got = run_scenario(
        "shabari", golden_specs()[scenario],
        sim_cfg=dataclasses.replace(cfg, fleet=fleet)).summary
    path = os.path.join(os.path.dirname(__file__), "goldens",
                        f"{scenario}.json")
    with open(path) as f:
        want = json.load(f)["summary"]
    assert got == want


def test_default_config_builds_uniform_fleet():
    profiles = build_profiles()
    pool = build_input_pool(seed=0)
    slo = B.build_slo_table(profiles, pool)
    policy = make_policy("shabari", profiles, pool, slo, seed=0)
    sim = Simulator(policy=policy, profiles=profiles, input_pool=pool,
                    slo_table=slo,
                    cfg=SimConfig(n_workers=2, n_clusters=2))
    assert sim.fleet.n_clusters == 2
    assert not sim._charge_transfer
    for cl in sim.clusters:
        for w in cl.workers:
            assert w.machine.physical_cores == 96
            assert w.machine.nic_gbps == NIC_GBPS
            assert w.machine.exec_factor == 1.0


# --------------------------------------------------- per-machine simulation
def _stack():
    profiles = build_profiles()
    pool = build_input_pool(seed=0)
    return profiles, pool, B.build_slo_table(profiles, pool)


def _sim(fleet, **cfg_kwargs):
    profiles, pool, slo = _stack()
    policy = make_policy("shabari", profiles, pool, slo, seed=0)
    return Simulator(policy=policy, profiles=profiles, input_pool=pool,
                     slo_table=slo, cfg=SimConfig(fleet=fleet, **cfg_kwargs))


def test_per_machine_cold_latency():
    slow = MachineType(cold_base_s=0.9, cold_per_gb_s=0.3)
    sim = _sim(FleetSpec.uniform(1, 1, MachineType()), seed=0)
    fast_lat = [sim.cold_latency(4, 1024, MachineType()) for _ in range(64)]
    sim2 = _sim(FleetSpec.uniform(1, 1, MachineType()), seed=0)
    slow_lat = [sim2.cold_latency(4, 1024, slow) for _ in range(64)]
    # identical jitter streams (same seed/draw order), so the ratio is
    # exactly the mean-field curve ratio
    ratio = (0.9 + 0.3) / (0.45 + 0.12)
    for f, s in zip(fast_lat, slow_lat):
        assert s / f == pytest.approx(ratio)


def test_per_worker_contention_denominator():
    """Fewer physical cores -> larger §5 slowdown for the same demand."""
    fleet = FleetSpec(clusters=(ClusterSpec(machines=(
        (MachineType(physical_cores=32, vcpus=32), 1),
        (MachineType(physical_cores=8, vcpus=32), 1),
    )),))
    sim = _sim(fleet)
    big, small = sim.clusters[0].workers
    big.add_active(16.0, 0.0)
    small.add_active(16.0, 0.0)
    assert sim._contention(big, "f", 16.0, 0.0) == pytest.approx(1.0)
    assert sim._contention(small, "f", 16.0, 0.0) == pytest.approx(4.0)


def test_per_worker_nic_clamp_and_net_slowdown():
    """_net_demand clamps at the MACHINE's NIC, and the §5 net slowdown
    divides by it (network-fed functions only)."""
    sim = _sim(FleetSpec.uniform(1, 1, MachineType(nic_gbps=2.0)))
    w = sim.clusters[0].workers[0]
    meta = {"file_size": 5e9}  # 5 GB payload -> 40 Gb over short exec
    assert sim._net_demand("compress", meta, 1.0, w.machine.nic_gbps) == 2.0
    w.add_active(0.0, 4.0)
    assert sim._contention(w, "compress", 0.0, 0.0) == pytest.approx(2.0)
    # non-network-fed functions never see the NIC term
    assert sim._contention(w, "floatops", 0.0, 0.0) == 1.0


def test_exec_factor_scales_exec_time():
    """The same trace on a 2x-slower machine finishes each invocation
    ~2x slower (uncontended), while calibration still records
    reference-normalized times."""
    profiles, pool, slo = _stack()

    def run_on(machine):
        sim = Simulator(policy=B.StaticPolicy(12, 6 * 1024, "s"),
                        profiles=profiles, input_pool=pool, slo_table=slo,
                        cfg=SimConfig(fleet=FleetSpec.uniform(1, 1, machine)))
        return sim, sim.run([Arrival(0, 0.0, "linpack", 0)])[0]

    ref, res_ref = run_on(MachineType())
    slow, res_slow = run_on(MachineType(exec_factor=2.0))
    assert not res_ref.oom_killed
    assert res_slow.exec_s == pytest.approx(2.0 * res_ref.exec_s)
    # observe_exec fed the REFERENCE time on both fleets
    key = base_function("linpack")
    assert slow.router._exec_ewma[key] == pytest.approx(
        ref.router._exec_ewma[key])


# ------------------------------------------------------- transfer charging
def _wan_fleet(gbps=1.0, latency_s=0.0):
    m = MachineType(physical_cores=32, vcpus=32, mem_mb=16 * 1024)
    return FleetSpec(
        clusters=(ClusterSpec(machines=((m, 1),)),
                  ClusterSpec(machines=((m, 1),))),
        topology=Topology(default_link=Link(gbps=gbps, latency_s=latency_s)),
    )


def test_remote_warm_placement_pays_transfer():
    """A warm container on a remote cluster starts only after the
    payload crosses the link; the same warm hit at home starts
    immediately. Driven through the simulator so the xfer_start event
    path is exercised end to end."""
    profiles, pool, slo = _stack()
    fn = "linpack"
    meta = pool[fn][0]
    from repro.serving.profiles import input_size_mb
    mb = input_size_mb(fn, meta)

    def run_with(warm_cluster):
        sim = Simulator(policy=B.StaticPolicy(4, 6 * 1024, "s"),
                        profiles=profiles, input_pool=pool, slo_table=slo,
                        cfg=SimConfig(fleet=_wan_fleet(gbps=1e-4)))
        home = sim.router.home_cluster(fn)
        ci = home if warm_cluster == "home" else 1 - home
        w = sim.clusters[ci].workers[0]
        sim.clusters[ci].new_container(
            w, fn, 4, 6 * 1024, now=0.0, warm_at=0.0)
        # saturate the home cluster so the router must take the remote
        # warm container in the remote case
        if warm_cluster == "remote":
            for hw in sim.clusters[home].workers:
                hw.acquire(hw.vcpu_limit, 0)
        return sim.run([Arrival(0, 0.0, fn, 0)])[0]

    local = run_with("home")
    remote = run_with("remote")
    xfer = Link(gbps=1e-4).transfer_s(mb)
    assert xfer > 0.1  # the link is slow enough to matter
    assert not local.cold_start and not remote.cold_start
    assert remote.start_t - local.start_t == pytest.approx(xfer, rel=1e-6)
    assert remote.queued_s - local.queued_s == pytest.approx(xfer, rel=1e-6)


def test_cold_start_overlaps_transfer():
    """A remote cold spill pays max(cold latency, transfer), not their
    sum — the payload moves while the container warms."""
    profiles, pool, slo = _stack()
    fn = "linpack"

    def run_with(latency_s):
        sim = Simulator(
            policy=B.StaticPolicy(4, 6 * 1024, "s"), profiles=profiles,
            input_pool=pool, slo_table=slo,
            cfg=SimConfig(fleet=_wan_fleet(latency_s=latency_s)))
        # saturate the home cluster: spill-over cold-starts the
        # invocation remotely, which charges the link
        home = sim.router.home_cluster(fn)
        for hw in sim.clusters[home].workers:
            hw.acquire(hw.vcpu_limit, 0)
        return sim.run([Arrival(0, 0.0, fn, 0)])[0]

    # tiny latency: the transfer hides entirely behind the cold start
    hidden = run_with(1e-6)
    # huge latency: the transfer dominates the cold start
    exposed = run_with(30.0)
    assert hidden.cold_start and exposed.cold_start
    assert hidden.start_t == pytest.approx(hidden.cold_latency_s, abs=0.05)
    assert exposed.start_t == pytest.approx(30.0, abs=0.1)


# --------------------------------------------------- router fleet pricing
def _mk_router(fleet, routing="estimate", **kwargs):
    clusters = [Cluster(machines=spec.worker_machines())
                for spec in fleet.clusters]
    scheds = [ShabariScheduler(c) for c in clusters]
    return clusters, Router(clusters, scheds, routing=routing,
                            topology=fleet.topology,
                            network_fed=lambda f: False, **kwargs)


def test_estimate_prices_transfer_on_remote_spill():
    """With the home cluster saturated, the estimate's remote score
    includes the payload's link time — and the transfer-blind A/B arm
    (price_transfer=False) scores the same spill as free."""
    fleet = _wan_fleet(gbps=1.0)
    clusters, r = _mk_router(fleet)
    home = r.home_cluster("f")
    for w in clusters[home].workers:
        w.acquire(w.vcpu_limit, 0)
    est, kind, _ = r._estimate(1 - home, "f", ALLOC, 0.0, input_mb=1000.0)
    blind_clusters, rb = _mk_router(fleet, price_transfer=False)
    for w in blind_clusters[home].workers:
        w.acquire(w.vcpu_limit, 0)
    est_blind, _, _ = rb._estimate(1 - home, "f", ALLOC, 0.0,
                                   input_mb=1000.0)
    # 1000 MB over 1 Gbps = 8 s; cold start ~0.5 s overlaps inside it
    # (the cold term prices the jitter expectation, not the median)
    assert est - est_blind == pytest.approx(
        8.0 - clusters[0].workers[0].machine.cold_latency_s(ALLOC.mem_mb)
        * COLD_JITTER_MEAN)
    assert est > est_blind + 7.0


def test_estimate_prefers_home_when_transfer_dominates():
    """A loaded-but-usable home beats an idle remote once the payload's
    link time exceeds the home penalty; with a tiny payload the idle
    remote wins again (same fleet, same load)."""
    fleet = _wan_fleet(gbps=0.1)  # 10 MB/s-ish: heavy payloads hurt
    clusters, r = _mk_router(fleet)
    home = r.home_cluster("f")
    # home busy enough that a remote cold start would win a free spill
    clusters[home].workers[0].add_active(64.0, 0.0)
    r.observe_exec("f", 1.0)
    heavy = r.route("f", ALLOC, 0.0, input_mb=2000.0)
    assert heavy.cluster_idx == home and not heavy.spilled
    light = r.route("f", ALLOC, 0.0, input_mb=0.001)
    assert light.cluster_idx == 1 - home and light.spilled


def test_estimate_prices_exec_factor_and_cold_curve():
    """Candidate scoring scales exec by the worker's speed factor and
    uses the worker's own cold curve: an idle slow-tier cluster loses
    to an equally idle fast tier."""
    fast = MachineType(physical_cores=32, vcpus=32, mem_mb=16 * 1024)
    slow = MachineType(physical_cores=32, vcpus=32, mem_mb=16 * 1024,
                       exec_factor=3.0, cold_base_s=1.5)
    fleet = FleetSpec(clusters=(ClusterSpec(machines=((fast, 1),)),
                                ClusterSpec(machines=((slow, 1),))))
    clusters, r = _mk_router(fleet)
    r.observe_exec("f", 2.0)
    est_fast, _, _ = r._estimate(0, "f", ALLOC, 0.0)
    est_slow, _, _ = r._estimate(1, "f", ALLOC, 0.0)
    # fast: 0.45 + 0.12*0.5 cold + 2 s exec; slow: 1.5 + 0.18*... + 6 s
    assert est_slow - est_fast == pytest.approx(
        (slow.cold_latency_s(ALLOC.mem_mb)
         - fast.cold_latency_s(ALLOC.mem_mb)) * COLD_JITTER_MEAN
        + (3.0 - 1.0) * 2.0)
    rd = r.route("f", ALLOC, 0.0)
    assert rd.cluster_idx == 0


def test_slo_reject_uses_per_cluster_costs():
    """admission='slo' must not admit on a fantasy mix of one cluster's
    idle worker and another's fast silicon: with the fast tier slammed
    and only a far/slow tier idle, the honest per-cluster minimum
    exceeds the budget and the invocation is shed."""
    from repro.core.ect import ECT_BLIND_SHED_BAND, ECT_SHED_OBS

    fast = MachineType(physical_cores=32, vcpus=64, mem_mb=16 * 1024)
    slow = MachineType(physical_cores=32, vcpus=64, mem_mb=16 * 1024,
                       exec_factor=200.0)
    fleet = FleetSpec(clusters=(ClusterSpec(machines=((fast, 1),)),
                                ClusterSpec(machines=((slow, 1),))))
    clusters, r = _mk_router(fleet, routing="spill-over", admission="slo")
    for _ in range(ECT_SHED_OBS):
        r.observe_exec("f", 1.0)  # mature estimate: ~1 s on reference
    # fast worker 256x oversubscribed -> ~256 s there; slow tier idle
    # but 200x silicon -> ~200 s there. Honest per-cluster min ~200 s,
    # far past the blind-shed band (4 s budget x 32 = 128 s).
    clusters[0].workers[0].add_active(8192.0, 0.0)
    assert 4.0 * ECT_BLIND_SHED_BAND < 200.0
    rd = r.route("f", ALLOC, 0.0, slo_s=4.0)
    assert rd.shed and r.admission_slo_shed == 1
    # the OLD fleet-min bug would have scored: min slowdown over ALL
    # workers (idle slow tier, 1.0) x exec 1 s ~= 1 s < budget ->
    # admitted. Sanity-check that an honest fleet with an idle FAST
    # tier does admit:
    clusters2, r2 = _mk_router(fleet, routing="spill-over", admission="slo")
    for _ in range(ECT_SHED_OBS):
        r2.observe_exec("f", 1.0)
    clusters2[1].workers[0].add_active(8192.0, 0.0)  # slam the SLOW tier
    assert not r2.route("f", ALLOC, 0.0, slo_s=4.0).shed


# ------------------------------------------------- preemptible-last packing
def test_cold_placement_prefers_reliable_workers():
    spot = MachineType(preemptible=True, vcpus=32, mem_mb=16 * 1024)
    firm = MachineType(vcpus=32, mem_mb=16 * 1024)
    cl = Cluster(machines=[spot, spot, firm])
    sched = ShabariScheduler(cl)
    w = sched.cold_candidate("f", 4, 512)
    assert w is cl.workers[2] and not w.machine.preemptible
    # saturate the reliable worker: spot tier becomes the fallback, in
    # walk order
    cl.workers[2].acquire(32, 0)
    w2 = sched.cold_candidate("f", 4, 512)
    assert w2 is not None and w2.machine.preemptible


# ------------------------------------------------- clone-pooled calibration
def test_observe_exec_pools_clone_aliases():
    """With pool_key=base_function (what the Simulator passes), clone
    aliases share one estimator: observations through 'f::1' move the
    estimate 'f::2' sees."""
    cl = Cluster(n_workers=1, vcpus_per_worker=16, mem_mb_per_worker=8192)
    r = Router([cl], [ShabariScheduler(cl)], routing="estimate",
               pool_key=base_function)
    assert r._exec_estimate("f::2") == DEFAULT_EXEC_ESTIMATE_S
    r.observe_exec("f::1", 4.0)
    assert r._exec_estimate("f::2") == pytest.approx(4.0)
    assert r._exec_estimate("f") == pytest.approx(4.0)
    r.observe_exec("f", 2.0)
    assert r._exec_estimate("f::7") == pytest.approx(0.7 * 4.0 + 0.3 * 2.0)
    assert set(r._exec_ewma) == {"f"}
    # without a pool key, aliases stay independent (the old behavior)
    r2 = Router([cl], [ShabariScheduler(cl)], routing="estimate")
    r2.observe_exec("f::1", 4.0)
    assert r2._exec_estimate("f::2") == DEFAULT_EXEC_ESTIMATE_S
