"""Function-chain/DAG workload tests (repro.serving.chains).

Pins the critical-path slack decomposition (aware vs uniform budgets),
the DAG validation (cycles, unreachable stages, multi-root), join
barriers with summed-payload input resolution, Fifer pre-warm counts
and the simulator's proactive launch fork, the router's budget-aware
estimate ranking, the estimate-aware admission hold (warm capacity in
budget -> queue instead of shed, both directions), and the chain
golden pins: the chain-uniform snapshot is a REAL semantics fork of
chain-pipeline's main golden, and the slack-aware arm must not lose
to the uniform split on end-to-end violations at golden scale.
"""

import dataclasses
import json
import os

import pytest

from repro.core.allocator import Allocation
from repro.core.cluster import Cluster
from repro.core.ect import ECT_SHED_OBS
from repro.core.fleet import MachineType
from repro.core.router import Router
from repro.core.scheduler import ShabariScheduler
from repro.serving import baselines as B
from repro.serving.chains import (
    ChainEdge,
    ChainRuntime,
    ChainSpec,
    ChainStage,
    chain_trigger,
    default_chains,
)
from repro.serving.golden import (
    CHAIN_UNIFORM_SCENARIOS,
    golden_sim_config,
    golden_specs,
)
from repro.serving.profiles import build_input_pool, build_profiles, input_size_mb
from repro.serving.simulator import Simulator
from repro.serving.workload import Arrival, generate_scenario

GOLDEN_DIR = os.path.join(os.path.dirname(__file__), "goldens")
ALLOC = Allocation(4, 512)


@pytest.fixture(scope="module")
def stack():
    profiles = build_profiles()
    pool = build_input_pool(seed=0)
    slo_table = B.build_slo_table(profiles, pool)
    return profiles, pool, slo_table


def _runtime(pool, which="pipeline", slack="aware"):
    return ChainRuntime((default_chains()[which],), pool, slack=slack)


# ------------------------------------------------------ critical-path math
def test_pipeline_critical_path_decomposition(stack):
    _, pool, _ = stack
    rt = _runtime(pool)
    comp = rt._compiled[chain_trigger(default_chains()["pipeline"])]
    # linear chain: cp = sum of stages; every stage is on the path
    assert comp.cp_total == pytest.approx(1.0 + 2.0 + 3.4 + 1.8)
    assert comp.depth == 4
    assert comp.e2e_slo == pytest.approx(1.6 * comp.cp_total)
    assert comp.cp_after == pytest.approx(
        {"ingest": 7.2, "detect": 5.2, "classify": 1.8, "archive": 0.0})


def test_fanout_critical_path_runs_through_slowest_branch(stack):
    _, pool, _ = stack
    rt = _runtime(pool, "fanout")
    comp = rt._compiled[chain_trigger(default_chains()["fanout"])]
    # the tag (3.4 s) branch dominates thumb (1.0) and detect (2.0)
    assert comp.cp_total == pytest.approx(0.15 + 3.4 + 2.1)
    assert comp.depth == 3
    # every sibling reserves the same tail (the digest), so the fast
    # branches inherit the join's slack through a SMALLER cp_after
    # than their own path would suggest
    assert comp.cp_after["thumb"] == pytest.approx(2.1)
    assert comp.cp_after["tag"] == pytest.approx(2.1)
    assert comp.cp_after["digest"] == pytest.approx(0.0)
    assert comp.cp_after["validate"] == pytest.approx(5.5)


def test_compile_rejects_cycles_unreachable_and_multi_root(stack):
    _, pool, _ = stack
    def spec(stages, edges):
        return ChainSpec(
            name="bad", stages=stages, edges=edges,
            expected_s=tuple((s.name, 1.0) for s in stages))
    two = (ChainStage("a", "qr"), ChainStage("b", "compress"))
    with pytest.raises(ValueError, match="cycle"):
        ChainRuntime((spec(
            two + (ChainStage("c", "sentiment"),),
            (ChainEdge("a", "b", 1.0), ChainEdge("b", "c", 1.0),
             ChainEdge("c", "b", 1.0))),), pool)
    with pytest.raises(AssertionError, match="exactly one root"):
        ChainRuntime((spec(two, ()),), pool)  # two roots, no edges
    with pytest.raises(AssertionError, match="duplicate stage"):
        ChainRuntime((spec(
            (ChainStage("a", "qr"), ChainStage("a", "compress")),
            ()),), pool)
    with pytest.raises(AssertionError):  # dangling edge endpoint
        ChainRuntime((spec(two, (ChainEdge("a", "nope", 1.0),)),), pool)


def test_two_chains_sharing_a_trigger_function_rejected(stack):
    _, pool, _ = stack
    p = default_chains()["pipeline"]
    with pytest.raises(AssertionError, match="share trigger"):
        ChainRuntime((p, dataclasses.replace(p, name="copy")), pool)


# ------------------------------------------------------------ join barrier
def test_join_barrier_spawns_on_last_parent_only(stack):
    _, pool, _ = stack
    rt = _runtime(pool, "fanout")
    trig = chain_trigger(default_chains()["fanout"])
    rt.stage_budget(Arrival(0, 0.0, trig, 0), 0.0, 0.0)
    assert rt.started == 1
    ready = rt.on_complete(0, 1.0)
    assert [(s, fn) for _, s, fn, _ in ready] == [
        ("thumb", "imageprocess"), ("detect", "mobilenet"),
        ("tag", "resnet50")]
    for iid, (inst, s, _, _) in enumerate(ready, start=100):
        rt.bind(inst, s, iid, 1.0)
    # first two siblings finishing spawn NOTHING; the last releases
    # the digest join
    assert rt.on_complete(100, 2.0) == []
    assert rt.on_complete(101, 3.0) == []
    ready = rt.on_complete(102, 4.5)
    assert [(s, fn) for _, s, fn, _ in ready] == [("digest", "sentiment")]
    inst, s, fn, idx = ready[0]
    # fan-in input resolves to the pool entry nearest the SUMMED
    # in-edge payloads (0.008 + 0.006 + 0.006 MB)
    sizes = [input_size_mb(fn, m) for m in pool[fn]]
    assert idx == min(range(len(sizes)), key=lambda i: abs(sizes[i] - 0.02))
    rt.bind(inst, s, 103, 4.5)
    assert rt.completed == 0
    rt.on_complete(103, 6.0)
    assert rt.completed == 1 and rt.late == 0
    assert rt.summary()["chain_e2e_p50_s"] == pytest.approx(6.0)


def test_failed_chain_spawns_nothing_and_counts_once(stack):
    _, pool, _ = stack
    rt = _runtime(pool)
    trig = chain_trigger(default_chains()["pipeline"])
    rt.stage_budget(Arrival(0, 0.0, trig, 0), 0.0, 0.0)
    rt.on_fail(0)
    rt.on_fail(0)  # e.g. queue timeout then reap race: count once
    assert rt.failed == 1
    assert rt.on_complete(0, 1.0) == []  # no downstream spawns
    s = rt.summary()
    assert s["chain_e2e_viol_pct"] == pytest.approx(100.0)
    assert s["chain_completed"] == 0.0


# ------------------------------------------------------------------ budgets
def test_aware_budget_is_remaining_e2e_minus_tail(stack):
    _, pool, _ = stack
    rt = _runtime(pool, slack="aware")
    trig = chain_trigger(default_chains()["pipeline"])
    e2e = rt._compiled[trig].e2e_slo
    slo, budget = rt.stage_budget(Arrival(0, 0.0, trig, 0), 0.0, 0.0)
    assert slo == budget == pytest.approx(e2e - 7.2)
    # 2 s later (a retry): the same stage's allowance shrank by 2 s
    slo2, _ = rt.stage_budget(Arrival(0, 0.0, trig, 0), 2.0, 0.0)
    assert slo2 == pytest.approx(slo - 2.0)
    # bind the classify stage at t=5: it gets everything the chain can
    # still afford minus the 1.8 s archive tail
    (inst, _), = [rt._by_iid[0]]
    rt.bind(inst, "classify", 7, 5.0)
    slo3, budget3 = rt.stage_budget(Arrival(7, 5.0, "resnet50", 0), 5.0, 5.0)
    assert slo3 == budget3 == pytest.approx(e2e - 5.0 - 1.8)


def test_uniform_budget_splits_evenly_with_no_routing_budget(stack):
    _, pool, _ = stack
    rt = _runtime(pool, slack="uniform")
    trig = chain_trigger(default_chains()["pipeline"])
    comp = rt._compiled[trig]
    slo, budget = rt.stage_budget(Arrival(0, 0.0, trig, 0), 1.0, 0.0)
    assert budget is None  # slack-blind: estimate routing stays min-ECT
    assert slo == pytest.approx(comp.e2e_slo / comp.depth - 1.0)


def test_non_chain_traffic_gets_no_budget(stack):
    _, pool, _ = stack
    rt = _runtime(pool)
    assert rt.stage_budget(Arrival(0, 0.0, "sentiment", 0), 0.0, 0.0) is None
    assert rt.started == 0


# ------------------------------------------------------- pre-warm counts
def test_note_start_end_track_child_inflight(stack):
    _, pool, _ = stack
    rt = _runtime(pool, "fanout")
    trig = chain_trigger(default_chains()["fanout"])
    rt.stage_budget(Arrival(0, 0.0, trig, 0), 0.0, 0.0)
    rt.stage_budget(Arrival(1, 0.0, trig, 0), 0.0, 0.0)
    assert rt.note_start(0) == [
        ("imageprocess", 1), ("mobilenet", 1), ("resnet50", 1)]
    assert rt.note_start(1) == [
        ("imageprocess", 2), ("mobilenet", 2), ("resnet50", 2)]
    rt.note_end(0)
    assert rt._inflight["resnet50"] == 1
    assert rt.note_start(999) == []  # non-chain invocations are inert
    rt.note_end(999)


def _chain_sim(stack, **cfg_overrides):
    profiles, pool, slo_table = stack
    cfg = dataclasses.replace(
        golden_sim_config("chain-pipeline"), **cfg_overrides)
    pol = B.ShabariPolicy()
    return Simulator(policy=pol, profiles=profiles, input_pool=pool,
                     slo_table=slo_table, cfg=cfg)


def test_simulator_prewarm_fork_both_ways(stack):
    """A stage start whose child demand exceeds the idle supply launches
    ONE uncommitted warming container on the child's home cluster —
    and launches nothing with chain_prewarm=False."""
    for prewarm, want in ((True, 1), (False, 0)):
        sim = _chain_sim(stack, chain_prewarm=prewarm)
        trig = chain_trigger(default_chains()["pipeline"])
        sim._chains.stage_budget(Arrival(0, 0.0, trig, 0), 0.0, 0.0)
        sim._chain_alloc["mobilenet"] = (8, 2048)  # last-seen allocation
        sim._chain_prewarm(0)
        ci = sim.router.home_cluster("mobilenet")
        byf = sim.clusters[ci].idle_by_function.get("mobilenet", {})
        assert len(byf) == want
        if prewarm:
            (c,) = byf.values()
            assert c.vcpus == 8 and c.warm_at > 0.0  # warming, not warm
            # the supply now covers the in-flight demand: a second
            # parent start does not stack another container
            sim._chains.stage_budget(Arrival(1, 0.0, trig, 0), 0.0, 0.0)
            sim._chain_prewarm(1)
            assert len(sim.clusters[ci].idle_by_function["mobilenet"]) == 2


def test_prewarm_skips_never_allocated_child(stack):
    sim = _chain_sim(stack)
    trig = chain_trigger(default_chains()["pipeline"])
    sim._chains.stage_budget(Arrival(0, 0.0, trig, 0), 0.0, 0.0)
    sim._chain_prewarm(0)  # no _chain_alloc entry for mobilenet yet
    ci = sim.router.home_cluster("mobilenet")
    assert not sim.clusters[ci].idle_by_function.get("mobilenet")


# ------------------------------------------- budget-aware estimate ranking
def _mk(n_clusters=2, n_workers=2, physical_cores=None, **kwargs):
    machines = None
    if physical_cores is not None:
        machines = [MachineType(physical_cores=physical_cores, vcpus=16,
                                mem_mb=8192)] * n_workers
    clusters = [
        Cluster(n_workers=n_workers, vcpus_per_worker=16,
                mem_mb_per_worker=8192, vcpu_limit=16, machines=machines)
        for _ in range(n_clusters)
    ]
    scheds = [ShabariScheduler(c) for c in clusters]
    return clusters, Router(clusters, scheds, routing="estimate", **kwargs)


def test_budget_ranking_prefers_home_cold_when_it_fits():
    """With slack to spend, a within-budget home cold start outranks a
    faster remote warm bind (warm pools are preserved for slack-less
    stages); without a budget the remote warm container wins min-ECT."""
    clusters, r = _mk()
    home = r.home_cluster("f")
    other = 1 - home
    w = clusters[other].workers[0]
    clusters[other].new_container(w, "f", 4, 512, now=0.0, warm_at=0.0)

    rd = r.route("f", ALLOC, 1.0)  # budget_s=None: pure min-ECT
    assert rd.cluster_idx == other and rd.decision.container is not None

    rd = r.route("f", ALLOC, 1.0, budget_s=1000.0)
    assert rd.cluster_idx == home
    assert rd.decision.container is None and rd.decision.cold_start

    # nothing fits a micro-budget -> degrade to exactly min-ECT order
    rd = r.route("f", ALLOC, 1.0, budget_s=1e-6)
    assert rd.cluster_idx == other and rd.decision.container is not None


# ------------------------------------- estimate-aware admission queueing
# A worker drowning in co-runner demand (slowdown 38x at the request's
# 4 vcpus) with a maturely-calibrated 2 s function: the contended
# fleet-min estimate (~76 s) blows past ECT_BLIND_SHED_BAND x the
# 2.05 s budget, while the contention-free warm figure (~2.001 s,
# sched overhead + exec) still fits it.
_HOLD_SLO = 2.05


def _held_setup(warm=True, warming_at=None):
    clusters, r = _mk(n_clusters=1, n_workers=1, physical_cores=8,
                      admission="slo")
    w = clusters[0].workers[0]
    if warm:
        clusters[0].new_container(w, "f", 8, 1024, now=0.0, warm_at=0.0)
    if warming_at is not None:
        clusters[0].new_container(w, "f", 8, 1024, now=0.0,
                                  warm_at=warming_at)
    w.add_active(300.0, 0.0)
    for _ in range(ECT_SHED_OBS):
        r.observe_exec("f", 2.0)
    return clusters, r


def test_slo_admission_holds_when_warm_capacity_fits_budget():
    """The contended estimate says shed but an idle warm container fits
    contention-free: hold at the front door — queued, NOT shed — and
    count it."""
    _, r = _held_setup(warm=True)
    rd = r.route("f", ALLOC, 0.0, slo_s=_HOLD_SLO)
    assert not rd.shed and rd.decision.queued
    assert r.admission_slo_held == 1
    assert r.admission_slo_shed == 0 and r.admission_shed == 0


def test_slo_admission_warming_soon_also_holds():
    _, r = _held_setup(warm=False, warming_at=0.02)
    rd = r.route("f", ALLOC, 0.0, slo_s=_HOLD_SLO)
    assert not rd.shed and rd.decision.queued
    assert r.admission_slo_held == 1


def test_slo_admission_shed_stands_without_warm_capacity():
    """No warm or warming container anywhere: the shed is final (a cold
    start can't dodge the contention that doomed the estimate)."""
    _, r = _held_setup(warm=False)
    rd = r.route("f", ALLOC, 0.0, slo_s=_HOLD_SLO)
    assert rd.shed
    assert r.admission_slo_held == 0 and r.admission_slo_shed == 1


def test_slo_admission_hold_terminates_on_exhausted_budget():
    """A held arrival keeps retrying, so the hold MUST NOT fire once the
    budget hits zero or the retry loop never ends."""
    _, r = _held_setup(warm=True)
    rd = r.route("f", ALLOC, 10.0, slo_s=0.0)
    assert rd.shed and r.admission_slo_held == 0


# ------------------------------------------------------------ golden pins
def test_chain_goldens_committed_with_chain_metrics():
    for scenario in ("chain-pipeline", "fan-out-join"):
        with open(os.path.join(GOLDEN_DIR, f"{scenario}.json")) as f:
            doc = json.load(f)
        s = doc["summary"]
        assert s["chain_started"] > 0
        assert s["chain_completed"] > 0
        assert s["chain_stage_spawned"] > 0
        # spawned stage invocations actually entered the trace totals
        assert s["n"] > s["chain_started"]


def test_chain_uniform_golden_is_a_real_fork():
    """chain_slack is a semantics fork: the uniform snapshot must share
    the spec but NOT the summary (identical summaries would mean the
    A/B arm silently stopped differing)."""
    for scenario in CHAIN_UNIFORM_SCENARIOS:
        with open(os.path.join(GOLDEN_DIR, f"{scenario}.json")) as f:
            main = json.load(f)
        with open(os.path.join(
                GOLDEN_DIR, "chain-uniform", f"{scenario}.json")) as f:
            uni = json.load(f)
        assert main["spec"] == uni["spec"]
        assert main["summary"] != uni["summary"]
        # at golden scale the slack-aware arm must not LOSE to the
        # uniform split on end-to-end violations (chain_bench gates the
        # strict win at matrix scale)
        assert (main["summary"]["chain_e2e_viol_pct"]
                <= uni["summary"]["chain_e2e_viol_pct"])


# ------------------------------------------------------------- scenarios
def test_chain_scenarios_keep_triggers_out_of_background(stack):
    """The chain population must be exactly the trigger stream: any
    background arrival of the trigger function would start a phantom
    chain."""
    profiles, pool, _ = stack
    for scenario, which in (("chain-pipeline", "pipeline"),
                            ("fan-out-join", "fanout")):
        spec = golden_specs()[scenario]
        trig = chain_trigger(default_chains()[which])
        trace = generate_scenario(
            spec, functions=sorted(profiles),
            inputs_per_function={f: len(pool[f]) for f in profiles})
        trig_arrivals = [a for a in trace if a.function == trig]
        assert trig_arrivals  # the trigger stream exists...
        frac = len(trig_arrivals) / len(trace)
        assert 0.2 < frac < 0.6  # ...at roughly trigger_frac of traffic
        # ids are the contiguous renumbered block, so chain spawns
        # (minted at len(trace)+) can never collide
        assert [a.invocation_id for a in trace] == list(range(len(trace)))
