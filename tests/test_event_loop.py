"""Array-backed event loop vs the legacy single-heapq loop.

The fast loop (``SimConfig(legacy_event_loop=False)``, the default) is
a pure fast path: sorted-array arrivals + calendar-queue scheduled
events + a FIFO retry lane must replay the exact event sequence the
global heap produced. These tests pin that equivalence end to end
(per-field ``InvocationResult`` equality on scenarios that exercise
retries, front-door sheds, and warming-soon binds), pin the
same-timestamp cohort partition both loops feed the policy batch hook,
and pin the :class:`CalendarQueue` boundary cases (including pushing
into the bucket currently being drained, and pushing an event EARLIER
than the cached head bucket).

The committed golden under tests/goldens/legacy-event-loop/ must stay
byte-identical to the main golden of the same scenario — unlike the
legacy-acquire fork, the two loops are one semantics.
"""

import dataclasses
import heapq
import json
import os
import random

import pytest

from repro.serving import baselines as B
from repro.serving.event_queue import CalendarQueue
from repro.serving.experiment import make_policy
from repro.serving.golden import (LEGACY_EVENT_LOOP_SCENARIOS,
                                  golden_sim_config, golden_specs)
from repro.serving.profiles import build_input_pool, build_profiles
from repro.serving.simulator import InvocationResult, SimConfig, Simulator
from repro.serving.workload import Arrival, generate_scenario

GOLDEN_DIR = os.path.join(os.path.dirname(__file__), "goldens")
FIELDS = [f.name for f in dataclasses.fields(InvocationResult)]


def _build_stack():
    profiles = build_profiles()
    pool = build_input_pool(seed=0)
    slo = B.build_slo_table(profiles, pool)
    return profiles, pool, slo


def _run_loop(policy, spec, cfg, legacy):
    profiles, pool, slo = _build_stack()
    trace = generate_scenario(
        spec, functions=sorted(profiles),
        inputs_per_function={f: len(pool[f]) for f in profiles})
    cfg = dataclasses.replace(cfg, legacy_event_loop=legacy)
    pol = make_policy(policy, profiles, pool, slo, seed=0)
    sim = Simulator(policy=pol, profiles=profiles, input_pool=pool,
                    slo_table=slo, cfg=cfg)
    return sim, sim.run(trace)


def _assert_field_equal(fast, legacy):
    assert len(fast) == len(legacy)
    for a, b in zip(fast, legacy):
        for f in FIELDS:
            assert getattr(a, f) == getattr(b, f), (
                f"invocation {a.invocation_id} field {f}: "
                f"fast={getattr(a, f)!r} legacy={getattr(b, f)!r}")


# ---------------------------------------------------- full-sim equality
def test_equal_oversubscribe_retry_storm():
    """Saturating cell with queue-mode admission: retries (both
    capacity-queued and front-door-held), timeouts, and the retry FIFO
    lane all in play, under the learning policy."""
    spec = golden_specs()["oversubscribe"]
    cfg = dataclasses.replace(
        golden_sim_config("oversubscribe"),
        admission="queue", admission_headroom=0.5)
    sim_f, fast = _run_loop("shabari", spec, cfg, legacy=False)
    sim_l, legacy = _run_loop("shabari", spec, cfg, legacy=True)
    assert sim_f.events_processed == sim_l.events_processed
    assert sim_f.router.admission_queue_events > 0  # front-door holds
    assert any(r.timed_out for r in fast)  # retries actually timed out
    _assert_field_equal(fast, legacy)


def test_equal_flash_crowd_sheds():
    """Shed-mode admission on the spike scenario: terminal front-door
    drops must land on the same invocations in both loops."""
    spec = golden_specs()["flash-crowd"]
    cfg = dataclasses.replace(
        golden_sim_config("flash-crowd"),
        admission="shed", admission_headroom=0.5)
    sim_f, fast = _run_loop("static-large", spec, cfg, legacy=False)
    sim_l, legacy = _run_loop("static-large", spec, cfg, legacy=True)
    assert sim_f.router.admission_shed > 0
    assert any(r.shed for r in fast)
    _assert_field_equal(fast, legacy)


def test_equal_estimate_routing_warming_binds():
    """Estimate routing on the multi-cluster golden cell: invocations
    bound to still-warming containers (pending commits + reservation
    cancellation on timeout) must replay identically."""
    spec = golden_specs()["multi-cluster"]
    cfg = dataclasses.replace(
        golden_sim_config("multi-cluster"), routing="estimate")
    sim_f, fast = _run_loop("shabari", spec, cfg, legacy=False)
    sim_l, legacy = _run_loop("shabari", spec, cfg, legacy=True)
    assert sim_f.router.binds_warming > 0  # the path is exercised
    assert sim_f.router.binds_warming == sim_l.router.binds_warming
    _assert_field_equal(fast, legacy)


def test_equal_registry_storm_image_cache():
    """Registry-storm with the image cache ON (the PR 8/9 gap): layer
    pulls, LRU evictions, and cache-affinity placement landed after the
    event-loop A/B matrix was chosen — per-field equality under
    legacy_event_loop=True closes it."""
    spec = golden_specs()["registry-storm"]
    cfg = golden_sim_config("registry-storm")
    assert cfg.image_cache is not None  # the golden cell keeps it on
    sim_f, fast = _run_loop("shabari", spec, cfg, legacy=False)
    sim_l, legacy = _run_loop("shabari", spec, cfg, legacy=True)
    assert sim_f.events_processed == sim_l.events_processed
    # the cache subsystem actually fired: layers were pulled somewhere
    pulls = sum(w.image_cache.misses
                for cl in sim_f.clusters for w in cl.workers)
    assert pulls > 0
    _assert_field_equal(fast, legacy)


def test_equal_chain_pipeline_spawned_arrivals():
    """Chain cell: downstream stage arrivals are pushed at t == now via
    the new "chain_arrival" event kind — the fast loop routes them
    through the calendar queue (NOT the retry FIFO, whose ordering
    invariant assumes now + retry_interval_s pushes). Both loops must
    replay identical results AND identical end-to-end chain metrics."""
    spec = golden_specs()["chain-pipeline"]
    cfg = golden_sim_config("chain-pipeline")
    sim_f, fast = _run_loop("shabari", spec, cfg, legacy=False)
    sim_l, legacy = _run_loop("shabari", spec, cfg, legacy=True)
    assert sim_f.chain_summary()["chain_stage_spawned"] > 0
    assert sim_f.chain_summary() == sim_l.chain_summary()
    fast = sorted(fast, key=lambda r: r.invocation_id)
    legacy = sorted(legacy, key=lambda r: r.invocation_id)
    _assert_field_equal(fast, legacy)


def test_legacy_event_loop_golden_is_byte_identical():
    """The pinned legacy-event-loop snapshot equals the main golden —
    the two loops are one semantics, not a fork."""
    for scenario in LEGACY_EVENT_LOOP_SCENARIOS:
        with open(os.path.join(GOLDEN_DIR, f"{scenario}.json")) as f:
            main = json.load(f)
        with open(os.path.join(
                GOLDEN_DIR, "legacy-event-loop", f"{scenario}.json")) as f:
            legacy = json.load(f)
        assert main["summary"] == legacy["summary"]
        assert main["spec"] == legacy["spec"]


# ------------------------------------------------ cohort-order parity
def _record_cohorts(sim):
    """Record (a) the flattened order every arrival is processed in and
    (b) the multi-payload cohort partitions handed to the policy batch
    hook. Singleton cohorts are equivalent to a direct ``_on_arrival``
    call (the batch hook only fires for len > 1), and the fast loop
    exploits that by dispatching lone retries directly — so only the
    multi-payload partitions are pinned, plus the total order."""
    orig_cohort = sim._process_arrival_cohort
    orig_arrival = sim._on_arrival
    order, cohorts = [], []

    def cohort_wrapper(t, payloads):
        if len(payloads) > 1:
            cohorts.append(
                (t, tuple(a.invocation_id for a, _, _, _ in payloads)))
        orig_cohort(t, payloads)

    def arrival_wrapper(arrival, first_seen, alloc=None, aux=None):
        order.append((sim.now, arrival.invocation_id))
        orig_arrival(arrival, first_seen, alloc, aux)

    sim._process_arrival_cohort = cohort_wrapper
    sim._on_arrival = arrival_wrapper
    return order, cohorts


def test_same_timestamp_cohorts_partition_identically():
    """Fresh arrivals sharing a timestamp form one cohort; retries
    landing on that timestamp extend it in seq order. Both loops must
    process arrivals in the same total order and feed the policy the
    same multi-payload (t, ids) partitions."""
    profiles, pool, slo = _build_stack()
    fn = "lrtrain"  # ~2.5 s at 8 vCPUs: serializes a 1-worker cluster
    trace = [Arrival(0, 0.0, fn, 0),
             Arrival(1, 1.0, fn, 0), Arrival(2, 1.0, fn, 0),
             # collides with the t=1.5 retries of invocations 1 and 2
             Arrival(3, 1.5, fn, 0),
             Arrival(4, 9.0, fn, 0)]
    orders, cohorts = {}, {}
    for legacy in (False, True):
        cfg = SimConfig(n_workers=1, vcpus_per_worker=8, physical_cores=8,
                        mem_mb_per_worker=4096, vcpu_limit=8,
                        retry_interval_s=0.5, queue_timeout_s=300.0,
                        seed=0, legacy_event_loop=legacy)
        pol = make_policy("static-large", profiles, pool, slo, seed=0)
        sim = Simulator(policy=pol, profiles=profiles, input_pool=pool,
                        slo_table=slo, cfg=cfg)
        orders[legacy], cohorts[legacy] = _record_cohorts(sim)
        sim.run(list(trace))
    assert orders[False] == orders[True]
    assert cohorts[False] == cohorts[True]
    # the trace actually produced a mixed fresh+retry cohort at t=1.5
    mixed = [ids for t, ids in cohorts[False] if t == 1.5]
    assert mixed and set(mixed[0]) >= {1, 2, 3}
    # fresh arrival 3 (virtual seq < any retry seq) leads its cohort
    assert mixed[0][0] == 3


# ------------------------------------------------- CalendarQueue units
def test_calendar_queue_pop_parity_fuzz():
    """Pop order matches a single global heapq over the same pushes,
    with interleaved pops and pushes into already-draining buckets."""
    rng = random.Random(7)
    q = CalendarQueue(bucket_s=1.0)
    ref = []
    seq = 0
    popped = []
    expect = []
    for _ in range(2000):
        if ref and rng.random() < 0.45:
            popped.append(q.pop())
            expect.append(heapq.heappop(ref))
        else:
            ev = (rng.uniform(0.0, 50.0), seq, "k", None)
            seq += 1
            q.push(ev)
            heapq.heappush(ref, ev)
    while ref:
        popped.append(q.pop())
        expect.append(heapq.heappop(ref))
    assert popped == expect
    assert len(q) == 0 and not q


def test_calendar_queue_insert_into_draining_bucket():
    q = CalendarQueue(bucket_s=1.0)
    q.push((0.1, 0, "a", None))
    q.push((0.9, 1, "b", None))
    assert q.pop()[2] == "a"  # bucket 0 is now the draining bucket
    q.push((0.5, 2, "c", None))  # lands in the draining bucket
    assert q.pop()[2] == "c"
    assert q.pop()[2] == "b"


def test_calendar_queue_push_earlier_than_cached_head():
    """A push that OPENS a bucket earlier than the cached head must
    invalidate the cache (regression test for the head-bucket cache)."""
    q = CalendarQueue(bucket_s=1.0)
    q.push((8.2, 0, "late", None))
    assert q.peek()[2] == "late"  # caches bucket 8 as the head
    q.push((5.5, 1, "early", None))
    assert q.peek()[2] == "early"
    assert q.pop()[2] == "early"
    assert q.pop()[2] == "late"


def test_calendar_queue_same_t_orders_by_seq_across_kinds():
    q = CalendarQueue(bucket_s=1.0)
    q.push((2.0, 7, "retry", None))
    q.push((2.0, 5, "finish", None))
    q.push((2.0, 6, "warm_start", None))
    assert [q.pop()[2] for _ in range(3)] == ["finish", "warm_start", "retry"]


def test_calendar_queue_empty_pop_raises():
    q = CalendarQueue()
    with pytest.raises(IndexError):
        q.pop()
    q.push((1.0, 0, "x", None))
    q.pop()
    with pytest.raises(IndexError):
        q.pop()
    assert q.peek() is None
