"""Agent-arena tests: the batched engine must be indistinguishable —
bit for bit — from the legacy per-object path.

Covers: random interleaved allocate/feedback streams (hypothesis),
capacity growth across the doubling boundary, per-function isolation
after slot release/reuse, the flush ordering rule (updates for F apply
before any predict for F), batched-vs-scalar cost vectors, the
calibrated NumPy backend and the vmapped JAX fallback, same-timestamp
arrival microbatching in the simulator, the retry-payload featurization
cache, and the legacy-engine golden pin."""

import json
import os

import numpy as np
import pytest

try:  # property tests use hypothesis when present, seeded sweeps if not
    import hypothesis
    from hypothesis import strategies as st
    given, settings = hypothesis.given, hypothesis.settings
except ModuleNotFoundError:  # pragma: no cover
    hypothesis = None


def _prop(argnames, hyp_strategies, fallback_cases, max_examples=30):
    """@given(**hyp_strategies) under hypothesis; otherwise a seeded
    pytest.mark.parametrize over ``fallback_cases``."""
    def deco(fn):
        if hypothesis is not None:
            return given(**hyp_strategies)(
                settings(max_examples=max_examples, deadline=None)(fn))
        return pytest.mark.parametrize(argnames, fallback_cases)(fn)
    return deco

from repro.core import agent_arena
from repro.core.agent_arena import AgentArena, _matvec_exact, _update_exact
from repro.core.allocator import OnlineCSC, ResourceAllocator
from repro.core.cost_functions import (
    Observation,
    absolute_vcpu_costs,
    absolute_vcpu_costs_batch,
    memory_costs,
    memory_costs_batch,
    proportional_vcpu_costs,
    proportional_vcpu_costs_batch,
)

GOLDEN_DIR = os.path.join(os.path.dirname(__file__), "goldens")


def _rand_obs(rng) -> Observation:
    alloc_v = int(rng.integers(1, 33))
    return Observation(
        exec_time_s=float(rng.uniform(0.05, 30.0)),
        slo_s=float(rng.uniform(0.1, 20.0)),
        alloc_vcpus=alloc_v,
        max_vcpus_used=float(rng.uniform(0.01, 1.0) * alloc_v),
        alloc_mem_mb=int(rng.integers(128, 8192)),
        max_mem_used_mb=float(rng.uniform(16.0, 6000.0)),
        oom_killed=bool(rng.random() < 0.05),
    )


def _pair(**kw):
    return (ResourceAllocator(engine="arena", **kw),
            ResourceAllocator(engine="legacy", **kw))


def _assert_same_weights(arena_alloc, legacy_alloc, fn):
    vw, vg, mw, mg = arena_alloc._arena.weights(fn)
    ag = legacy_alloc._agents[fn]
    assert np.array_equal(vw, np.asarray(ag.vcpu.w))
    assert np.array_equal(vg, np.asarray(ag.vcpu.g2))
    assert np.array_equal(mw, np.asarray(ag.mem.w))
    assert np.array_equal(mg, np.asarray(ag.mem.g2))


# ---------------------------------------------------------- equivalence
@_prop("seed,n_fns,n_ops",
       dict(seed=st.integers(0, 10_000), n_fns=st.integers(1, 6),
            n_ops=st.integers(5, 60)) if hypothesis else None,
       [(s, 1 + s % 6, 5 + (s * 11) % 56) for s in range(10)],
       max_examples=20)
def test_arena_matches_legacy_on_random_stream(seed, n_fns, n_ops):
    """Random interleaving of allocates and feedbacks over functions of
    mixed feature dims: every served Allocation and every final weight
    tensor must be bit-identical across engines."""
    rng = np.random.default_rng(seed)
    fns = [f"f{i}" for i in range(n_fns)]
    dims = {f: int(rng.integers(1, 7)) for f in fns}
    arena, legacy = _pair(vcpu_confidence=2, mem_confidence=3)
    touched = set()
    for _ in range(n_ops):
        fn = fns[int(rng.integers(n_fns))]
        x = rng.standard_normal(dims[fn]).astype(np.float32)
        if rng.random() < 0.5:
            size = float(rng.uniform(0, 3000))
            a = arena.allocate(fn, x, size)
            b = legacy.allocate(fn, x, size)
            assert a == b
        else:
            obs = _rand_obs(rng)
            arena.feedback(fn, x, obs)
            legacy.feedback(fn, x, obs)
            touched.add(fn)
        assert arena.agent_updates(fn) == legacy.agent_updates(fn)
    for fn in touched:
        _assert_same_weights(arena, legacy, fn)


def test_growth_across_doubling_boundary():
    """More functions than the initial arena capacity: slots grow by
    doubling and predictions stay identical to per-object agents."""
    rng = np.random.default_rng(7)
    arena, legacy = _pair(vcpu_confidence=1, mem_confidence=1)
    fns = [f"g{i}" for i in range(11)]  # initial capacity is 4
    xs = {f: rng.standard_normal(3).astype(np.float32) for f in fns}
    for rep in range(2):
        for f in fns:
            obs = _rand_obs(rng)
            arena.feedback(f, xs[f], obs)
            legacy.feedback(f, xs[f], obs)
    for f in fns:
        assert arena.allocate(f, xs[f]) == legacy.allocate(f, xs[f])
        _assert_same_weights(arena, legacy, f)
    eng = arena._arena
    va = eng._arena(arena.n_vcpu_classes, 3)
    assert va.capacity >= 11 and va.capacity % 4 == 0
    assert len({va.slot(f) for f in fns}) == len(fns)


def test_slot_release_and_reuse_isolation():
    """A released slot's next tenant starts as a FRESH agent, and
    bystander functions' weights are untouched by the reuse."""
    rng = np.random.default_rng(11)
    arena, legacy = _pair(vcpu_confidence=1, mem_confidence=1)
    xa = rng.standard_normal(3).astype(np.float32)
    xb = rng.standard_normal(3).astype(np.float32)
    for _ in range(5):
        obs = _rand_obs(rng)
        for al in (arena, legacy):
            al.feedback("a", xa, obs)
            al.feedback("bystander", xb, obs)
    before = arena._arena.weights("bystander")
    eng = arena._arena
    slot_a = eng._arena(arena.n_vcpu_classes, 3).slot("a")
    arena.release("a")
    legacy.release("a")
    assert arena.agent_updates("a") == (0, 0) == legacy.agent_updates("a")
    # new function lands in the recycled row...
    obs = _rand_obs(rng)
    arena.feedback("fresh", xa, obs)
    legacy.feedback("fresh", xa, obs)
    assert eng._arena(arena.n_vcpu_classes, 3).slot("fresh") == slot_a
    # ...and behaves exactly like a from-scratch agent
    assert arena.allocate("fresh", xa) == legacy.allocate("fresh", xa)
    _assert_same_weights(arena, legacy, "fresh")
    after = arena._arena.weights("bystander")
    for b, a in zip(before, after):
        assert np.array_equal(b, a)


# ------------------------------------------------------- flush ordering
def test_update_flushes_before_same_function_predict():
    """The ordering rule: a pending update for F is applied before any
    predict for F — same timestamp, same event-loop flush."""
    rng = np.random.default_rng(3)
    arena, legacy = _pair(vcpu_confidence=1, mem_confidence=1)
    x = rng.standard_normal(4).astype(np.float32)
    obs = _rand_obs(rng)
    arena.feedback("f", x, obs)
    legacy.feedback("f", x, obs)
    assert arena._arena._pending  # deferred, not yet applied
    a = arena.allocate("f", x)  # must flush first
    assert not arena._arena._pending
    assert a == legacy.allocate("f", x)
    _assert_same_weights(arena, legacy, "f")


def test_batch_predict_flushes_pending_and_matches_sequential():
    rng = np.random.default_rng(5)
    arena, legacy = _pair(vcpu_confidence=1, mem_confidence=1)
    xf = rng.standard_normal(3).astype(np.float32)
    xg = rng.standard_normal(6).astype(np.float32)
    for _ in range(3):
        obs = _rand_obs(rng)
        arena.feedback("f", xf, obs)
        legacy.feedback("f", xf, obs)
        obs2 = _rand_obs(rng)
        arena.feedback("g", xg, obs2)
        legacy.feedback("g", xg, obs2)
    batch = arena.allocate_batch([("f", xf, 0.0), ("g", xg, 0.0)])
    seq = [legacy.allocate("f", xf, 0.0), legacy.allocate("g", xg, 0.0)]
    assert batch == seq


def test_deferred_updates_do_not_leak_across_functions():
    """Pending updates for g must not affect a predict for f beyond
    what the sequential path would do (rows are disjoint state)."""
    rng = np.random.default_rng(9)
    arena, legacy = _pair(vcpu_confidence=1, mem_confidence=1)
    x = rng.standard_normal(2).astype(np.float32)
    obs = _rand_obs(rng)
    for al in (arena, legacy):
        al.feedback("f", x, obs)
        al.feedback("g", x, obs)
    assert arena.allocate("f", x) == legacy.allocate("f", x)
    _assert_same_weights(arena, legacy, "g")


# ------------------------------------------------------- cost functions
@_prop("seed,k,n",
       dict(seed=st.integers(0, 100_000), k=st.integers(1, 12),
            n=st.sampled_from([16, 32, 40])) if hypothesis else None,
       [(s * 131, 1 + s % 12, [16, 32, 40][s % 3]) for s in range(15)],
       max_examples=60)
def test_batched_cost_vectors_match_scalar(seed, k, n):
    rng = np.random.default_rng(seed)
    obs = [_rand_obs(rng) for _ in range(k)]
    for scalar, batched in (
        (absolute_vcpu_costs, absolute_vcpu_costs_batch),
        (proportional_vcpu_costs, proportional_vcpu_costs_batch),
    ):
        want = np.stack([scalar(o, n) for o in obs])
        assert np.array_equal(batched(obs, n), want)
    want = np.stack([memory_costs(o, n) for o in obs])
    assert np.array_equal(memory_costs_batch(obs, n), want)


# ------------------------------------------------------------- backends
@pytest.mark.parametrize("dim", [1, 2, 3, 4, 5, 6])
def test_numpy_backend_calibrates_for_all_feature_dims(dim):
    """Every Table-2 feature schema (dims 1-6) must take the
    dispatch-free path on this platform — the engine-speedup gate in
    sim_bench depends on it."""
    assert agent_arena.numpy_backend(dim)


@_prop("seed,dim,n",
       dict(seed=st.integers(0, 100_000), dim=st.integers(1, 6),
            n=st.sampled_from([16, 32, 40])) if hypothesis else None,
       [(s * 977, 1 + s % 6, [16, 32, 40][s % 3]) for s in range(18)],
       max_examples=40)
def test_numpy_kernels_bitwise_equal_reference(seed, dim, n):
    """_matvec_exact/_update_exact vs the jitted reference kernels —
    the property the calibration spot-checks, hammered harder here."""
    import jax.numpy as jnp

    rng = np.random.default_rng(seed)
    w = (rng.standard_normal((n, dim + 1)) * 10).astype(np.float32)
    g2 = (rng.random((n, dim + 1)) * 10).astype(np.float32)
    x = (rng.standard_normal(dim) * 3).astype(np.float32)
    costs = (1.0 + rng.random(n) * 30).astype(np.float32)
    xb = np.concatenate([x, np.ones(1, np.float32)])
    ref_c = np.asarray(agent_arena._csc_predict(jnp.asarray(w),
                                                jnp.asarray(x), n))
    assert np.array_equal(ref_c, _matvec_exact(w.copy(), xb))
    rw, rg = agent_arena._csc_update(
        jnp.asarray(w), jnp.asarray(g2), jnp.asarray(x),
        jnp.asarray(costs), jnp.asarray(np.float32(0.5)))
    gw, gg = _update_exact(w.copy(), g2.copy(), xb, costs, np.float32(0.5))
    assert np.array_equal(np.asarray(rw), gw)
    assert np.array_equal(np.asarray(rg), gg)


def test_jax_fallback_path_matches_legacy(monkeypatch):
    """With the NumPy backend forced off, the vmapped bucketed kernel
    (padding no-ops included) must still be bit-identical."""
    monkeypatch.setattr(agent_arena, "numpy_backend", lambda d: False)
    rng = np.random.default_rng(13)
    arena, legacy = _pair(vcpu_confidence=1, mem_confidence=1)
    fns = ["a", "b", "c"]  # k=3 pads to a 4-bucket
    xs = {f: rng.standard_normal(3).astype(np.float32) for f in fns}
    for _ in range(2):
        for f in fns:
            obs = _rand_obs(rng)
            arena.feedback(f, xs[f], obs)
            legacy.feedback(f, xs[f], obs)
    for f in fns:
        assert arena.allocate(f, xs[f]) == legacy.allocate(f, xs[f])
        _assert_same_weights(arena, legacy, f)
    # the batched predict (one fused vmapped dispatch, bucket-padded
    # 3 -> 4) must match sequential legacy predicts too
    batch = arena.allocate_batch([(f, xs[f], 0.0) for f in fns])
    seq = [legacy.allocate(f, xs[f], 0.0) for f in fns]
    assert batch == seq


def test_jax_fallback_chunks_past_max_bucket(monkeypatch):
    """A flush pass larger than _MAX_BUCKET must chunk into calibrated
    dispatch shapes and still match legacy exactly."""
    monkeypatch.setattr(agent_arena, "numpy_backend", lambda d: False)
    rng = np.random.default_rng(17)
    arena, legacy = _pair(vcpu_confidence=1, mem_confidence=1)
    fns = [f"c{i}" for i in range(agent_arena._MAX_BUCKET + 4)]
    xs = {f: rng.standard_normal(2).astype(np.float32) for f in fns}
    for f in fns:  # one pending update per function -> a 20-item pass
        obs = _rand_obs(rng)
        arena.feedback(f, xs[f], obs)
        legacy.feedback(f, xs[f], obs)
    batch = arena.allocate_batch([(f, xs[f], 0.0) for f in fns])
    seq = [legacy.allocate(f, xs[f], 0.0) for f in fns]
    assert batch == seq
    for f in fns:
        _assert_same_weights(arena, legacy, f)


def test_arena_growth_preserves_weights():
    ar = AgentArena(n_classes=4, dim=2, capacity=2)
    s0 = ar.slot("x")
    ar.w[s0] = 1.5
    for name in ("y", "z", "w2", "v"):
        ar.slot(name)
    assert ar.capacity == 8
    assert np.all(ar.w[ar.slot("x")] == 1.5)
    assert np.all(ar.w[ar.slot("v")] == 0.0)


# --------------------------------------------------------- legacy fixes
def test_predict_lazy_defers_host_sync():
    """Satellite fix: the legacy predict issues its dispatch without
    forcing a device->host sync; the int() at the consumption site is
    where the transfer happens — and it matches eager predict."""
    import jax

    rng = np.random.default_rng(1)
    m = OnlineCSC(8, 3)
    x = rng.standard_normal(3).astype(np.float32)
    m.update(x, (1.0 + rng.random(8)).astype(np.float32))
    lazy = m.predict_lazy(x)
    assert isinstance(lazy, jax.Array) and lazy.shape == ()
    assert int(lazy) == m.predict(x)


# --------------------------------------------------- simulator plumbing
def _sim_fixture():
    from repro.serving import baselines as B
    from repro.serving.profiles import build_input_pool, build_profiles

    profiles = build_profiles()
    pool = build_input_pool(seed=0)
    slo = B.build_slo_table(profiles, pool)
    return profiles, pool, slo


def _small_cfg(**over):
    from repro.serving.simulator import SimConfig

    base = dict(n_workers=2, vcpus_per_worker=32, physical_cores=32,
                mem_mb_per_worker=16 * 1024, vcpu_limit=32,
                retry_interval_s=0.5, queue_timeout_s=45.0, seed=0)
    base.update(over)
    return SimConfig(**base)


def _run_shabari(engine, arrivals, profiles, pool, slo, **cfg_over):
    from repro.serving import baselines as B
    from repro.serving.simulator import Simulator

    pol = B.ShabariPolicy(vcpu_confidence=2, mem_confidence=3, engine=engine)
    sim = Simulator(policy=pol, profiles=profiles, input_pool=pool,
                    slo_table=slo, cfg=_small_cfg(**cfg_over))
    return pol, sim.run(arrivals)


def test_engines_identical_through_simulator():
    """Full stack, recorded event stream: every per-invocation field
    identical across engines (not just the summary)."""
    from repro.serving.workload import ScenarioSpec, generate_scenario

    profiles, pool, slo = _sim_fixture()
    spec = ScenarioSpec(scenario="poisson-steady", rps=3.0,
                        duration_s=45.0, seed=0)
    trace = generate_scenario(
        spec, functions=sorted(profiles),
        inputs_per_function={f: len(pool[f]) for f in profiles})
    _, res_a = _run_shabari("arena", trace, profiles, pool, slo)
    _, res_l = _run_shabari("legacy", trace, profiles, pool, slo)
    assert len(res_a) == len(res_l)
    for a, b in zip(res_a, res_l):
        assert a == b


def test_same_timestamp_arrivals_batch_identically():
    """The event-loop microbatch (begin_arrival_batch) must serve the
    same allocations as one-by-one processing — including duplicate
    functions inside one timestamp."""
    from repro.serving.workload import Arrival

    profiles, pool, slo = _sim_fixture()
    fns = sorted(profiles)[:3]
    arrivals, iid = [], 0
    for t in (0.0, 0.0, 0.0, 5.0, 5.0, 9.0, 9.0, 9.0, 9.0):
        arrivals.append(Arrival(iid, t, fns[iid % len(fns)], 0))
        iid += 1
    pol_a, res_a = _run_shabari("arena", arrivals, profiles, pool, slo)
    pol_l, res_l = _run_shabari("legacy", arrivals, profiles, pool, slo)
    assert [(r.invocation_id, r.alloc_vcpus, r.alloc_mem_mb, r.finish_t)
            for r in res_a] == \
           [(r.invocation_id, r.alloc_vcpus, r.alloc_mem_mb, r.finish_t)
            for r in res_l]
    assert not pol_a._prealloc and not pol_a._features
    assert not pol_l._prealloc and not pol_l._features


def test_retry_payload_caches_featurization():
    """Satellite: under the legacy per-retry re-allocation path the
    featurized input + input size ride the retry payload — the
    Featurizer runs exactly once per invocation no matter how many
    retries re-enter allocate."""
    from repro.serving import baselines as B
    from repro.serving.simulator import Simulator
    from repro.serving.workload import Arrival

    profiles, pool, slo = _sim_fixture()
    pol = B.ShabariPolicy(engine="arena")
    calls = []
    orig = pol.featurizer.extract
    pol.featurizer.extract = lambda fn, it, meta, object_id="": (
        calls.append(fn) or orig(fn, it, meta, object_id))

    fn = "lrtrain"
    arrivals = [Arrival(0, 0.0, fn, 0)] + [
        Arrival(i, 1.5, fn, 0) for i in range(1, 6)]
    cfg = _small_cfg(n_workers=1, vcpus_per_worker=12, vcpu_limit=12,
                     physical_cores=12, legacy_retry_alloc=True)
    sim = Simulator(policy=pol, profiles=profiles, input_pool=pool,
                    slo_table=slo, cfg=cfg)
    results = sim.run(arrivals)
    assert len(results) == 6
    assert any(r.queued_s > 0 for r in results)  # retries happened
    assert len(calls) == 6  # one featurization per invocation, not per retry


# ------------------------------------------------------------- goldens
def test_legacy_engine_golden_pinned_and_bit_identical():
    """The legacy-engine snapshot must exist AND equal the arena-engine
    golden bit-for-bit — the 'arena is a pure fast path' claim, pinned
    in CI from both sides."""
    scenario = "heavy-tail-inputs"
    with open(os.path.join(GOLDEN_DIR, "legacy-engine",
                           f"{scenario}.json")) as f:
        legacy = json.load(f)
    with open(os.path.join(GOLDEN_DIR, f"{scenario}.json")) as f:
        main = json.load(f)
    assert legacy["policy"] == "shabari-legacy-engine"
    assert legacy["spec"] == main["spec"]
    assert legacy["summary"] == main["summary"]


@pytest.mark.slow
def test_legacy_engine_golden_reproduces():
    from repro.serving.golden import run_golden

    scenario = "heavy-tail-inputs"
    with open(os.path.join(GOLDEN_DIR, "legacy-engine",
                           f"{scenario}.json")) as f:
        want = json.load(f)["summary"]
    got = run_golden(scenario, legacy_engine=True)
    assert got == want
