"""Scheduler unit tests: the §5 routing priority and placement rules."""

import random

import pytest

from repro.core.allocator import Allocation
from repro.core.cluster import Cluster
from repro.core.scheduler import ShabariScheduler


def _mk(n_workers=4):
    cluster = Cluster(n_workers=n_workers, vcpus_per_worker=16,
                      mem_mb_per_worker=8192, vcpu_limit=16)
    return cluster, ShabariScheduler(cluster)


def test_exact_warm_container_preferred():
    cluster, sched = _mk()
    w = cluster.workers[0]
    exact = cluster.new_container(w, "f", 4, 512, now=0.0, warm_at=0.0)
    bigger = cluster.new_container(w, "f", 8, 1024, now=0.0, warm_at=0.0)
    d = sched.schedule("f", Allocation(4, 512, True), now=1.0)
    assert d.container is exact and not d.cold_start


def test_larger_warm_used_with_background_launch():
    cluster, sched = _mk()
    w = cluster.workers[0]
    big = cluster.new_container(w, "f", 8, 1024, now=0.0, warm_at=0.0)
    d = sched.schedule("f", Allocation(4, 512, True), now=1.0)
    assert d.container is big and not d.cold_start
    assert d.background_launch is not None
    _, v, m = d.background_launch
    assert (v, m) == (4, 512)  # exact size spawned for the future


def test_cold_start_on_home_server_then_spill():
    cluster, sched = _mk()
    home = sched._home_worker("f")
    d = sched.schedule("f", Allocation(4, 512, True), now=0.0)
    assert d.cold_start and d.background_launch[0].wid == home
    # fill the home server -> next worker in ring order
    cluster.workers[home].acquire(16, 0)
    d2 = sched.schedule("f", Allocation(4, 512, True), now=0.0)
    assert d2.background_launch[0].wid == (home + 1) % 4


def test_busy_and_cold_containers_not_reused():
    cluster, sched = _mk()
    w = cluster.workers[0]
    busy = cluster.new_container(w, "f", 4, 512, now=0.0, warm_at=0.0)
    busy.busy = True
    still_cold = cluster.new_container(w, "f", 4, 512, now=0.0, warm_at=99.0)
    d = sched.schedule("f", Allocation(4, 512, True), now=1.0)
    assert d.cold_start  # neither container usable


def test_no_capacity_anywhere_queues():
    cluster, sched = _mk(n_workers=2)
    for w in cluster.workers:
        w.acquire(16, 0)
    d = sched.schedule("f", Allocation(4, 512, True), now=0.0)
    assert d.queued


def test_openwhisk_mode_skips_larger_and_background():
    cluster = Cluster(n_workers=2, vcpus_per_worker=16,
                      mem_mb_per_worker=8192)
    sched = ShabariScheduler(cluster, route_larger=False,
                             background_launch=False)
    w = cluster.workers[0]
    cluster.new_container(w, "f", 8, 1024, now=0.0, warm_at=0.0)
    d = sched.schedule("f", Allocation(4, 512, True), now=1.0)
    assert d.cold_start  # larger warm container NOT used


def test_keep_alive_reaps_idle_containers():
    cluster, sched = _mk()
    w = cluster.workers[0]
    c = cluster.new_container(w, "f", 4, 512, now=0.0, warm_at=0.0)
    c.last_used = 0.0
    assert sched.reap_idle(now=601.0) == 1
    assert not w.containers


def test_packing_placement_fills_loaded_worker_first():
    cluster = Cluster(n_workers=3, vcpus_per_worker=16, mem_mb_per_worker=8192)
    sched = ShabariScheduler(cluster, placement="packing")
    cluster.workers[1].acquire(8, 100)
    d = sched.schedule("f", Allocation(4, 512, True), now=0.0)
    assert d.background_launch[0].wid == 1  # most-loaded with capacity


# ------------------------------------------------------------ invariants
def test_case_preference_ordering():
    """§5 priority: exact warm > smallest-larger warm > cold, checked by
    peeling the preferred option away one step at a time."""
    cluster, sched = _mk()
    w = cluster.workers[0]
    exact = cluster.new_container(w, "f", 4, 512, now=0.0, warm_at=0.0)
    larger_close = cluster.new_container(w, "f", 6, 768, now=0.0, warm_at=0.0)
    larger_far = cluster.new_container(w, "f", 8, 2048, now=0.0, warm_at=0.0)
    alloc = Allocation(4, 512, True)

    d = sched.schedule("f", alloc, now=1.0)
    assert d.container is exact and not d.cold_start

    exact.busy = True
    d = sched.schedule("f", alloc, now=1.0)
    assert d.container is larger_close and not d.cold_start

    larger_close.busy = True
    d = sched.schedule("f", alloc, now=1.0)
    assert d.container is larger_far and not d.cold_start

    larger_far.busy = True
    d = sched.schedule("f", alloc, now=1.0)
    assert d.container is None and d.cold_start


def test_capacity_never_exceeded_after_any_schedule_sequence():
    """Drive a seeded random schedule/finish sequence the way the
    simulator does and assert no decision ever pushes a worker past its
    vCPU limit or physical memory."""
    cluster, sched = _mk(n_workers=3)
    rng = random.Random(0)
    fns = ["f", "g", "h", "i"]
    running = []  # (container, vcpus, mem)
    now = 0.0
    for step in range(400):
        now += rng.random()
        if running and rng.random() < 0.4:
            c, v, m = running.pop(rng.randrange(len(running)))
            c.worker.release(v, m)
            c.busy = False
            c.last_used = now
            continue
        fn = rng.choice(fns)
        alloc = Allocation(rng.choice([2, 4, 8, 12]),
                           rng.choice([256, 512, 1024, 2048]), True)
        d = sched.schedule(fn, alloc, now)
        if d.queued:
            continue
        if d.container is not None:
            c = d.container
        else:
            w, v, m = d.background_launch
            c = cluster.new_container(w, fn, v, m, now, warm_at=now)
        c.busy = True
        c.worker.acquire(c.vcpus, c.mem_mb)
        running.append((c, c.vcpus, c.mem_mb))
        for w in cluster.workers:
            assert w.used_vcpus <= w.vcpu_limit
            assert w.used_mem_mb <= w.total_mem_mb
            assert w.used_vcpus >= 0 and w.used_mem_mb >= 0


def test_reap_never_reaps_busy_container():
    cluster, sched = _mk()
    w = cluster.workers[0]
    busy = cluster.new_container(w, "f", 4, 512, now=0.0, warm_at=0.0)
    busy.busy = True
    busy.last_used = 0.0  # long past keep-alive, but still running
    idle = cluster.new_container(w, "f", 4, 512, now=0.0, warm_at=0.0)
    idle.last_used = 0.0
    assert sched.reap_idle(now=10_000.0) == 1
    assert busy.cid in w.containers
    assert idle.cid not in w.containers
    # warm-index bookkeeping follows the reap
    assert busy.cid in w.by_function["f"]
    assert idle.cid not in w.by_function["f"]
