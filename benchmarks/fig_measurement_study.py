"""Figures 1-4: the measurement study driving Shabari's design.

* Fig 1a/2: input size vs execution time per vCPU allocation — positive
  correlation but NOT consistently linear (imageprocess, compress).
* Fig 1b/3: videoprocess utilization vs size — same-size inputs differ
  ~70% in vCPUs used depending on RESOLUTION; memory moves inversely.
* Fig 4: execution time & vCPU utilization vs allocation — bounded
  parallelism (compress/resnet scale then plateau; imageprocess pinned
  at 1 vCPU).
"""

from __future__ import annotations

import time

import numpy as np

from benchmarks.util import emit, time_us
from repro.serving.profiles import build_input_pool, build_profiles


def run() -> None:
    profiles = build_profiles()
    pool = build_input_pool()
    rng = np.random.default_rng(0)

    # --- Fig 2: nonlinearity of size->time -------------------------------
    t0 = time.perf_counter()
    for fn in ("imageprocess", "compress", "matmult"):
        prof = profiles[fn]
        metas = pool[fn]
        sizes = np.array([
            m.get("file_size", m.get("rows", 0.0)) for m in metas
        ])
        times = np.array([
            np.median([prof.exec_time(m, 16, rng) for _ in range(8)])
            for m in metas
        ])
        # linearity: R^2 of a linear fit in size
        A = np.vstack([sizes, np.ones_like(sizes)]).T
        coef, res, *_ = np.linalg.lstsq(A, times, rcond=None)
        ss_tot = np.sum((times - times.mean()) ** 2)
        r2 = 1.0 - (res[0] / ss_tot if len(res) else 0.0)
        corr = np.corrcoef(sizes, times)[0, 1]
        # Fig 2c: execution-time variability at the largest input
        big = metas[-1]
        reps = np.array([prof.exec_time(big, 16, rng) for _ in range(30)])
        var_pct = 100.0 * (reps.max() - reps.min()) / reps.min()
        emit(f"fig2_{fn}", (time.perf_counter() - t0) * 1e6,
             f"size_time_corr={corr:.3f};linear_r2={r2:.3f};"
             f"variability_at_max_pct={var_pct:.0f}")

    # --- Fig 3: videoprocess resolution effect ----------------------------
    prof = profiles["videoprocess"]
    by_res = {}
    for m in pool["videoprocess"]:
        by_res.setdefault((m["width"], m["height"]), []).append(m)
    lo = min(by_res)
    hi = max(by_res)
    v_lo = np.mean([prof.vcpus_used(m, 48) for m in by_res[lo]])
    v_hi = np.mean([prof.vcpus_used(m, 48) for m in by_res[hi]])
    m_lo = np.mean([prof.mem_used_mb(m) for m in by_res[lo]])
    m_hi = np.mean([prof.mem_used_mb(m) for m in by_res[hi]])
    emit("fig3_videoprocess", 0.0,
         f"vcpus_lowres={v_lo:.1f};vcpus_hires={v_hi:.1f};"
         f"vcpu_delta_pct={100*(v_lo-v_hi)/max(v_lo,1e-9):.0f};"
         f"mem_lowres={m_lo:.0f};mem_hires={m_hi:.0f}")

    # --- Fig 4: bounded parallelism ---------------------------------------
    for fn in ("compress", "resnet50", "imageprocess"):
        prof = profiles[fn]
        meta = pool[fn][-1]
        ts = {v: float(np.median([prof.exec_time(meta, v, rng)
                                  for _ in range(8)]))
              for v in (1, 4, 16, 32)}
        used = {v: prof.vcpus_used(meta, v) for v in (1, 4, 16, 32)}
        speedup = ts[1] / ts[32]
        emit(f"fig4_{fn}", 0.0,
             f"speedup_1to32={speedup:.2f};used@32={used[32]:.1f};"
             f"t1={ts[1]:.2f}s;t32={ts[32]:.2f}s")
