"""Figure 6: ML formulation study — per-function vs one-hot vs
per-input-type agents. Per-function must win on BOTH SLO compliance and
idle-vCPU waste (one-hot p90 idle ~5x worse in the paper)."""

from __future__ import annotations

import time

import numpy as np

from benchmarks.util import duration_s, emit
from repro.serving.experiment import run_experiment


def run() -> None:
    for mode in ("shabari", "shabari-one-hot", "shabari-per-input-type"):
        t0 = time.perf_counter()
        r = run_experiment(mode, rps=5.0, duration_s=duration_s(), seed=0,
                           keep_results=True)
        wasted = np.array([x.wasted_vcpus for x in r.results])
        p90 = float(np.percentile(wasted, 90)) if wasted.size else 0.0
        emit(f"fig6_{mode}", (time.perf_counter() - t0) * 1e6,
             f"slo_viol_pct={r.summary['slo_violation_pct']:.2f};"
             f"idle_vcpus_p90={p90:.2f};"
             f"idle_vcpus_p50={r.summary['wasted_vcpus_p50']:.2f}")
