"""Acquire-on-placement + admission-control sweep.

Four resource-lifecycle/admission modes on the saturating scenarios
(oversubscribe, flash-crowd, multi-cluster) plus the well-provisioned
poisson-steady control, all behind a 2-cluster spill-over front door on
the same total worker footprint:

* ``legacy``        — acquire-on-START (pre-reservation accounting): a
  cold-started container holds no load until warm, so arrivals inside
  the warm-up window see a free-looking worker and stack cold starts
  onto it (the Fifer over-commitment failure mode);
* ``reserve``       — acquire-on-PLACEMENT (the default): placed cold
  starts reserve capacity immediately, so ``Worker.fits`` and
  ``Router._load`` are truthful about committed-but-warming load;
* ``reserve+shed``  — reservation plus front-door shedding when every
  cluster's committed load exceeds the admission headroom;
* ``reserve+queue`` — reservation plus front-door queueing under the
  same condition (arrivals retry without probing any scheduler);
* ``reserve+slo``   — reservation plus SLO-native admission: shed
  exactly the invocations whose best fleet-wide completion-time
  estimate (per-input when calibrated) already exceeds their remaining
  SLO budget, instead of shedding on load alone.

The headline A/Bs (also CI gates, like sim_bench's retry check):

* truthful reservation accounting must not stack cold starts — p99
  cold-start queueing on ``oversubscribe`` must not be worse than
  legacy's — and must stay SLO-neutral on the uncontended
  ``poisson-steady`` control;
* SLO-native admission must DOMINATE load-headroom shedding on at
  least one saturating cell — no more violations from no more sheds
  (it drops only work that was doomed anyway) — and must stay neutral
  on the half-load control (shed nothing, change nothing).

  PYTHONPATH=src python -m benchmarks.admission_bench
"""

from __future__ import annotations

import time

import numpy as np

from benchmarks.util import QUICK, emit
from repro.serving import baselines as B
from repro.serving.experiment import make_policy
from repro.serving.profiles import build_input_pool, build_profiles
from repro.serving.simulator import SimConfig, Simulator, summarize
from repro.serving.workload import ScenarioSpec, generate_scenario

TOTAL_WORKERS = 8 if QUICK else 16
N_CLUSTERS = 2
DURATION_S = 240.0 if QUICK else 360.0
RPS = 2.0 if QUICK else 4.0  # offered load scales with the fleet
POLICY = "shabari"
HEADROOM = 0.95

# deeply saturating shapes (the admission regime — fleet-wide overload,
# unlike router_bench's hot-cluster-only loads) + a well-provisioned
# poisson-steady control where reservation accounting must be neutral.
# Each entry: (scenario params, rps scale) — the control runs at half
# the offered load so it genuinely has headroom.
SCENARIOS = {
    "oversubscribe": ({"load_mult": 4.0}, 1.0),
    "flash-crowd": ({"spike_mult": 8.0}, 1.0),
    "multi-cluster": ({}, 1.0),
    "poisson-steady": ({}, 0.5),
}

# the load-shedding arm the slo-dominance gate compares against: a
# tighter headroom than the default arm so its shed rate brackets
# reserve+slo's from above — the gate then reads "fewer violations
# from no more sheds" at a MATCHED (or conceded) shed rate, not a win
# bought by simply serving more traffic
MATCH_HEADROOM = 0.90

MODES = (
    ("legacy", dict(legacy_acquire=True)),
    ("reserve", dict()),
    ("reserve+shed", dict(admission="shed", admission_headroom=HEADROOM)),
    ("reserve+shed@match", dict(admission="shed",
                                admission_headroom=MATCH_HEADROOM)),
    ("reserve+queue", dict(admission="queue", admission_headroom=HEADROOM)),
    ("reserve+slo", dict(admission="slo")),
)
# the cells the slo-dominates-shed gate quantifies over (the control is
# gated separately, for neutrality)
SATURATING = ("oversubscribe", "flash-crowd", "multi-cluster")


def _cfg(**overrides) -> SimConfig:
    # vcpu_limit > physical_cores (the §6 userCPU knob): stacked
    # placements translate into co-runner contention, the failure mode
    # reservation accounting is meant to prevent
    return SimConfig(
        n_workers=TOTAL_WORKERS // N_CLUSTERS,
        n_clusters=N_CLUSTERS,
        routing="spill-over",
        vcpus_per_worker=44,
        physical_cores=32,
        mem_mb_per_worker=16 * 1024,
        vcpu_limit=44,
        retry_interval_s=1.0,
        queue_timeout_s=60.0,
        seed=0,
        **overrides,
    )


def _cold_queue_p99(results) -> float:
    q = [r.queued_s for r in results if r.cold_start]
    return float(np.percentile(q, 99)) if q else 0.0


def _run_cell(trace, profiles, pool, slo_table, overrides):
    policy = make_policy(POLICY, profiles, pool, slo_table, seed=0)
    sim = Simulator(policy=policy, profiles=profiles, input_pool=pool,
                    slo_table=slo_table, cfg=_cfg(**overrides))
    t0 = time.perf_counter()
    results = sim.run(trace)
    wall = time.perf_counter() - t0
    summary = summarize(results)
    summary["cold_queue_p99_s"] = _cold_queue_p99(results)
    eps = sim.events_processed / wall
    return summary, sim.router, eps


def run() -> None:
    profiles = build_profiles()
    pool = build_input_pool(seed=0)
    slo_table = B.build_slo_table(profiles, pool)

    cells = {}
    warmed = False
    for scenario, (params, rps_scale) in SCENARIOS.items():
        spec = ScenarioSpec(scenario=scenario, rps=RPS * rps_scale,
                            duration_s=DURATION_S, seed=0,
                            params=dict(params))
        trace = generate_scenario(
            spec, functions=sorted(profiles),
            inputs_per_function={f: len(pool[f]) for f in profiles},
        )
        if not warmed:
            # throwaway run: trace shabari's jit kernels so the one-time
            # compiles aren't charged to the first timed cell
            _run_cell(trace[: max(len(trace) // 4, 1)],
                      profiles, pool, slo_table, {})
            warmed = True
        for mode, overrides in MODES:
            summary, router, eps = _run_cell(
                trace, profiles, pool, slo_table, overrides)
            cells[(scenario, mode)] = summary
            emit(
                f"admission_bench.{scenario}.{mode}",
                1e6 / max(eps, 1e-9),
                f"n={len(trace)}"
                f"|events_per_sec={eps:.0f}"
                f"|slo_viol_pct={summary['slo_violation_pct']:.2f}"
                f"|cold_start_pct={summary['cold_start_pct']:.2f}"
                f"|cold_queue_p99_s={summary['cold_queue_p99_s']:.3f}"
                f"|wasted_vcpus_p95={summary['wasted_vcpus_p95']:.2f}"
                f"|timeout_pct={summary['timeout_pct']:.2f}"
                f"|shed_pct={summary['shed_pct']:.2f}"
                f"|admission_shed={router.admission_shed}"
                f"|admission_slo_shed={router.admission_slo_shed}"
                f"|admission_queue_events={router.admission_queue_events}",
            )

    # headline deltas: what acquire-on-placement buys over acquire-on-start
    for scenario in SCENARIOS:
        legacy, reserve = cells[(scenario, "legacy")], cells[(scenario, "reserve")]
        emit(
            f"admission_bench.{scenario}.reserve_delta",
            0.0,
            f"slo_viol_pts={reserve['slo_violation_pct'] - legacy['slo_violation_pct']:+.2f}"
            f"|cold_queue_p99_delta_s="
            f"{reserve['cold_queue_p99_s'] - legacy['cold_queue_p99_s']:+.3f}"
            f"|wasted_vcpus_p95_delta="
            f"{reserve['wasted_vcpus_p95'] - legacy['wasted_vcpus_p95']:+.2f}",
        )

    # CI gates for the tentpole semantics (mirrors sim_bench's retry gate)
    over_legacy = cells[("oversubscribe", "legacy")]
    over_reserve = cells[("oversubscribe", "reserve")]
    if over_reserve["cold_queue_p99_s"] > over_legacy["cold_queue_p99_s"] + 1e-9:
        raise RuntimeError(
            "acquire-on-placement stacked cold starts worse than legacy on "
            f"oversubscribe: p99 cold queueing {over_reserve['cold_queue_p99_s']:.3f}s "
            f"> {over_legacy['cold_queue_p99_s']:.3f}s")
    steady_legacy = cells[("poisson-steady", "legacy")]
    steady_reserve = cells[("poisson-steady", "reserve")]
    if (steady_reserve["slo_violation_pct"]
            > steady_legacy["slo_violation_pct"] + 0.5):
        raise RuntimeError(
            "acquire-on-placement raised SLO violations on the "
            f"poisson-steady control: {steady_reserve['slo_violation_pct']:.2f}% "
            f"> {steady_legacy['slo_violation_pct']:.2f}%")

    # CI gates for SLO-native admission. Dominance: on at least one
    # saturating cell, reserve+slo must beat the matched-shed-rate
    # load-headroom arm on SLO violations WITHOUT shedding more —
    # load-headroom shedding drops arrivals blindly when the fleet
    # looks full, so an estimate that sheds only doomed work should
    # serve more and violate less
    dominated = [
        s for s in SATURATING
        if (cells[(s, "reserve+slo")]["slo_violation_pct"]
            < cells[(s, "reserve+shed@match")]["slo_violation_pct"] - 1e-9
            and cells[(s, "reserve+slo")]["shed_pct"]
            <= cells[(s, "reserve+shed@match")]["shed_pct"] + 1e-9)
    ]
    if not dominated:
        raise RuntimeError(
            "slo admission failed to dominate load-headroom shedding "
            "(fewer violations from no more sheds) on any saturating "
            "cell: " + ", ".join(
                f"{s}: slo {cells[(s, 'reserve+slo')]['slo_violation_pct']:.2f}%"
                f"/{cells[(s, 'reserve+slo')]['shed_pct']:.2f}% shed vs "
                f"shed@match "
                f"{cells[(s, 'reserve+shed@match')]['slo_violation_pct']:.2f}%"
                f"/{cells[(s, 'reserve+shed@match')]['shed_pct']:.2f}% shed"
                for s in SATURATING))
    # Neutrality: on the half-load control the estimate clears every
    # SLO, so slo admission must shed nothing and change nothing
    steady_slo = cells[("poisson-steady", "reserve+slo")]
    if steady_slo["shed_pct"] > 0.0:
        raise RuntimeError(
            "slo admission shed servable work on the half-load "
            f"poisson-steady control: shed_pct={steady_slo['shed_pct']:.2f}%")
    if (steady_slo["slo_violation_pct"]
            > steady_reserve["slo_violation_pct"] + 0.5):
        raise RuntimeError(
            "slo admission raised SLO violations on the poisson-steady "
            f"control: {steady_slo['slo_violation_pct']:.2f}% > "
            f"{steady_reserve['slo_violation_pct']:.2f}%")


if __name__ == "__main__":
    print("name,us_per_call,derived")
    run()
