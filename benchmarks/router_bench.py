"""Front-door router sweep: 1/2/4 clusters x {hashing, spill-over,
estimate, random} routing on the flash-crowd and oversubscribe
scenarios.

The TOTAL worker footprint is held constant across cluster counts
(16 workers as 1x16, 2x8, or 4x4), so every row sees the same hardware
and the same arrival trace — only the routing layer differs. ``hashing``
pins each function to its home cluster (pure warm-pool locality, the
Fifer-style underutilization regime: hot functions saturate their
cluster while others idle); ``spill-over`` adds cold-start-aware load
spreading on top of the same locality; ``random`` is the load-oblivious
control. The headline row compares spill-over against hashing at each
cluster count: confining a hot function to a single cluster costs SLO
compliance that spill-over recovers.

  PYTHONPATH=src python -m benchmarks.router_bench
"""

from __future__ import annotations

import time

from benchmarks.util import QUICK, emit
from repro.serving import baselines as B
from repro.serving.experiment import make_policy
from repro.serving.profiles import build_input_pool, build_profiles
from repro.serving.simulator import SimConfig, Simulator, summarize
from repro.serving.workload import ScenarioSpec, generate_scenario

TOTAL_WORKERS = 8 if QUICK else 16
DURATION_S = 240.0 if QUICK else 360.0
RPS = 1.0 if QUICK else 2.0  # offered load scales with the fleet
CLUSTER_COUNTS = (1, 2, 4)
ROUTINGS = ("hashing", "spill-over", "estimate", "random")
# Loads chosen so the HOT cluster saturates while total capacity still
# suffices — the front-door regime. (At sustained whole-fleet overload
# no routing policy can win: shedding work via queue timeouts then
# "beats" completing it late on every per-invocation metric.)
SCENARIOS = {
    "flash-crowd": {"spike_mult": 4.0},
    "oversubscribe": {"load_mult": 1.6},
}
POLICY = "shabari"


def _cfg(n_clusters: int, routing: str) -> SimConfig:
    # vcpu_limit > physical_cores: workers oversubscribe vCPUs (the §6
    # userCPU knob, 90-vCPU allocs on 96 cores in the paper's testbed),
    # so per-worker demand above the core count slows co-runners down —
    # the regime where load-aware routing pays and load-oblivious
    # admission keeps piling demand onto already-contended workers
    return SimConfig(
        n_workers=TOTAL_WORKERS // n_clusters,
        n_clusters=n_clusters,
        routing=routing,
        vcpus_per_worker=44,
        physical_cores=32,
        mem_mb_per_worker=16 * 1024,
        vcpu_limit=44,
        retry_interval_s=1.0,
        queue_timeout_s=60.0,
        seed=0,
    )


def _run_cell(trace, profiles, pool, slo_table, n_clusters, routing):
    policy = make_policy(POLICY, profiles, pool, slo_table, seed=0)
    sim = Simulator(policy=policy, profiles=profiles, input_pool=pool,
                    slo_table=slo_table, cfg=_cfg(n_clusters, routing))
    t0 = time.perf_counter()
    summary = summarize(sim.run(trace))
    wall = time.perf_counter() - t0
    return summary, sim.router, wall


def run() -> None:
    profiles = build_profiles()
    pool = build_input_pool(seed=0)
    slo_table = B.build_slo_table(profiles, pool)

    for scenario, params in SCENARIOS.items():
        spec = ScenarioSpec(scenario=scenario, rps=RPS, duration_s=DURATION_S,
                            seed=0, params=dict(params))
        trace = generate_scenario(
            spec, functions=sorted(profiles),
            inputs_per_function={f: len(pool[f]) for f in profiles},
        )
        viol = {}
        for n_clusters in CLUSTER_COUNTS:
            for routing in ROUTINGS:
                if n_clusters == 1 and routing != "hashing":
                    # one cluster: hashing/spill-over/random are
                    # identical (estimate differs via warming-soon
                    # binding even at c1 — covered by
                    # tests/test_router.py's single-cluster estimate
                    # case; this sweep compares front-door policies)
                    continue
                summary, router, wall = _run_cell(
                    trace, profiles, pool, slo_table, n_clusters, routing)
                viol[(n_clusters, routing)] = summary["slo_violation_pct"]
                emit(
                    f"router_bench.{scenario}.c{n_clusters}.{routing}",
                    wall * 1e6 / max(len(trace), 1),
                    f"n={len(trace)}"
                    f"|slo_viol_pct={summary['slo_violation_pct']:.2f}"
                    f"|cold_start_pct={summary['cold_start_pct']:.2f}"
                    f"|timeout_pct={summary['timeout_pct']:.2f}"
                    f"|spills_warm={router.spills_warm}"
                    f"|spills_cold={router.spills_cold}"
                    f"|binds_warming={router.binds_warming}",
                )
        for n_clusters in CLUSTER_COUNTS[1:]:
            gain = (viol[(n_clusters, "hashing")]
                    - viol[(n_clusters, "spill-over")])
            emit(
                f"router_bench.{scenario}.c{n_clusters}.spill_gain",
                0.0,
                f"slo_viol_reduction_pts={gain:.2f}"
                f"|hashing={viol[(n_clusters, 'hashing')]:.2f}"
                f"|spill-over={viol[(n_clusters, 'spill-over')]:.2f}",
            )


if __name__ == "__main__":
    print("name,us_per_call,derived")
    run()
