"""Figure 9: allocation timeline for one input of a multi-threaded
(matmult) vs single-threaded (sentiment) function. Shabari must explore
allocations for matmult but keep sentiment pinned near 1 vCPU even when
its SLO is violated (it learns more vCPUs cannot help)."""

from __future__ import annotations

import time

import numpy as np

from benchmarks.util import duration_s, emit
from repro.serving.experiment import run_experiment


def run() -> None:
    t0 = time.perf_counter()
    r = run_experiment("shabari", rps=5.0, duration_s=duration_s(), seed=0,
                       keep_results=True)
    for fn in ("matmult", "sentiment"):
        res = sorted(
            (x for x in r.results if x.function == fn),
            key=lambda x: x.arrival_t,
        )
        if not res:
            emit(f"fig9_{fn}", 0.0, "n=0")
            continue
        allocs = [x.alloc_vcpus for x in res]
        unique = len(set(allocs))
        tail = allocs[len(allocs) // 2:]
        emit(f"fig9_{fn}", (time.perf_counter() - t0) * 1e6,
             f"n={len(res)};unique_vcpu_allocs={unique};"
             f"second_half_mean_alloc={np.mean(tail):.2f};"
             f"second_half_max_alloc={max(tail)};"
             f"viol_pct={100*sum(x.slo_violated for x in res)/len(res):.1f}")
