"""Roofline table (deliverable g): reads the dry-run JSON artifacts and
emits one CSV row per (arch x shape x mesh) with the three roofline
terms, the dominant bottleneck, and the useful-FLOPs ratio."""

from __future__ import annotations

import json
from pathlib import Path

from benchmarks.util import emit

DRYRUN_DIR = Path("experiments/dryrun")


def run() -> None:
    files = sorted(DRYRUN_DIR.glob("*.json")) if DRYRUN_DIR.exists() else []
    if not files:
        emit("roofline_report", 0.0, "no_dryrun_artifacts_found_run_dryrun_first")
        return
    for f in files:
        rec = json.loads(f.read_text())
        if rec.get("skipped"):
            emit(f"roofline_{f.stem}", 0.0, f"skipped={rec['reason']}")
            continue
        r = rec["roofline"]
        emit(
            f"roofline_{f.stem}",
            rec.get("compile_s", 0.0) * 1e6,
            f"compute_s={r['compute_s']:.4f};memory_s={r['memory_s']:.4f};"
            f"collective_s={r['collective_s']:.4f};dominant={r['dominant']};"
            f"useful_flops_ratio={r['useful_flops_ratio']:.3f}",
        )
