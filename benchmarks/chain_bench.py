"""Slack-aware vs slack-blind chain scheduling A/B.

Both chain scenarios run under the full chain stack — estimate routing
scored against the remaining end-to-end budget, SLO-native admission
with the warm-hold fork, and Fifer pre-warm — with ONLY the slack
decomposition flipped between arms:

* ``aware``   — per-stage allowance = remaining e2e budget minus the
  longest expected path below the stage (critical-path analysis): a
  slack-rich stage tolerates a local cold start or a front-door hold,
  a critical-path stage gets exactly what the chain can still afford;
* ``uniform`` — the slack-blind baseline: the e2e SLO split evenly
  over the chain's depth, measured per stage, no routing budget.

Every cell is the MEAN over a fixed seed panel: a single heavy-tailed
trace is dominated by where its few giant inputs happen to land, so a
one-seed comparison measures the seed, not the scheduler. The panel is
deterministic, so the gates are exact, not statistical.

Headline CI gates (hard failures, mirroring admission_bench):

* on at least one full-load chain cell, ``aware`` must beat
  ``uniform`` on mean end-to-end SLO violations
  (``chain_e2e_viol_pct`` counts late completions AND failed
  instances against starts);
* on the half-load control the arms' overall per-invocation
  ``slo_violation_pct`` must agree within 0.5 pt — with headroom,
  slack awareness must not distort ordinary SLO outcomes to buy its
  chain wins.

  PYTHONPATH=src python -m benchmarks.chain_bench
"""

from __future__ import annotations

import time

from benchmarks.util import QUICK, emit
from repro.serving import baselines as B
from repro.serving.chains import default_chains
from repro.serving.experiment import make_policy
from repro.serving.profiles import build_input_pool, build_profiles
from repro.serving.simulator import SimConfig, Simulator, summarize
from repro.serving.workload import ScenarioSpec, generate_scenario

DURATION_S = 240.0 if QUICK else 360.0
RPS = 4.0
POLICY = "shabari"
SEEDS = tuple(range(5))

# (scenario, chain key, rps scale): the two full-load chain cells the
# dominance gate quantifies over, plus the half-load neutrality control
CELLS = (
    ("chain-pipeline", "pipeline", 1.0),
    ("fan-out-join", "fanout", 1.0),
    ("chain-pipeline@half", "pipeline", 0.5),
)
ARMS = ("aware", "uniform")

MEAN_KEYS = ("chain_e2e_viol_pct", "chain_e2e_p50_s", "chain_e2e_p99_s",
             "chain_failed", "chain_started", "slo_violation_pct",
             "shed_pct", "admission_slo_held")


def _cfg(chain_key: str, slack: str) -> SimConfig:
    # 8 x 32-vCPU workers: big enough that Poisson bursts average out
    # at half load (the neutrality control needs genuine headroom),
    # small enough that full load genuinely contends
    return SimConfig(
        n_workers=8,
        vcpus_per_worker=32,
        physical_cores=32,
        mem_mb_per_worker=16 * 1024,
        vcpu_limit=32,
        retry_interval_s=1.0,
        queue_timeout_s=45.0,
        seed=0,
        routing="estimate",
        admission="slo",
        chains=(default_chains()[chain_key],),
        chain_slack=slack,
    )


def _run_once(trace, profiles, pool, slo_table, chain_key, slack):
    policy = make_policy(POLICY, profiles, pool, slo_table, seed=0)
    sim = Simulator(policy=policy, profiles=profiles, input_pool=pool,
                    slo_table=slo_table, cfg=_cfg(chain_key, slack))
    t0 = time.perf_counter()
    results = sim.run(trace)
    wall = time.perf_counter() - t0
    summary = summarize(results)
    summary.update(sim.chain_summary())
    summary["admission_slo_held"] = float(sim.router.admission_slo_held)
    return summary, sim.events_processed, wall


def run() -> None:
    profiles = build_profiles()
    pool = build_input_pool(seed=0)
    slo_table = B.build_slo_table(profiles, pool)
    functions = sorted(profiles)
    inputs_per_function = {f: len(pool[f]) for f in profiles}

    def trace_for(name, rps_scale, seed):
        spec = ScenarioSpec(scenario=name.split("@")[0],
                            rps=RPS * rps_scale, duration_s=DURATION_S,
                            seed=seed)
        return generate_scenario(spec, functions=functions,
                                 inputs_per_function=inputs_per_function)

    # throwaway warmup so first-touch compile/caching isn't charged to
    # the first timed cell
    warm_trace = trace_for(CELLS[0][0], CELLS[0][2], SEEDS[0])
    _run_once(warm_trace[: max(len(warm_trace) // 4, 1)],
              profiles, pool, slo_table, CELLS[0][1], "aware")

    cells = {}
    for name, chain_key, rps_scale in CELLS:
        for slack in ARMS:
            acc = {k: 0.0 for k in MEAN_KEYS}
            events = wall = 0.0
            n = 0
            for seed in SEEDS:
                trace = trace_for(name, rps_scale, seed)
                summary, ev, w = _run_once(
                    trace, profiles, pool, slo_table, chain_key, slack)
                for k in MEAN_KEYS:
                    acc[k] += summary[k]
                events += ev
                wall += w
                n += len(trace)
            mean = {k: v / len(SEEDS) for k, v in acc.items()}
            cells[(name, slack)] = mean
            eps = events / wall
            emit(
                f"chain_bench.{name}.{slack}",
                1e6 / max(eps, 1e-9),
                f"n={n}"
                f"|seeds={len(SEEDS)}"
                f"|events_per_sec={eps:.0f}"
                f"|chain_e2e_viol_pct={mean['chain_e2e_viol_pct']:.2f}"
                f"|chain_e2e_p50_s={mean['chain_e2e_p50_s']:.3f}"
                f"|chain_e2e_p99_s={mean['chain_e2e_p99_s']:.3f}"
                f"|chain_failed={mean['chain_failed']:.1f}"
                f"|chain_started={mean['chain_started']:.1f}"
                f"|slo_viol_pct={mean['slo_violation_pct']:.2f}"
                f"|shed_pct={mean['shed_pct']:.2f}"
                f"|held={mean['admission_slo_held']:.1f}",
            )

    for name, _, _ in CELLS:
        aware, uni = cells[(name, "aware")], cells[(name, "uniform")]
        emit(
            f"chain_bench.{name}.aware_delta",
            0.0,
            f"e2e_viol_pts="
            f"{aware['chain_e2e_viol_pct'] - uni['chain_e2e_viol_pct']:+.2f}"
            f"|slo_viol_pts="
            f"{aware['slo_violation_pct'] - uni['slo_violation_pct']:+.2f}"
            f"|e2e_p99_delta_s="
            f"{aware['chain_e2e_p99_s'] - uni['chain_e2e_p99_s']:+.3f}",
        )

    # CI gate 1: slack awareness must WIN somewhere it has slack to
    # spend — strictly fewer mean end-to-end violations on >= 1 loaded
    # cell
    loaded = [name for name, _, scale in CELLS if scale >= 1.0]
    won = [
        name for name in loaded
        if (cells[(name, "aware")]["chain_e2e_viol_pct"]
            < cells[(name, "uniform")]["chain_e2e_viol_pct"] - 1e-9)
    ]
    if not won:
        raise RuntimeError(
            "slack-aware chain scheduling failed to beat the uniform "
            "SLO split on mean end-to-end violations on any loaded "
            "cell: " + ", ".join(
                f"{name}: aware "
                f"{cells[(name, 'aware')]['chain_e2e_viol_pct']:.2f}% vs "
                f"uniform "
                f"{cells[(name, 'uniform')]['chain_e2e_viol_pct']:.2f}%"
                for name in loaded))
    # CI gate 2: per-invocation SLO neutrality on the half-load
    # control (+-0.5 pt)
    control = "chain-pipeline@half"
    gap = (cells[(control, "aware")]["slo_violation_pct"]
           - cells[(control, "uniform")]["slo_violation_pct"])
    if abs(gap) > 0.5:
        raise RuntimeError(
            "slack-aware scheduling is not SLO-neutral on the half-load "
            f"control: aware-uniform gap {gap:+.2f} pts "
            f"(aware {cells[(control, 'aware')]['slo_violation_pct']:.2f}%"
            f" vs uniform "
            f"{cells[(control, 'uniform')]['slo_violation_pct']:.2f}%)")


if __name__ == "__main__":
    print("name,us_per_call,derived")
    run()
