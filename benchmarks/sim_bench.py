"""Simulator-core throughput: events/sec on a 10k-invocation trace.

Three A/Bs, each against a pre-fix path kept behind a config switch:

* ``legacy_scans`` — the incremental simulator core (per-worker
  contention aggregates + per-function warm-container index) vs the
  O(running)/O(containers) scans. Both runs must produce identical
  ``summarize()`` metrics — the refactor is a pure fast path. The trace
  is heavy-tail-inputs under memory-centric scheduling (vCPU
  oversubscription), which holds hundreds of invocations running
  concurrently.

* ``legacy_retry_alloc`` — the cached-retry-allocation fix vs the
  pre-fix retry path that re-ran ``policy.allocate`` (a jit'd jax
  dispatch per predict for learning policies) on every 0.5 s retry of a
  queued invocation. Measured on the oversubscribe scenario, whose
  retry storm is where the per-retry dispatch dominated. For
  deterministic-allocation policies the fix is metric-neutral even
  under saturation (same allocation on every retry), which the bench
  asserts with static-large; for learning policies only QUEUED
  invocations can change (they now keep their first prediction). Note
  the legacy leg re-runs only the PREDICT per retry — the featurized
  input rides the retry payload either way — so the ratio isolates the
  dispatch cost the fix removed.

* allocator engine — the batched agent arena
  (``ResourceAllocator(engine="arena")``, see repro.core.agent_arena)
  vs the per-function-object path (``engine="legacy"``: two jit'd JAX
  dispatches per allocate and two per feedback) with the SHABARI
  policy on the same heavy-tail trace as the scans A/B. This is the
  learning-path throughput gate: the arena must be ≥3x events/sec AND
  bit-identical in summary metrics (enforced here, not just printed).

Plus the ``image_cache_on`` cell — the scans-A/B trace re-run with
``SimConfig(image_cache=ImageCacheSpec())`` so the per-node layer
cache's per-cold-start overhead has its own events/sec floor next to
the ``incremental`` cell's (the cache-off default path) — and the
``scale`` tier (run_stack_ab + run_scale): a full-stack A/B —
array-backed event loop + indexed scans + agent arena vs
``legacy_event_loop`` + ``legacy_scans`` + the legacy engine, hard-
failing on any summary-metric difference — and the azure-24h cell, one
production day at Azure-trace scale (~100k invocations under
BENCH_QUICK=1, 1M otherwise) whose events/sec floor rides
benchmarks/baselines.json.

  PYTHONPATH=src python -m benchmarks.sim_bench
"""

from __future__ import annotations

import time

from benchmarks.util import QUICK, emit
from repro.core.image_cache import ImageCacheSpec
from repro.serving import baselines as B
from repro.serving.experiment import make_policy
from repro.serving.profiles import build_input_pool, build_profiles
from repro.serving.simulator import SimConfig, Simulator, summarize
from repro.serving.workload import ScenarioSpec, generate_scenario

N_INVOCATIONS = 2_000 if QUICK else 10_000
DURATION_S = 400.0
SCENARIO = "heavy-tail-inputs"
POLICY = "static-large"


def _run_once(trace, profiles, pool, slo_table, *, legacy: bool,
              policy: str = POLICY):
    # uncapped worker resources: every invocation is admitted, so the
    # event count is pure start/finish work and the running set grows to
    # the hundreds (retry storms would otherwise dominate both sides)
    cfg = SimConfig(seed=0, vcpu_limit=100_000,
                    mem_mb_per_worker=4_000_000, legacy_scans=legacy)
    pol = make_policy(policy, profiles, pool, slo_table, seed=0)
    sim = Simulator(policy=pol, profiles=profiles, input_pool=pool,
                    slo_table=slo_table, cfg=cfg)
    t0 = time.perf_counter()
    results = sim.run(trace)
    wall = time.perf_counter() - t0
    return sim.events_processed, wall, summarize(results)


# ------------------------------------------------------- image-cache cell
def run_cache_cell(trace, profiles, pool, slo_table) -> None:
    """events/sec with the per-node image/layer cache ENABLED on the
    same uncapped heavy-tail cell as the scans A/B (floor rides
    benchmarks/baselines.json). The cache adds per-cold-start work —
    a residual-pull rank across the walk plus the pull bookkeeping —
    so this cell prices that overhead next to ``sim_bench.incremental``
    (the identical run with ``image_cache=None``, the zero-overhead
    default)."""
    cfg = SimConfig(seed=0, vcpu_limit=100_000,
                    mem_mb_per_worker=4_000_000,
                    image_cache=ImageCacheSpec())
    pol = make_policy(POLICY, profiles, pool, slo_table, seed=0)
    sim = Simulator(policy=pol, profiles=profiles, input_pool=pool,
                    slo_table=slo_table, cfg=cfg)
    t0 = time.perf_counter()
    results = sim.run(trace)
    wall = time.perf_counter() - t0
    ev = sim.events_processed
    s = summarize(results)
    emit("sim_bench.image_cache_on", wall / ev * 1e6,
         f"n={len(trace)}|events={ev}|events_per_sec={ev / wall:.0f}"
         f"|cold_start_pct={s['cold_start_pct']:.2f}")


# --------------------------------------------------- allocator-engine A/B
def run_engine_ab(trace, profiles, pool, slo_table) -> None:
    """Shabari (learning) policy: agent arena vs per-object agents.

    Hard gates, mirroring the scans A/B's metrics_identical check:
    summary metrics must be BIT-identical (the arena is a pure fast
    path — its NumPy backend is calibrated against the jit kernels and
    its flush ordering reproduces the sequential update/predict
    interleaving), and the arena must clear 3x events/sec."""
    # throwaway warm-up: run the arena's one-time backend calibration
    # (NumPy-vs-JAX bit-identity proofs + crossover benchmark, which
    # trace XLA programs) and the legacy jit kernels outside both timed
    # legs — every feature schema is dim 1-6
    from repro.core import agent_arena

    agent_arena.calibrate(range(1, 7))
    warm = trace[: max(len(trace) // 10, 1)]
    _run_once(warm, profiles, pool, slo_table, legacy=False,
              policy="shabari")
    _run_once(warm, profiles, pool, slo_table, legacy=False,
              policy="shabari-legacy-engine")

    ev_l, wall_l, sum_l = _run_once(
        trace, profiles, pool, slo_table, legacy=False,
        policy="shabari-legacy-engine")
    ev_a, wall_a, sum_a = _run_once(
        trace, profiles, pool, slo_table, legacy=False, policy="shabari")
    eps_l = ev_l / wall_l
    eps_a = ev_a / wall_a
    emit("sim_bench.shabari_legacy_engine", wall_l / ev_l * 1e6,
         f"n={len(trace)}|events={ev_l}|events_per_sec={eps_l:.0f}")
    emit("sim_bench.shabari_arena", wall_a / ev_a * 1e6,
         f"n={len(trace)}|events={ev_a}|events_per_sec={eps_a:.0f}")
    emit("sim_bench.engine_speedup", 0.0,
         f"x{eps_a / eps_l:.2f}|metrics_identical={sum_a == sum_l}")
    if sum_a != sum_l:
        raise RuntimeError(
            "agent arena changed shabari summary metrics vs the legacy "
            f"engine: {sum_a} != {sum_l}")
    if eps_a < 3.0 * eps_l:
        raise RuntimeError(
            "agent arena below the 3x events/sec target: "
            f"{eps_a:.0f} vs legacy {eps_l:.0f}")


# --------------------------------------------------------- retry-path A/B
RETRY_RPS = 1.5 if QUICK else 2.0
RETRY_DURATION_S = 120.0 if QUICK else 240.0


def _run_retry(trace, profiles, pool, slo_table, *, policy: str, legacy: bool):
    # a small saturating cluster: the oversubscribe backlog retries every
    # 0.5 s, so the retry path dominates event count
    cfg = SimConfig(n_workers=4, vcpus_per_worker=32, physical_cores=32,
                    mem_mb_per_worker=16 * 1024, vcpu_limit=32,
                    retry_interval_s=0.5, queue_timeout_s=60.0, seed=0,
                    legacy_retry_alloc=legacy)
    pol = make_policy(policy, profiles, pool, slo_table, seed=0)
    sim = Simulator(policy=pol, profiles=profiles, input_pool=pool,
                    slo_table=slo_table, cfg=cfg)
    t0 = time.perf_counter()
    results = sim.run(trace)
    wall = time.perf_counter() - t0
    return sim.events_processed, wall, summarize(results)


def run_retry_ab(profiles, pool, slo_table) -> None:
    spec = ScenarioSpec(scenario="oversubscribe", rps=RETRY_RPS,
                        duration_s=RETRY_DURATION_S, seed=0,
                        params={"load_mult": 4.0})
    trace = generate_scenario(
        spec, functions=sorted(profiles),
        inputs_per_function={f: len(pool[f]) for f in profiles},
    )

    # throwaway warm-up: trace shabari's jit kernels (predict/update per
    # feature-dim shape) so the one-time compiles are charged to neither
    # timed leg below
    _run_retry(trace[: max(len(trace) // 4, 1)], profiles, pool, slo_table,
               policy="shabari", legacy=False)

    # the events/sec win: shabari's jit'd predict no longer runs per retry
    ev_legacy, wall_legacy, _ = _run_retry(
        trace, profiles, pool, slo_table, policy="shabari", legacy=True)
    ev_fast, wall_fast, _ = _run_retry(
        trace, profiles, pool, slo_table, policy="shabari", legacy=False)
    eps_legacy = ev_legacy / wall_legacy
    eps_fast = ev_fast / wall_fast
    emit("sim_bench.retry_legacy", wall_legacy / ev_legacy * 1e6,
         f"n={len(trace)}|events={ev_legacy}|events_per_sec={eps_legacy:.0f}")
    emit("sim_bench.retry_cached", wall_fast / ev_fast * 1e6,
         f"n={len(trace)}|events={ev_fast}|events_per_sec={eps_fast:.0f}")

    # metric neutrality: with a deterministic allocation the cached and
    # re-predicted retry paths are the same decision sequence, queued
    # and timed-out invocations included
    _, _, sum_legacy = _run_retry(
        trace, profiles, pool, slo_table, policy="static-large", legacy=True)
    _, _, sum_fast = _run_retry(
        trace, profiles, pool, slo_table, policy="static-large", legacy=False)
    emit("sim_bench.retry_speedup", 0.0,
         f"x{eps_fast / eps_legacy:.2f}"
         f"|static_metrics_identical={sum_fast == sum_legacy}")
    if sum_fast != sum_legacy:
        # this is the CI gate for the cached-retry fast path, not just
        # a printed observation
        raise RuntimeError(
            "retry-allocation cache changed metrics for a deterministic "
            f"policy: {sum_fast} != {sum_legacy}")


# ------------------------------------------------------------- scale tier
# The azure-24h tier: one production day at Azure-trace scale. Quick mode
# compresses the diurnal cycle into a tenth of a day at the same rate
# (~100k invocations); the full sweep runs the whole 24 h (1M). The
# fleet is deliberately saturated at its peak with queue-mode admission
# holding the backlog at the front door, so the event mix matches what a
# production-scale replay looks like: a long retry tail around the
# diurnal crest plus warm/cold starts everywhere else.
SCALE_N = 100_000 if QUICK else 1_000_000
SCALE_DURATION_S = 8_640.0 if QUICK else 86_400.0


def _scale_config() -> SimConfig:
    return SimConfig(seed=0, n_clusters=10, n_workers=16,
                     admission="queue", admission_headroom=0.85,
                     queue_timeout_s=90.0, retry_interval_s=0.5)


def run_scale(profiles, pool, slo_table) -> None:
    """events/sec on the azure-24h trace (floor in baselines.json)."""
    spec = ScenarioSpec(scenario="azure-24h", rps=SCALE_N / SCALE_DURATION_S,
                        duration_s=SCALE_DURATION_S, seed=11)
    t0 = time.perf_counter()
    trace = generate_scenario(
        spec, functions=sorted(profiles),
        inputs_per_function={f: len(pool[f]) for f in profiles},
    )
    build_wall = time.perf_counter() - t0
    pol = make_policy(POLICY, profiles, pool, slo_table, seed=0)
    sim = Simulator(policy=pol, profiles=profiles, input_pool=pool,
                    slo_table=slo_table, cfg=_scale_config())
    t0 = time.perf_counter()
    results = sim.run(trace)
    wall = time.perf_counter() - t0
    ev = sim.events_processed
    timeouts = sum(r.timed_out for r in results)
    emit("sim_bench.scale_azure24h", wall / ev * 1e6,
         f"n={len(trace)}|events={ev}|events_per_sec={ev / wall:.0f}"
         f"|trace_build_s={build_wall:.2f}|timeouts={timeouts}")


def _run_stack(trace, profiles, pool, slo_table, *, legacy: bool):
    """One leg of the full-stack A/B: the fast stack (array-backed
    event loop + indexed scans + agent arena) or the whole legacy stack
    (global heapq loop + O(running)/O(containers) scans + per-object
    agent engine). Same uncapped cell as the scans A/B."""
    cfg = SimConfig(seed=0, vcpu_limit=100_000,
                    mem_mb_per_worker=4_000_000,
                    legacy_event_loop=legacy, legacy_scans=legacy)
    pol = make_policy("shabari-legacy-engine" if legacy else "shabari",
                      profiles, pool, slo_table, seed=0)
    sim = Simulator(policy=pol, profiles=profiles, input_pool=pool,
                    slo_table=slo_table, cfg=cfg)
    t0 = time.perf_counter()
    results = sim.run(trace)
    wall = time.perf_counter() - t0
    return sim.events_processed, wall, summarize(results)


def run_stack_ab(trace, profiles, pool, slo_table) -> None:
    """Learning-policy full-stack A/B on the heavy-tail trace.

    Every layer of the legacy stack is a metric-identical slow path
    (the event loop, the scan refactor, and the agent engine are all
    pure fast paths), so the summaries must match BIT-identically —
    enforced with a hard failure, same as the engine A/B. The speedup
    floor here is a conservative in-bench backstop; the real
    events/sec floors ride benchmarks/baselines.json where the
    best-of-3 re-measure absorbs machine noise."""
    # jit kernels + arena calibration are already warm: run() calls
    # run_engine_ab first, which traces both engines on this trace
    ev_l, wall_l, sum_l = _run_stack(
        trace, profiles, pool, slo_table, legacy=True)
    ev_f, wall_f, sum_f = _run_stack(
        trace, profiles, pool, slo_table, legacy=False)
    eps_l = ev_l / wall_l
    eps_f = ev_f / wall_f
    emit("sim_bench.scale_legacy_stack", wall_l / ev_l * 1e6,
         f"n={len(trace)}|events={ev_l}|events_per_sec={eps_l:.0f}")
    emit("sim_bench.scale_fast_stack", wall_f / ev_f * 1e6,
         f"n={len(trace)}|events={ev_f}|events_per_sec={eps_f:.0f}")
    emit("sim_bench.scale_stack_speedup", 0.0,
         f"x{eps_f / eps_l:.2f}|metrics_identical={sum_f == sum_l}")
    if sum_f != sum_l:
        raise RuntimeError(
            "fast stack changed shabari summary metrics vs the full "
            f"legacy stack: {sum_f} != {sum_l}")
    if eps_f < 4.0 * eps_l:
        raise RuntimeError(
            "fast stack below the 4x events/sec backstop vs the full "
            f"legacy stack: {eps_f:.0f} vs {eps_l:.0f}")


def run() -> None:
    profiles = build_profiles()
    pool = build_input_pool(seed=0)
    slo_table = B.build_slo_table(profiles, pool)
    spec = ScenarioSpec(
        scenario=SCENARIO, rps=N_INVOCATIONS / DURATION_S,
        duration_s=DURATION_S, seed=0,
    )
    trace = generate_scenario(
        spec, functions=sorted(profiles),
        inputs_per_function={f: len(pool[f]) for f in profiles},
    )

    ev_legacy, wall_legacy, sum_legacy = _run_once(
        trace, profiles, pool, slo_table, legacy=True)
    ev_fast, wall_fast, sum_fast = _run_once(
        trace, profiles, pool, slo_table, legacy=False)

    eps_legacy = ev_legacy / wall_legacy
    eps_fast = ev_fast / wall_fast
    emit("sim_bench.legacy_scan", wall_legacy / ev_legacy * 1e6,
         f"n={len(trace)}|events={ev_legacy}|events_per_sec={eps_legacy:.0f}")
    emit("sim_bench.incremental", wall_fast / ev_fast * 1e6,
         f"n={len(trace)}|events={ev_fast}|events_per_sec={eps_fast:.0f}")
    emit("sim_bench.speedup", 0.0,
         f"x{eps_fast / eps_legacy:.2f}|metrics_identical={sum_fast == sum_legacy}")

    run_cache_cell(trace, profiles, pool, slo_table)
    run_engine_ab(trace, profiles, pool, slo_table)
    run_retry_ab(profiles, pool, slo_table)
    run_stack_ab(trace, profiles, pool, slo_table)
    run_scale(profiles, pool, slo_table)


if __name__ == "__main__":
    print("name,us_per_call,derived")
    run()
