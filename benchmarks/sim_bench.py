"""Simulator-core throughput: events/sec on a 10k-invocation trace.

A/B of the incremental simulator core — per-worker contention
aggregates (Worker.active_demand_vcpus / active_net_gbps, maintained on
start/finish) plus the per-function warm-container index — against the
pre-refactor O(running)/O(containers) scans, kept behind
``SimConfig.legacy_scans``. Both runs must produce identical
``summarize()`` metrics — the refactor is a pure fast path.

The trace is heavy-tail-inputs under memory-centric scheduling (vCPU
oversubscription), which holds hundreds of invocations running
concurrently — the regime where the per-event scans made large traces
slow to evaluate.

  PYTHONPATH=src python -m benchmarks.sim_bench
"""

from __future__ import annotations

import time

from benchmarks.util import QUICK, emit
from repro.serving import baselines as B
from repro.serving.experiment import make_policy
from repro.serving.profiles import build_input_pool, build_profiles
from repro.serving.simulator import SimConfig, Simulator, summarize
from repro.serving.workload import ScenarioSpec, generate_scenario

N_INVOCATIONS = 2_000 if QUICK else 10_000
DURATION_S = 400.0
SCENARIO = "heavy-tail-inputs"
POLICY = "static-large"


def _run_once(trace, profiles, pool, slo_table, *, legacy: bool):
    # uncapped worker resources: every invocation is admitted, so the
    # event count is pure start/finish work and the running set grows to
    # the hundreds (retry storms would otherwise dominate both sides)
    cfg = SimConfig(seed=0, vcpu_limit=100_000,
                    mem_mb_per_worker=4_000_000, legacy_scans=legacy)
    policy = make_policy(POLICY, profiles, pool, slo_table, seed=0)
    sim = Simulator(policy=policy, profiles=profiles, input_pool=pool,
                    slo_table=slo_table, cfg=cfg)
    t0 = time.perf_counter()
    results = sim.run(trace)
    wall = time.perf_counter() - t0
    return sim.events_processed, wall, summarize(results)


def run() -> None:
    profiles = build_profiles()
    pool = build_input_pool(seed=0)
    slo_table = B.build_slo_table(profiles, pool)
    spec = ScenarioSpec(
        scenario=SCENARIO, rps=N_INVOCATIONS / DURATION_S,
        duration_s=DURATION_S, seed=0,
    )
    trace = generate_scenario(
        spec, functions=sorted(profiles),
        inputs_per_function={f: len(pool[f]) for f in profiles},
    )

    ev_legacy, wall_legacy, sum_legacy = _run_once(
        trace, profiles, pool, slo_table, legacy=True)
    ev_fast, wall_fast, sum_fast = _run_once(
        trace, profiles, pool, slo_table, legacy=False)

    eps_legacy = ev_legacy / wall_legacy
    eps_fast = ev_fast / wall_fast
    emit("sim_bench.legacy_scan", wall_legacy / ev_legacy * 1e6,
         f"n={len(trace)}|events={ev_legacy}|events_per_sec={eps_legacy:.0f}")
    emit("sim_bench.incremental", wall_fast / ev_fast * 1e6,
         f"n={len(trace)}|events={ev_fast}|events_per_sec={eps_fast:.0f}")
    emit("sim_bench.speedup", 0.0,
         f"x{eps_fast / eps_legacy:.2f}|metrics_identical={sum_fast == sum_legacy}")


if __name__ == "__main__":
    print("name,us_per_call,derived")
    run()
