"""Figure 10: cold-start mitigation — Shabari's scheduler must roughly
halve the fraction of invocations with cold starts vs the same
allocator on the default (OpenWhisk-style) scheduler."""

from __future__ import annotations

import time

from benchmarks.util import duration_s, emit
from repro.serving.experiment import run_experiment


def run() -> None:
    vals = {}
    for name in ("shabari", "shabari-openwhisk-sched", "parrotfish",
                 "static-large"):
        t0 = time.perf_counter()
        r = run_experiment(name, rps=6.0, duration_s=duration_s(), seed=0)
        vals[name] = r.summary
        emit(f"fig10_{name}", (time.perf_counter() - t0) * 1e6,
             f"cold_start_pct={r.summary['cold_start_pct']:.2f};"
             f"viol_with_cold_pct={r.summary['cold_viol_pct']:.2f};"
             f"slo_viol_pct={r.summary['slo_violation_pct']:.2f}")
    red = 100.0 * (
        vals["shabari-openwhisk-sched"]["cold_start_pct"]
        - vals["shabari"]["cold_start_pct"]
    ) / max(vals["shabari-openwhisk-sched"]["cold_start_pct"], 1e-9)
    emit("fig10_headline", 0.0, f"cold_start_reduction_vs_default_sched_pct={red:.1f}")
