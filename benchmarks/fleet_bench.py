"""Topology-aware vs transfer-blind estimate routing A/B over a WAN
fleet (repro.core.fleet).

Two clusters of identical machines sit across a constrained WAN link
(1 Gb/s, 50 ms). The simulator always charges input-payload transfer on
remote placements; the arms differ only in what the ROUTER believes:

* ``aware``       — estimate routing, ``estimate_transfer=True``: the
  candidate score prices each remote candidate with the invocation's
  own payload over the actual link (plus the per-machine cold curve and
  exec-speed factor);
* ``blind``       — the same estimate routing with
  ``estimate_transfer=False``: remote spills look free, exactly the
  pre-fleet cost model;
* ``spill-over``  — load-ranked spilling, the transfer-oblivious
  reference heuristic.

On heavy-tail inputs (compress payloads reach 2 GB -> 16 s over the
link) the blind forecaster happily ships the biggest payloads to the
far cluster whenever home looks busy; the aware forecaster keeps them
home and spills the cheap-to-move work instead. The uniform-fleet
control runs the same arms on the same machines with free links, where
``estimate_transfer`` must be inert (the pricing path is skipped
entirely on a free topology).

CI gates:

* ``aware`` must BEAT ``blind`` on SLO-violation % in at least one
  heavy-tail WAN cell — a refactor that drops transfer from the
  candidate score (or stops threading per-input sizes into ``route``)
  fails here;
* ``aware`` and ``blind`` must be SLO-identical (within 0.5 pts) on the
  uniform-fleet free-link control — transfer pricing must never
  activate, let alone tax, a topology with nothing to price.

  PYTHONPATH=src python -m benchmarks.fleet_bench
"""

from __future__ import annotations

import time

from benchmarks.util import QUICK, emit
from repro.core.fleet import ClusterSpec, FleetSpec, Link, MachineType, Topology
from repro.serving import baselines as B
from repro.serving.experiment import make_policy
from repro.serving.profiles import build_input_pool, build_profiles
from repro.serving.simulator import SimConfig, Simulator, summarize
from repro.serving.workload import ScenarioSpec, generate_scenario

TOTAL_WORKERS = 8 if QUICK else 16
N_CLUSTERS = 2
DURATION_S = 240.0 if QUICK else 360.0
RPS = 1.0 if QUICK else 2.0
POLICY = "shabari"

# estimate_bench's per-worker shape (vcpu_limit > physical cores, so
# placements translate into §5 contention) on an explicit FleetSpec
_MACHINE = MachineType(
    name="bench-32c", physical_cores=32, vcpus=44, mem_mb=16 * 1024,
    vcpu_limit=44)


def _fleet(topology: Topology) -> FleetSpec:
    per_cluster = ClusterSpec(
        machines=((_MACHINE, TOTAL_WORKERS // N_CLUSTERS),))
    return FleetSpec(clusters=(per_cluster,) * N_CLUSTERS,
                     topology=topology)


WAN_FLEET = _fleet(Topology(default_link=Link(gbps=1.0, latency_s=0.05)))
UNIFORM_FLEET = _fleet(Topology())

# label -> SimConfig overrides; all three arms run the SAME fleet per
# cell, so deltas isolate the router's cost model
ARMS = (
    ("aware", dict(routing="estimate")),
    ("blind", dict(routing="estimate", estimate_transfer=False)),
    ("spill-over", dict(routing="spill-over")),
)

# cell -> (params, rps scale, fleet): the WAN cells pair heavy-tail
# input sizes with enough spill pressure that routing decides who pays
# the link (at 2x base load the hot cluster saturates while the fleet
# still has capacity — transfer becomes painful but avoidable; at
# lighter load spills are too rare to separate the arms, and under
# fleet-wide overload the link is the least of anyone's problems). The
# -xl variant steepens the input skew so more of the spilled bytes are
# tail payloads. The control is the same machines at half load with
# free links.
SCENARIOS = {
    "wan-spill": ({}, 2.0, WAN_FLEET),
    "wan-spill-xl": ({"skew": 5.0}, 2.0, WAN_FLEET),
    "uniform-control": ({}, 0.5, UNIFORM_FLEET),
}
# bench-cell key -> registered scenario name (where they differ: the
# -xl variant and the control only rename a registered generator)
_SCENARIO_NAME = {"wan-spill-xl": "wan-spill",
                  "uniform-control": "poisson-steady"}
# the cells the aware-beats-blind gate quantifies over
WAN_CELLS = ("wan-spill", "wan-spill-xl")
# a third trace seed: router_bench uses 0 and estimate_bench 1 on
# overlapping fleets/loads, so an independent seed keeps this sweep
# from replaying their exact simulations
TRACE_SEED = 2


def _cfg(fleet: FleetSpec, **overrides) -> SimConfig:
    return SimConfig(
        fleet=fleet,
        retry_interval_s=1.0,
        queue_timeout_s=60.0,
        seed=0,
        **overrides,
    )


def _run_cell(trace, profiles, pool, slo_table, fleet, overrides):
    policy = make_policy(POLICY, profiles, pool, slo_table, seed=0)
    sim = Simulator(policy=policy, profiles=profiles, input_pool=pool,
                    slo_table=slo_table, cfg=_cfg(fleet, **overrides))
    t0 = time.perf_counter()
    summary = summarize(sim.run(trace))
    wall = time.perf_counter() - t0
    eps = sim.events_processed / wall
    return summary, sim.router, eps


def run() -> None:
    profiles = build_profiles()
    pool = build_input_pool(seed=0)
    slo_table = B.build_slo_table(profiles, pool)

    cells = {}
    warmed = False
    for cell, (params, rps_scale, fleet) in SCENARIOS.items():
        scenario = _SCENARIO_NAME.get(cell, cell)
        spec = ScenarioSpec(scenario=scenario, rps=RPS * rps_scale,
                            duration_s=DURATION_S, seed=TRACE_SEED,
                            params=dict(params))
        trace = generate_scenario(
            spec, functions=sorted(profiles),
            inputs_per_function={f: len(pool[f]) for f in profiles},
        )
        if not warmed:
            # throwaway run: trace shabari's jit kernels so the one-time
            # compiles aren't charged to the first timed cell
            _run_cell(trace[: max(len(trace) // 4, 1)], profiles, pool,
                      slo_table, fleet, dict(routing="spill-over"))
            warmed = True
        for label, overrides in ARMS:
            summary, router, eps = _run_cell(
                trace, profiles, pool, slo_table, fleet, overrides)
            cells[(cell, label)] = summary
            emit(
                f"fleet_bench.{cell}.{label}",
                1e6 / max(eps, 1e-9),
                f"n={len(trace)}"
                f"|events_per_sec={eps:.0f}"
                f"|slo_viol_pct={summary['slo_violation_pct']:.2f}"
                f"|cold_start_pct={summary['cold_start_pct']:.2f}"
                f"|timeout_pct={summary['timeout_pct']:.2f}"
                f"|wasted_vcpus_p95={summary['wasted_vcpus_p95']:.2f}"
                f"|spills_warm={router.spills_warm}"
                f"|spills_cold={router.spills_cold}"
                f"|binds_warming={router.binds_warming}",
            )

    # headline deltas: what pricing the payload's transfer buys
    for cell in SCENARIOS:
        blind = cells[(cell, "blind")]
        aware = cells[(cell, "aware")]
        emit(
            f"fleet_bench.{cell}.aware_gain",
            0.0,
            f"slo_viol_reduction_pts="
            f"{blind['slo_violation_pct'] - aware['slo_violation_pct']:.2f}"
            f"|blind={blind['slo_violation_pct']:.2f}"
            f"|aware={aware['slo_violation_pct']:.2f}",
        )

    # CI gate 1: transfer-aware routing must beat transfer-blind on at
    # least one heavy-tail WAN cell
    wins = [
        c for c in WAN_CELLS
        if (cells[(c, "aware")]["slo_violation_pct"]
            < cells[(c, "blind")]["slo_violation_pct"] - 1e-9)
    ]
    if not wins:
        raise RuntimeError(
            "transfer-aware estimate routing failed to beat transfer-blind "
            "on any WAN cell: " + ", ".join(
                f"{c}: aware {cells[(c, 'aware')]['slo_violation_pct']:.2f}%"
                f" vs blind {cells[(c, 'blind')]['slo_violation_pct']:.2f}%"
                for c in WAN_CELLS))

    # CI gate 2: on free links the estimate_transfer flag must be inert
    ctrl_aware = cells[("uniform-control", "aware")]
    ctrl_blind = cells[("uniform-control", "blind")]
    drift = abs(ctrl_aware["slo_violation_pct"]
                - ctrl_blind["slo_violation_pct"])
    if drift > 0.5:
        raise RuntimeError(
            "estimate_transfer changed behavior on the free-link uniform "
            f"control: aware {ctrl_aware['slo_violation_pct']:.2f}% vs "
            f"blind {ctrl_blind['slo_violation_pct']:.2f}%")


if __name__ == "__main__":
    print("name,us_per_call,derived")
    run()
