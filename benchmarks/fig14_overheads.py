"""Figure 14: Shabari's overheads — featurization, model prediction,
model update, scheduler decision. The paper measures 2-4 ms predictions
and 4-5 ms updates (Vowpal Wabbit over gRPC); our in-process jit'd
agents are microseconds once traced — recorded as-is."""

from __future__ import annotations

import numpy as np

from benchmarks.util import emit, time_us
from repro.core.allocator import Allocation, ResourceAllocator
from repro.core.cost_functions import Observation
from repro.core.featurizer import Featurizer
from repro.core.scheduler import ShabariScheduler
from repro.core.cluster import Cluster
from repro.serving.profiles import build_input_pool, build_profiles


def run() -> None:
    feat = Featurizer()
    alloc = ResourceAllocator(vcpu_confidence=0, mem_confidence=0)
    profiles = build_profiles()
    pool = build_input_pool()

    # featurization per input type (matmult needs file-open in the paper
    # -> 20-35 ms there; metadata-only types are ~free)
    for fn in ("matmult", "imageprocess", "videoprocess", "speech2text"):
        meta = pool[fn][-1]
        t = time_us(lambda: feat.extract(fn, profiles[fn].input_type, meta),
                    iters=200)
        emit(f"fig14_featurize_{fn}", t, "per_invocation")

    # prediction / update
    x = feat.extract("matmult", "matrix", pool["matmult"][0])
    obs = Observation(exec_time_s=1.0, slo_s=1.4, alloc_vcpus=8,
                      max_vcpus_used=6.0, alloc_mem_mb=1024,
                      max_mem_used_mb=700.0)
    alloc.feedback("matmult", x, obs)  # trace the jits
    emit("fig14_predict", time_us(lambda: alloc.allocate("matmult", x),
                                  iters=200), "per_invocation")
    emit("fig14_update", time_us(lambda: alloc.feedback("matmult", x, obs),
                                 iters=200), "off_critical_path")

    # scheduler decision
    sched = ShabariScheduler(Cluster())
    a = Allocation(vcpus=8, mem_mb=1024, vcpu_predicted=True,
                   mem_predicted=True)
    emit("fig14_schedule", time_us(lambda: sched.schedule("matmult", a, 0.0),
                                   iters=200), "per_invocation")
