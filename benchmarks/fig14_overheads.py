"""Figure 14: Shabari's overheads — featurization, model prediction,
model update, scheduler decision. The paper measures 2-4 ms predictions
and 4-5 ms updates (Vowpal Wabbit over gRPC); our in-process agents are
tens of microseconds — recorded as-is, for BOTH allocator engines:

* ``legacy``  — one jit'd JAX dispatch per per-function agent per call
  (~107 µs predict+argmin+sync, ~130 µs update on the bench machine);
* ``arena``   — the batched agent arena (repro.core.agent_arena): the
  predict is a dispatch-free calibrated-NumPy matvec over both agents'
  stacked regressors, and the update is an amortized enqueue whose
  cost is paid at the next flush (emitted separately).

The NumPy-vs-JAX crossover (where a batched JAX dispatch starts to
beat the stacked NumPy path) is emitted per feature dim — this is the
measurement behind the arena's per-call backend pick."""

from __future__ import annotations

import numpy as np

from benchmarks.util import emit, time_us
from repro.core import agent_arena
from repro.core.allocator import Allocation, ResourceAllocator
from repro.core.cost_functions import Observation
from repro.core.featurizer import Featurizer
from repro.core.scheduler import ShabariScheduler
from repro.core.cluster import Cluster
from repro.serving.profiles import build_input_pool, build_profiles


def run() -> None:
    feat = Featurizer()
    profiles = build_profiles()
    pool = build_input_pool()
    agent_arena.calibrate(range(1, 7))  # one-time, outside the timings

    # featurization per input type (matmult needs file-open in the paper
    # -> 20-35 ms there; metadata-only types are ~free)
    for fn in ("matmult", "imageprocess", "videoprocess", "speech2text"):
        meta = pool[fn][-1]
        t = time_us(lambda: feat.extract(fn, profiles[fn].input_type, meta),
                    iters=200)
        emit(f"fig14_featurize_{fn}", t, "per_invocation")

    # prediction / update, per engine
    x = feat.extract("matmult", "matrix", pool["matmult"][0])
    obs = Observation(exec_time_s=1.0, slo_s=1.4, alloc_vcpus=8,
                      max_vcpus_used=6.0, alloc_mem_mb=1024,
                      max_mem_used_mb=700.0)
    for engine in ("legacy", "arena"):
        alloc = ResourceAllocator(vcpu_confidence=0, mem_confidence=0,
                                  engine=engine)
        alloc.feedback("matmult", x, obs)  # trace jits / assign slots
        alloc.allocate("matmult", x)
        emit(f"fig14_predict_{engine}",
             time_us(lambda: alloc.allocate("matmult", x), iters=200),
             "per_invocation")
        # the arena defers updates: feedback is an enqueue, the work
        # happens in flush — emit both so the split is visible
        emit(f"fig14_update_{engine}",
             time_us(lambda: alloc.feedback("matmult", x, obs), iters=200),
             "off_critical_path|arena=enqueue_only" if engine == "arena"
             else "off_critical_path")
        if engine == "arena":
            def enqueue_and_flush():
                alloc.feedback("matmult", x, obs)
                alloc.flush()
            emit("fig14_update_arena_flushed",
                 time_us(enqueue_and_flush, iters=200), "off_critical_path")

    # the per-call backend pick: stacked-NumPy vs one batched JAX
    # dispatch crossover, in stacked rows (0 = NumPy not bit-identical
    # for that dim, so the JAX kernel always serves it)
    for dim in (1, 3, 6):
        emit(f"fig14_numpy_crossover_rows_dim{dim}", 0.0,
             f"rows={agent_arena.numpy_crossover_rows(dim)}"
             f"|numpy_backend={agent_arena.numpy_backend(dim)}")

    # scheduler decision
    sched = ShabariScheduler(Cluster())
    a = Allocation(vcpus=8, mem_mb=1024, vcpu_predicted=True,
                   mem_predicted=True)
    emit("fig14_schedule", time_us(lambda: sched.schedule("matmult", a, 0.0),
                                   iters=200), "per_invocation")
