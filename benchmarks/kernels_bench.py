"""Kernel microbenchmarks: reference-path CPU timings (what the engine
actually runs in this container) + interpret-mode kernel/oracle parity.

Wall-clock TPU kernel timing is impossible here (interpret mode executes
the kernel body in Python); the TPU-side performance story lives in the
roofline analysis (EXPERIMENTS.md §Roofline). What this records:
us_per_call of the jnp reference ops on CPU, and derived max-abs-err of
each Pallas kernel against its oracle on a production-relevant shape."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.util import emit, time_us
from repro.kernels import ops, ref


def run() -> None:
    key = jax.random.PRNGKey(0)
    ks = jax.random.split(key, 8)

    # flash attention, prefill-like shape
    B, S, H, Hkv, D = 1, 1024, 8, 2, 128
    q = jax.random.normal(ks[0], (B, S, H, D), jnp.bfloat16)
    k = jax.random.normal(ks[1], (B, S, Hkv, D), jnp.bfloat16)
    v = jax.random.normal(ks[2], (B, S, Hkv, D), jnp.bfloat16)
    fref = jax.jit(lambda q, k, v: ref.flash_attention_ref(q, k, v, causal=True))
    fref(q, k, v).block_until_ready()
    t = time_us(lambda: fref(q, k, v).block_until_ready(), iters=5)
    out = ops.flash_attention(q, k, v, causal=True, block_q=256, block_kv=256)
    err = float(jnp.max(jnp.abs(out.astype(jnp.float32)
                                - fref(q, k, v).astype(jnp.float32))))
    emit("kernel_flash_attention", t, f"ref_cpu;max_err_vs_oracle={err:.1e}")

    # decode attention, 8k window
    from repro.models.kv_cache import ring_positions, ring_valid
    B, W, H, Hkv, D = 4, 8192, 32, 8, 128
    q1 = jax.random.normal(ks[3], (B, 1, H, D), jnp.bfloat16)
    kc = jax.random.normal(ks[4], (B, W, Hkv, D), jnp.bfloat16)
    vc = jax.random.normal(ks[5], (B, W, Hkv, D), jnp.bfloat16)
    pos = jnp.full((B,), W + 5, jnp.int32)
    kvp, kvv = ring_positions(pos, W), ring_valid(pos, W)
    dref = jax.jit(ref.decode_attention_ref)
    dref(q1, kc, vc, kvp, kvv, pos).block_until_ready()
    t = time_us(lambda: dref(q1, kc, vc, kvp, kvv, pos).block_until_ready(), iters=5)
    out = ops.decode_attention(q1, kc, vc, kvp, kvv, pos, block_kv=1024)
    err = float(jnp.max(jnp.abs(out.astype(jnp.float32)
                                - dref(q1, kc, vc, kvp, kvv, pos).astype(jnp.float32))))
    emit("kernel_decode_attention", t, f"ref_cpu;max_err_vs_oracle={err:.1e}")

    # ssd scan, mamba2-1.3b layer shape
    B, S, H, P, N, Q = 2, 1024, 16, 64, 128, 256
    x = jax.random.normal(ks[6], (B, S, H, P), jnp.float32) * 0.5
    dt = jax.nn.softplus(jax.random.normal(ks[7], (B, S, H)))
    A = -jnp.exp(jax.random.normal(key, (H,)) * 0.3)
    B_ = jax.random.normal(ks[0], (B, S, N)) * 0.5
    C_ = jax.random.normal(ks[1], (B, S, N)) * 0.5
    sref = jax.jit(lambda *a: ref.ssd_scan_ref(*a, chunk=Q))
    sref(x, dt, A, B_, C_)[0].block_until_ready()
    t = time_us(lambda: sref(x, dt, A, B_, C_)[0].block_until_ready(), iters=5)
    y, _ = ops.ssd_scan(x, dt, A, B_, C_, Q)
    err = float(jnp.max(jnp.abs(y - sref(x, dt, A, B_, C_)[0])))
    emit("kernel_ssd_scan", t, f"ref_cpu;max_err_vs_oracle={err:.1e}")

    # moe grouped matmul, mixtral-like per-device shard
    E, C, D2, F = 8, 256, 512, 1792
    buf = jax.random.normal(ks[2], (E, C, D2), jnp.bfloat16)
    w = jax.random.normal(ks[3], (E, D2, F), jnp.bfloat16) * (D2 ** -0.5)
    gref = jax.jit(ref.moe_gmm_ref)
    gref(buf, w).block_until_ready()
    t = time_us(lambda: gref(buf, w).block_until_ready(), iters=5)
    out = ops.moe_gmm(buf, w, block_c=128, block_d=256, block_f=256)
    err = float(jnp.max(jnp.abs(out.astype(jnp.float32)
                                - gref(buf, w).astype(jnp.float32))))
    emit("kernel_moe_gmm", t, f"ref_cpu;max_err_vs_oracle={err:.1e}")
