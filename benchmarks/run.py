"""Benchmark harness: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows. Set BENCH_QUICK=1 for the
abbreviated sweep (shorter traces, fewer grid points).

  PYTHONPATH=src python -m benchmarks.run [--only fig8,table3]
"""

from __future__ import annotations

import argparse
import sys
import time
import traceback

MODULES = [
    ("measurement", "benchmarks.fig_measurement_study"),
    ("fig6", "benchmarks.fig6_formulations"),
    ("fig7", "benchmarks.fig7_ablations"),
    ("fig8", "benchmarks.fig8_e2e"),
    ("fig9", "benchmarks.fig9_timeline"),
    ("fig10", "benchmarks.fig10_cold_starts"),
    ("fig11_13", "benchmarks.fig11_13_sensitivity"),
    ("fig14", "benchmarks.fig14_overheads"),
    ("table3", "benchmarks.table3_container_sizes"),
    ("scenario_matrix", "benchmarks.scenario_matrix"),
    ("sim_bench", "benchmarks.sim_bench"),
    ("router_bench", "benchmarks.router_bench"),
    ("kernels", "benchmarks.kernels_bench"),
    ("roofline", "benchmarks.roofline_report"),
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated subset of module keys")
    args = ap.parse_args()
    only = set(args.only.split(",")) if args.only else None

    print("name,us_per_call,derived")
    failures = []
    for key, modname in MODULES:
        if only and key not in only:
            continue
        t0 = time.time()
        try:
            mod = __import__(modname, fromlist=["run"])
            mod.run()
            print(f"# {key} done in {time.time()-t0:.1f}s", file=sys.stderr)
        except Exception as e:
            failures.append((key, repr(e)))
            traceback.print_exc()
    if failures:
        raise SystemExit(f"benchmark failures: {failures}")


if __name__ == "__main__":
    main()
