"""Benchmark harness: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows. Set BENCH_QUICK=1 for the
abbreviated sweep (shorter traces, fewer grid points).

  PYTHONPATH=src python -m benchmarks.run [--only fig8,table3]

The CI bench-regression gate (see benchmarks/README.md):

  --json-out PATH       dump every emitted row as JSON (the workflow
                        artifact, so the BENCH_*.json trajectory
                        accumulates across runs); also writes a
                        deterministic BENCH_latest.json next to it
  --check-baseline      compare events/sec + SLO-violation rates against
                        benchmarks/baselines.json; exit non-zero on a
                        >25% events/sec regression or a missing row.
                        A failing row within 2x of its floor re-runs
                        its module (best-of-3, per-row max) before the
                        verdict — flake resistance for loaded runners
  --write-baseline      regenerate benchmarks/baselines.json from this
                        run (intentional re-baselining; commit the diff)
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time
import traceback

from benchmarks import util

MODULES = [
    ("measurement", "benchmarks.fig_measurement_study"),
    ("fig6", "benchmarks.fig6_formulations"),
    ("fig7", "benchmarks.fig7_ablations"),
    ("fig8", "benchmarks.fig8_e2e"),
    ("fig9", "benchmarks.fig9_timeline"),
    ("fig10", "benchmarks.fig10_cold_starts"),
    ("fig11_13", "benchmarks.fig11_13_sensitivity"),
    ("fig14", "benchmarks.fig14_overheads"),
    ("table3", "benchmarks.table3_container_sizes"),
    ("scenario_matrix", "benchmarks.scenario_matrix"),
    ("sim_bench", "benchmarks.sim_bench"),
    ("router_bench", "benchmarks.router_bench"),
    ("admission_bench", "benchmarks.admission_bench"),
    ("chain_bench", "benchmarks.chain_bench"),
    ("estimate_bench", "benchmarks.estimate_bench"),
    ("fleet_bench", "benchmarks.fleet_bench"),
    ("registry_bench", "benchmarks.registry_bench"),
    ("kernels", "benchmarks.kernels_bench"),
    ("roofline", "benchmarks.roofline_report"),
]

BASELINE_PATH = os.path.join(os.path.dirname(__file__), "baselines.json")
# >25% events/sec regression against the committed baseline fails CI
EVENTS_PER_SEC_TOLERANCE = 0.25
# SLO-violation drift is informational (warn only): rates move with
# intentional semantics changes, which the golden-drift job already
# forces to be refreshed explicitly
SLO_WARN_PTS = 2.0


def collect_baseline_metrics(rows):
    """Extract the gated metrics from emitted rows.

    events/sec is gated only for sim_bench rows — the designated
    throughput harness, whose multi-second cells are stable enough to
    compare across runs. The SLO/admission sweeps also print
    events_per_sec, but their sub-second cells swing with machine load,
    so they contribute only their (deterministic) SLO-violation rates.

    A best-of-3 re-measure appends duplicate-named rows, so events/sec
    takes the per-name MAX (the machine's least-loaded attempt); the
    deterministic SLO rates just take the latest.
    """
    events, slo = {}, {}
    for row in rows:
        derived = util.parse_derived(str(row["derived"]))
        name = str(row["name"])
        if "events_per_sec" in derived and name.startswith("sim_bench."):
            eps = derived["events_per_sec"]
            if name not in events or eps > events[name]:
                events[name] = eps
        if "slo_viol_pct" in derived:
            slo[name] = derived["slo_viol_pct"]
    return {"events_per_sec": events, "slo_violation_pct": slo}


def check_baseline(rows, attempts: int = 1):
    """Compare this run against benchmarks/baselines.json.

    Returns ``(failures, retry_modules)``: a list of failure strings
    (empty = gate passed) and the module keys whose failing rows came
    in WITHIN 2x of their floor — a plausible machine-load flake worth
    a best-of-3 re-measure rather than an immediate verdict. Rows more
    than 2x under their floor are treated as real regressions and are
    not retried."""
    if not os.path.exists(BASELINE_PATH):
        return ([f"missing {BASELINE_PATH}; run with --write-baseline first"],
                set())
    with open(BASELINE_PATH) as f:
        baseline = json.load(f)
    if baseline.get("bench_quick") != util.QUICK:
        return ([
            f"baseline was captured with bench_quick={baseline.get('bench_quick')}"
            f" but this run has bench_quick={util.QUICK}; quick and full "
            "sweeps use different traces/fleets and are not comparable"
        ], set())
    current = collect_baseline_metrics(rows)
    failures = []
    retry_modules = set()
    best_of = f"best of {attempts} runs" if attempts > 1 else "single run"
    for name, base_eps in sorted(baseline.get("events_per_sec", {}).items()):
        cur_eps = current["events_per_sec"].get(name)
        if cur_eps is None:
            failures.append(
                f"{name}: baselined events/sec row missing from this run")
            continue
        floor = base_eps * (1.0 - EVENTS_PER_SEC_TOLERANCE)
        status = "FAIL" if cur_eps < floor else "ok"
        print(f"# baseline {status}: {name} events/sec "
              f"{cur_eps:.0f} vs {base_eps:.0f} (floor {floor:.0f}, "
              f"{best_of})",
              file=sys.stderr)
        if cur_eps < floor:
            failures.append(
                f"{name}: events/sec regressed >25% "
                f"({cur_eps:.0f} < floor {floor:.0f}, baseline {base_eps:.0f}, "
                f"{best_of})")
            if cur_eps >= floor / 2.0:
                retry_modules.add(name.split(".", 1)[0])
    for name, base_slo in sorted(baseline.get("slo_violation_pct", {}).items()):
        cur_slo = current["slo_violation_pct"].get(name)
        if cur_slo is None:
            # SLO rows are informational; a subset run (--only) simply
            # doesn't produce them all
            continue
        if abs(cur_slo - base_slo) > SLO_WARN_PTS:
            print(f"# baseline WARN: {name} slo_viol_pct moved "
                  f"{base_slo:.2f} -> {cur_slo:.2f} "
                  "(informational; refresh with --write-baseline if intended)",
                  file=sys.stderr)
    return failures, retry_modules


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated subset of module keys")
    ap.add_argument("--json-out", default=None,
                    help="write every emitted row to this JSON file")
    ap.add_argument("--check-baseline", action="store_true",
                    help="fail on >25%% events/sec regression vs "
                         "benchmarks/baselines.json")
    ap.add_argument("--write-baseline", action="store_true",
                    help="regenerate benchmarks/baselines.json from this run")
    args = ap.parse_args()
    only = set(args.only.split(",")) if args.only else None

    print("name,us_per_call,derived")
    failures = []
    modules = {}
    for key, modname in MODULES:
        if only and key not in only:
            continue
        t0 = time.time()
        try:
            mod = modules[key] = __import__(modname, fromlist=["run"])
            mod.run()
            print(f"# {key} done in {time.time()-t0:.1f}s", file=sys.stderr)
        except Exception as e:
            failures.append((key, repr(e)))
            traceback.print_exc()

    # flake resistance: a gated row that lands under its floor but
    # within 2x of it gets its whole module re-run (up to best-of-3,
    # per-row max) before the verdict — multi-second cells still swing
    # with machine load on shared CI runners
    gate = []
    if args.check_baseline:
        attempts = 1
        gate, retry = check_baseline(util.ROWS, attempts)
        while retry and attempts < 3:
            attempts += 1
            print(f"# re-measuring {sorted(retry)} (attempt {attempts}/3): "
                  "failing rows were within 2x of their floor",
                  file=sys.stderr)
            for key in sorted(retry):
                mod = modules.get(key)
                if mod is None:
                    break
                try:
                    mod.run()
                except Exception as e:
                    failures.append((key, repr(e)))
                    traceback.print_exc()
            gate, retry = check_baseline(util.ROWS, attempts)

    if args.json_out:
        payload = {"bench_quick": util.QUICK, "rows": util.ROWS}
        with open(args.json_out, "w") as f:
            json.dump(payload, f, indent=2, sort_keys=True)
            f.write("\n")
        print(f"# wrote {len(util.ROWS)} rows to {args.json_out}",
              file=sys.stderr)
        # the deterministic twin: a fixed name the workflow can upload
        # (and humans can diff) without knowing the run id baked into
        # --json-out
        latest = os.path.join(
            os.path.dirname(args.json_out) or ".", "BENCH_latest.json")
        with open(latest, "w") as f:
            json.dump(payload, f, indent=2, sort_keys=True)
            f.write("\n")
        print(f"# wrote {latest}", file=sys.stderr)
    if args.write_baseline:
        # merge into the existing baseline so a subset re-baseline
        # (--only sim_bench) can't silently delete every other gate;
        # a mode switch (quick vs full) starts fresh — the two sweeps
        # use different traces/fleets and must never mix
        doc = {"events_per_sec": {}, "slo_violation_pct": {}}
        if os.path.exists(BASELINE_PATH):
            with open(BASELINE_PATH) as f:
                prior = json.load(f)
            if prior.get("bench_quick") == util.QUICK:
                doc.update(prior)
            else:
                print("# baseline mode changed; starting fresh",
                      file=sys.stderr)
        current = collect_baseline_metrics(util.ROWS)
        doc["bench_quick"] = util.QUICK
        doc["events_per_sec"].update(current["events_per_sec"])
        doc["slo_violation_pct"].update(current["slo_violation_pct"])
        with open(BASELINE_PATH, "w") as f:
            json.dump(doc, f, indent=2, sort_keys=True)
            f.write("\n")
        print(f"# wrote baseline to {BASELINE_PATH}", file=sys.stderr)
    if failures:
        raise SystemExit(f"benchmark failures: {failures}")
    if gate:
        raise SystemExit(
            "bench-regression gate failed:\n  " + "\n  ".join(gate))


if __name__ == "__main__":
    main()
