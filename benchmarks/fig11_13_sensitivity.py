"""Figures 11-13: sensitivity studies.

* Fig 11 — vCPU oversubscription limit sweep at RPS 6: above the
  physical core count violations stop improving and timeouts appear.
* Fig 12 — confidence-threshold sweeps: higher memory confidence cuts
  OOM kills (<1% at 20); higher vCPU confidence does NOT keep helping.
* Fig 13 — SLO multiplier sweep: stricter SLOs violate more, but median
  idle vCPUs stay flat (no panic over-allocation).
"""

from __future__ import annotations

import dataclasses
import time

from benchmarks.util import QUICK, duration_s, emit
from repro.serving.experiment import run_experiment
from repro.serving.simulator import SimConfig


def run() -> None:
    # --- Fig 11: oversubscription limit -----------------------------------
    limits = (60, 90, 130) if QUICK else (45, 60, 90, 110, 130)
    for lim in limits:
        t0 = time.perf_counter()
        r = run_experiment(
            "shabari", rps=6.0, duration_s=duration_s(), seed=0,
            sim_cfg=SimConfig(seed=0, vcpu_limit=lim),
        )
        emit(f"fig11_limit{lim}", (time.perf_counter() - t0) * 1e6,
             f"slo_viol_pct={r.summary['slo_violation_pct']:.2f};"
             f"timeout_pct={r.summary['timeout_pct']:.2f}")

    # --- Fig 12: confidence thresholds -------------------------------------
    vconfs = (5, 10, 20) if QUICK else (3, 5, 10, 16, 24)
    for vc in vconfs:
        t0 = time.perf_counter()
        r = run_experiment("shabari", rps=5.0, duration_s=duration_s(),
                           seed=0, vcpu_confidence=vc)
        emit(f"fig12a_vconf{vc}", (time.perf_counter() - t0) * 1e6,
             f"slo_viol_pct={r.summary['slo_violation_pct']:.2f}")
    mconfs = (5, 20) if QUICK else (5, 10, 20, 30)
    for mc in mconfs:
        t0 = time.perf_counter()
        r = run_experiment("shabari", rps=5.0, duration_s=duration_s(),
                           seed=0, mem_confidence=mc)
        emit(f"fig12b_mconf{mc}", (time.perf_counter() - t0) * 1e6,
             f"oom_killed_pct={r.summary['oom_pct']:.2f}")

    # --- Fig 13: SLO multiplier --------------------------------------------
    mults = (1.2, 1.4, 1.8) if QUICK else (1.2, 1.4, 1.6, 1.8)
    for mult in mults:
        t0 = time.perf_counter()
        r = run_experiment("shabari", rps=5.0, duration_s=duration_s(),
                           seed=0, slo_multiplier=mult)
        emit(f"fig13_slo{mult}", (time.perf_counter() - t0) * 1e6,
             f"slo_viol_pct={r.summary['slo_violation_pct']:.2f};"
             f"idle_vcpus_p50={r.summary['wasted_vcpus_p50']:.2f};"
             f"idle_vcpus_p95={r.summary['wasted_vcpus_p95']:.2f}")
