"""Figure 7: (a) Absolute vs Proportional cost function; (b) hashing vs
Hermod-style packing placement. Absolute and hashing must win at load."""

from __future__ import annotations

import time

from benchmarks.util import duration_s, emit
from repro.serving.experiment import run_experiment


def run() -> None:
    for name in ("shabari", "shabari-proportional"):
        t0 = time.perf_counter()
        r = run_experiment(name, rps=6.0, duration_s=duration_s(), seed=0)
        emit(f"fig7a_{name}", (time.perf_counter() - t0) * 1e6,
             f"slo_viol_pct={r.summary['slo_violation_pct']:.2f};"
             f"wasted_vcpus_p95={r.summary['wasted_vcpus_p95']:.2f}")
    for name in ("shabari", "shabari-packing"):
        t0 = time.perf_counter()
        r = run_experiment(name, rps=6.0, duration_s=duration_s(), seed=0)
        emit(f"fig7b_{name}", (time.perf_counter() - t0) * 1e6,
             f"slo_viol_pct={r.summary['slo_violation_pct']:.2f};"
             f"cold_start_pct={r.summary['cold_start_pct']:.2f}")
