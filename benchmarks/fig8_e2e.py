"""Figure 8: end-to-end comparison against all five baselines across
RPS 2-6 — % SLO violations, wasted vCPUs/memory, utilization.

The headline claims validated here (recorded in EXPERIMENTS.md §Repro):
Shabari reduces SLO violations by 11-73% vs the state-of-the-art
baselines at load, with ~0 median wasted vCPUs and 64-94% less median
wasted memory."""

from __future__ import annotations

import time

from benchmarks.util import duration_s, emit, rps_list
from repro.serving.experiment import run_experiment

POLICIES = ("static-medium", "static-large", "parrotfish", "aquatope",
            "cypress", "shabari")


def run() -> None:
    shabari = {}
    base_viol = {}
    for rps in rps_list():
        for pol in POLICIES:
            t0 = time.perf_counter()
            r = run_experiment(pol, rps=rps, duration_s=duration_s(), seed=0)
            s = r.summary
            emit(f"fig8_{pol}_rps{rps:g}", (time.perf_counter() - t0) * 1e6,
                 f"slo_viol_pct={s['slo_violation_pct']:.2f};"
                 f"wasted_vcpus_p50={s['wasted_vcpus_p50']:.2f};"
                 f"wasted_vcpus_p95={s['wasted_vcpus_p95']:.2f};"
                 f"wasted_mem_p50={s['wasted_mem_mb_p50']:.0f};"
                 f"cpu_util_p50={s['cpu_util_p50']:.3f};"
                 f"mem_util_p50={s['mem_util_p50']:.3f};"
                 f"oom_pct={s['oom_pct']:.2f}")
            if pol == "shabari":
                shabari[rps] = s
            else:
                base_viol.setdefault(rps, {})[pol] = s

    # headline reductions at the highest load
    top = max(shabari)
    sv = shabari[top]["slo_violation_pct"]
    for pol, s in base_viol[top].items():
        bv = s["slo_violation_pct"]
        red = 100.0 * (bv - sv) / bv if bv > 0 else 0.0
        memred = 100.0 * (
            s["wasted_mem_mb_p50"] - shabari[top]["wasted_mem_mb_p50"]
        ) / max(s["wasted_mem_mb_p50"], 1e-9)
        emit(f"fig8_headline_vs_{pol}", 0.0,
             f"slo_viol_reduction_pct={red:.1f};wasted_mem_reduction_pct={memred:.1f}")
