"""Every policy x every scenario x an RPS sweep — the violation surface.

The paper's Figure 8 compares policies at one load shape (the Azure
trace) across arrival rates. Allocation quality flips under bursty
versus steady load (Fifer, arXiv 2008.12819), so this matrix runs each
policy against all registered scenarios — azure, poisson-steady,
flash-crowd, diurnal, heavy-tail-inputs, cold-storm, oversubscribe, and
multi-cluster (run here on the default single-cluster testbed — its
workload shape alone; the routing layer it targets is swept in
benchmarks/router_bench.py) — and, fig8-style, sweeps the offered RPS
per cell. The emitted rows form a violation SURFACE (scenario x policy
x rps -> SLO-violation / cold-start / timeout / waste rates);
``benchmarks/run.py --json-out`` dumps them for plotting, and the
learning-policy cells are what the agent arena made affordable (the
shabari column alone was ~3.5x slower before it).

Rows: ``scenario_matrix.<scenario>.<policy>.rps<r>,<wall_us>,<metrics>``.
Set BENCH_QUICK=1 for a reduced grid (3 policies, 2 rates, shorter
traces).

  PYTHONPATH=src python -m benchmarks.scenario_matrix
"""

from __future__ import annotations

import time

from benchmarks.util import QUICK, duration_s, emit, rps_list
from repro.serving.experiment import POLICIES, run_scenario
from repro.serving.workload import ScenarioSpec, list_scenarios

QUICK_POLICIES = ("shabari", "parrotfish", "static-medium")


def run() -> None:
    policies = QUICK_POLICIES if QUICK else POLICIES
    for scenario in list_scenarios():
        for rps in rps_list():
            spec = ScenarioSpec(
                scenario=scenario, rps=rps, duration_s=duration_s(), seed=0,
            )
            for pol in policies:
                t0 = time.perf_counter()
                r = run_scenario(pol, spec)
                wall = time.perf_counter() - t0
                s = r.summary
                emit(
                    f"scenario_matrix.{scenario}.{pol}.rps{rps:g}",
                    wall * 1e6,
                    "|".join([
                        f"n={s['n']:.0f}",
                        f"slo_viol_pct={s['slo_violation_pct']:.2f}",
                        f"cold_pct={s['cold_start_pct']:.2f}",
                        f"wasted_mem_p50={s['wasted_mem_mb_p50']:.0f}",
                        f"timeout_pct={s['timeout_pct']:.2f}",
                        f"oom_pct={s['oom_pct']:.2f}",
                    ]),
                )


if __name__ == "__main__":
    print("name,us_per_call,derived")
    run()
