"""Shared benchmark utilities: CSV emission + timing."""

from __future__ import annotations

import os
import time
from typing import Callable, Iterable, List


QUICK = os.environ.get("BENCH_QUICK", "0") == "1"


def emit(name: str, us_per_call: float, derived: str) -> str:
    line = f"{name},{us_per_call:.3f},{derived}"
    print(line)
    return line


def time_us(fn: Callable, *, warmup: int = 2, iters: int = 10) -> float:
    for _ in range(warmup):
        fn()
    t0 = time.perf_counter()
    for _ in range(iters):
        fn()
    return (time.perf_counter() - t0) / iters * 1e6


def duration_s() -> float:
    return 240.0 if QUICK else 600.0


def rps_list() -> List[float]:
    return [3.0, 6.0] if QUICK else [2.0, 3.0, 4.0, 5.0, 6.0]
