"""Shared benchmark utilities: CSV emission + timing.

Every ``emit`` call is also recorded in ``ROWS`` so ``benchmarks.run``
can dump the whole sweep as JSON (the CI workflow artifact) and check
it against ``benchmarks/baselines.json`` (the bench-regression gate).
"""

from __future__ import annotations

import os
import time
from typing import Callable, Dict, Iterable, List


QUICK = os.environ.get("BENCH_QUICK", "0") == "1"

# every emitted row of the current process, in emission order
ROWS: List[Dict[str, object]] = []


def emit(name: str, us_per_call: float, derived: str) -> str:
    line = f"{name},{us_per_call:.3f},{derived}"
    ROWS.append(
        {"name": name, "us_per_call": us_per_call, "derived": derived}
    )
    print(line)
    return line


def parse_derived(derived: str) -> Dict[str, float]:
    """Parse an emit row's ``key=value|key=value`` derived field,
    keeping only the numeric values (the machine-readable metrics the
    baseline gate compares)."""
    out: Dict[str, float] = {}
    for part in derived.split("|"):
        key, sep, value = part.partition("=")
        if not sep:
            continue
        try:
            out[key] = float(value)
        except ValueError:
            continue
    return out


def time_us(fn: Callable, *, warmup: int = 2, iters: int = 10) -> float:
    for _ in range(warmup):
        fn()
    t0 = time.perf_counter()
    for _ in range(iters):
        fn()
    return (time.perf_counter() - t0) / iters * 1e6


def duration_s() -> float:
    return 240.0 if QUICK else 600.0


def rps_list() -> List[float]:
    return [3.0, 6.0] if QUICK else [2.0, 3.0, 4.0, 5.0, 6.0]
