"""Completion-time-estimate routing A/B: estimate vs spill-over vs
hashing on the saturating scenarios + a half-load control.

The router's ``spill-over`` mode ranks spill candidates by raw
committed-load fraction; ``estimate`` replaces the ranking with a
per-candidate estimated completion time — warm / warming-soon container
availability (``estimate_horizon_s``), expected cold-start latency,
scheduling overhead, and the §5 contention slowdown from the candidate
worker's incremental aggregates, applied to a per-function exec
estimate calibrated online from observed completions (the same
cold-start-aware lateness signal Fifer builds from container-queue
slack, arXiv 2008.12819). This sweep quantifies what the estimate buys
on three saturating shapes (flash-crowd, oversubscribe, multi-cluster)
behind a 2-cluster front door, plus a half-load poisson-steady control
where any routing policy should be near-neutral.

The ``estimate-ewma`` variant runs the same minimum-ECT routing with
the per-input regressor disabled (``estimate_features=False``) — the
PR 5 input-blind EWMA estimator — so the sweep separates what the ECT
*ranking* buys from what the *per-input* forecast buys on top of it.

CI gates (mirroring admission_bench's):

* ``estimate`` must BEAT ``spill-over`` on SLO-violation % in at least
  one saturating cell — the tentpole claim; a refactor that quietly
  degrades the estimator to load-ranking fails here;
* ``estimate`` must stay SLO-neutral (within 0.5 pts of spill-over) on
  the half-load control — a forecaster that helps under saturation must
  not tax the common case;
* the per-input forecast must BEAT the input-blind EWMA on one-step-
  ahead accuracy over the ``heavy-tail-inputs`` cell's completion
  stream — the input distribution that motivates per-input estimation
  in the first place. Accuracy (median |log(pred/actual)| on identical
  completions, scored before each observation trains either estimator)
  is the right yardstick here because under that cell's deep
  saturation few invocations complete at all, so end-to-end violation
  deltas between estimators sit inside shed/timeout noise.

  PYTHONPATH=src python -m benchmarks.estimate_bench
"""

from __future__ import annotations

import math
import time

import numpy as np

from benchmarks.util import QUICK, emit
from repro.core.ect import ECT_WARMUP_OBS
from repro.serving import baselines as B
from repro.serving.experiment import make_policy
from repro.serving.profiles import build_input_pool, build_profiles
from repro.serving.simulator import SimConfig, Simulator, summarize
from repro.serving.workload import ScenarioSpec, generate_scenario

TOTAL_WORKERS = 8 if QUICK else 16
N_CLUSTERS = 2
DURATION_S = 240.0 if QUICK else 360.0
RPS = 1.0 if QUICK else 2.0  # offered load scales with the fleet
POLICY = "shabari"
# label -> SimConfig overrides; estimate-ewma is the A/B arm with the
# per-input regressor off (EWMA-only ECT, the PR 5 estimator)
ROUTINGS = (
    ("hashing", dict(routing="hashing")),
    ("spill-over", dict(routing="spill-over")),
    ("estimate", dict(routing="estimate")),
    ("estimate-ewma", dict(routing="estimate", estimate_features=False)),
)
# the cells the beats-spill-over gate quantifies over (the control is
# gated separately, for neutrality)
SATURATING = ("flash-crowd", "oversubscribe", "multi-cluster",
              "heavy-tail-inputs")

# Each entry: (scenario params, rps scale) — router_bench's loads: the
# HOT cluster saturates while total capacity still suffices, the regime
# where routing quality decides SLO compliance. (At admission_bench's
# fleet-wide overload no routing policy can win — queue-timeout
# shedding dominates every per-invocation metric there; that regime
# belongs to admission control, not the spill heuristic.) The control
# runs at half the offered load so it genuinely has headroom.
SCENARIOS = {
    "flash-crowd": ({"spike_mult": 4.0}, 1.0),
    "oversubscribe": ({"load_mult": 1.6}, 1.0),
    "multi-cluster": ({}, 1.0),
    # saturating AND input-skewed: per-invocation exec times spread far
    # around each function's mean, the regime where a per-input forecast
    # separates from the EWMA (gate 3)
    "heavy-tail-inputs": ({"skew": 3.0}, 2.0),
    "poisson-steady": ({}, 0.5),
}
# a DIFFERENT trace seed than router_bench's (seed 0): its c2 cells use
# the same fleet and loads, so an identical seed would duplicate those
# simulations verbatim — an independent seed makes this sweep (and the
# gates below) second-seed evidence instead of repeated wall-clock
TRACE_SEED = 1


def _cfg(**overrides) -> SimConfig:
    # vcpu_limit > physical_cores (the §6 userCPU knob): placements
    # translate into co-runner contention, which is exactly the signal
    # the estimate's §5 slowdown term is supposed to price in
    return SimConfig(
        n_workers=TOTAL_WORKERS // N_CLUSTERS,
        n_clusters=N_CLUSTERS,
        vcpus_per_worker=44,
        physical_cores=32,
        mem_mb_per_worker=16 * 1024,
        vcpu_limit=44,
        retry_interval_s=1.0,
        queue_timeout_s=60.0,
        seed=0,
        **overrides,
    )


def _run_cell(trace, profiles, pool, slo_table, overrides):
    policy = make_policy(POLICY, profiles, pool, slo_table, seed=0)
    sim = Simulator(policy=policy, profiles=profiles, input_pool=pool,
                    slo_table=slo_table, cfg=_cfg(**overrides))
    t0 = time.perf_counter()
    summary = summarize(sim.run(trace))
    wall = time.perf_counter() - t0
    eps = sim.events_processed / wall
    return summary, sim.router, eps


def _estimator_accuracy(trace, profiles, pool, slo_table):
    """One-step-ahead |log(pred/actual)| of the per-input forecast vs
    the EWMA over one run's completion stream, scored inside the
    calibration hook BEFORE each observation trains either estimator
    (so neither is graded on a point it has already seen) and only once
    the regressor is past warm-up (before that the two predictions are
    identical by construction)."""
    policy = make_policy(POLICY, profiles, pool, slo_table, seed=0)
    sim = Simulator(policy=policy, profiles=profiles, input_pool=pool,
                    slo_table=slo_table, cfg=_cfg(routing="estimate"))
    router = sim.router
    errs_feat, errs_ewma = [], []
    orig = router.observe_exec

    def tap(function, base_exec_s, net_gbps=0.0, *, features=None,
            input_mb=None):
        if (base_exec_s > 0.0 and features is not None
                and router._ect.observations(function) >= ECT_WARMUP_OBS
                and function in router._exec_ewma):
            pred = router._exec_estimate(function, features, input_mb)
            errs_feat.append(abs(math.log(pred / base_exec_s)))
            errs_ewma.append(
                abs(math.log(router._exec_ewma[function] / base_exec_s)))
        orig(function, base_exec_s, net_gbps, features=features,
             input_mb=input_mb)

    router.observe_exec = tap
    sim.run(trace)
    return (float(np.median(errs_feat)) if errs_feat else 0.0,
            float(np.median(errs_ewma)) if errs_ewma else 0.0,
            len(errs_feat))


def run() -> None:
    profiles = build_profiles()
    pool = build_input_pool(seed=0)
    slo_table = B.build_slo_table(profiles, pool)

    cells = {}
    traces = {}
    warmed = False
    for scenario, (params, rps_scale) in SCENARIOS.items():
        spec = ScenarioSpec(scenario=scenario, rps=RPS * rps_scale,
                            duration_s=DURATION_S, seed=TRACE_SEED,
                            params=dict(params))
        trace = generate_scenario(
            spec, functions=sorted(profiles),
            inputs_per_function={f: len(pool[f]) for f in profiles},
        )
        traces[scenario] = trace
        if not warmed:
            # throwaway run: trace shabari's jit kernels so the one-time
            # compiles aren't charged to the first timed cell
            _run_cell(trace[: max(len(trace) // 4, 1)],
                      profiles, pool, slo_table, dict(routing="spill-over"))
            warmed = True
        for label, overrides in ROUTINGS:
            summary, router, eps = _run_cell(
                trace, profiles, pool, slo_table, overrides)
            cells[(scenario, label)] = summary
            emit(
                f"estimate_bench.{scenario}.{label}",
                1e6 / max(eps, 1e-9),
                f"n={len(trace)}"
                f"|events_per_sec={eps:.0f}"
                f"|slo_viol_pct={summary['slo_violation_pct']:.2f}"
                f"|cold_start_pct={summary['cold_start_pct']:.2f}"
                f"|timeout_pct={summary['timeout_pct']:.2f}"
                f"|wasted_vcpus_p95={summary['wasted_vcpus_p95']:.2f}"
                f"|spills_warm={router.spills_warm}"
                f"|spills_cold={router.spills_cold}"
                f"|binds_warming={router.binds_warming}",
            )

    # headline deltas: what minimum-ECT routing buys over load ranking
    for scenario in SCENARIOS:
        spill = cells[(scenario, "spill-over")]
        est = cells[(scenario, "estimate")]
        emit(
            f"estimate_bench.{scenario}.estimate_gain",
            0.0,
            f"slo_viol_reduction_pts="
            f"{spill['slo_violation_pct'] - est['slo_violation_pct']:.2f}"
            f"|spill-over={spill['slo_violation_pct']:.2f}"
            f"|estimate={est['slo_violation_pct']:.2f}",
        )

    # CI gate 1: the estimate must beat load-ranked spill-over on SLO
    # violations in at least one saturating cell
    wins = [
        s for s in SATURATING
        if (cells[(s, "estimate")]["slo_violation_pct"]
            < cells[(s, "spill-over")]["slo_violation_pct"] - 1e-9)
    ]
    if not wins:
        raise RuntimeError(
            "estimate routing failed to beat spill-over on any saturating "
            "cell: " + ", ".join(
                f"{s}: est {cells[(s, 'estimate')]['slo_violation_pct']:.2f}%"
                f" vs spill {cells[(s, 'spill-over')]['slo_violation_pct']:.2f}%"
                for s in SATURATING))

    # CI gate 2: SLO-neutrality on the half-load control
    ctrl_spill = cells[("poisson-steady", "spill-over")]
    ctrl_est = cells[("poisson-steady", "estimate")]
    if (ctrl_est["slo_violation_pct"]
            > ctrl_spill["slo_violation_pct"] + 0.5):
        raise RuntimeError(
            "estimate routing raised SLO violations on the half-load "
            f"poisson-steady control: {ctrl_est['slo_violation_pct']:.2f}% "
            f"> {ctrl_spill['slo_violation_pct']:.2f}%")

    # CI gate 3: the per-input regressor must beat the input-blind EWMA
    # on one-step-ahead accuracy where the inputs are the story —
    # skewed sizes under saturation
    err_feat, err_ewma, n_scored = _estimator_accuracy(
        traces["heavy-tail-inputs"], profiles, pool, slo_table)
    emit(
        "estimate_bench.heavy-tail-inputs.feature_gain",
        0.0,
        f"median_abs_log_err_feature={err_feat:.3f}"
        f"|median_abs_log_err_ewma={err_ewma:.3f}"
        f"|n_scored={n_scored}",
    )
    if n_scored == 0 or err_feat >= err_ewma - 1e-9:
        raise RuntimeError(
            "per-input ECT features failed to beat the EWMA estimator on "
            f"heavy-tail-inputs: median |log err| {err_feat:.3f} >= "
            f"{err_ewma:.3f} (n={n_scored})")


if __name__ == "__main__":
    print("name,us_per_call,derived")
    run()
