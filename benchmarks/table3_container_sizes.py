"""Table 3: number of unique container sizes Shabari creates per
function across RPS — low/stable for single-threaded functions, growing
with load for multi-threaded ones (exploration)."""

from __future__ import annotations

import time

from benchmarks.util import QUICK, duration_s, emit
from repro.serving.experiment import run_experiment

FNS = ("matmult", "encrypt", "linpack", "imageprocess", "sentiment",
       "mobilenet", "videoprocess", "lrtrain")


def run() -> None:
    rps_values = (3.0, 6.0) if QUICK else (2.0, 4.0, 6.0)
    for rps in rps_values:
        t0 = time.perf_counter()
        r = run_experiment("shabari", rps=rps, duration_s=duration_s(), seed=0)
        parts = ";".join(
            f"{fn}={r.container_sizes.get(fn, 0)}" for fn in FNS
        )
        emit(f"table3_rps{rps:g}", (time.perf_counter() - t0) * 1e6, parts)
