"""Cache-affinity vs cache-blind vs flat-constant cold starts under a
registry storm (repro.core.image_cache).

Every worker keeps a finite image/layer store behind a slow registry
downlink; registry-storm floods the fleet with clone aliases that share
base layers. The simulator always charges pull-what's-missing when the
cache is enabled; the arms differ in what the DECISIONS see:

* ``affinity`` — ``ImageCacheSpec(affinity=True)``: the scheduler ranks
  cold placement by residual pull seconds and estimate routing prices
  each candidate's missing layers;
* ``blind``    — ``ImageCacheSpec(affinity=False)``: identical cache
  physics, but placement and pricing ignore it — a cold start lands
  wherever the plain walk says and pulls whatever that node is missing;
* ``flat``     — ``image_cache=None``: the pre-cache flat-constant cold
  model (no pulls charged at all), the historical baseline.

Under storm pressure the blind walk keeps re-pulling gigabytes onto
whichever node the hash picks, while affinity concentrates each image's
cold starts where its layers already sit — fewer registry seconds on
the critical path, so lower p99 cold latency and fewer SLO violations.
The storm population is the INTERACTIVE profile subset (sub-second to
few-second exec, tight SLOs): those are the functions whose completion
time a multi-second registry pull actually dominates — batch profiles
like matmult run for minutes and bury any cold-start signal. The
free-cache control runs the same trace with an infinite registry (zero
pull cost, oversized stores), where affinity's rank keys are all zero
and it must degenerate to the blind walk exactly.

CI gates:

* ``affinity`` must strictly beat ``blind`` on SLO-violation % OR p99
  cold-start latency in at least one registry-storm cell — a refactor
  that severs the scheduler's affinity rank or the router's residual
  -pull pricing fails here;
* ``affinity`` and ``blind`` must be SLO-identical (within 0.5 pts) on
  the free-cache control — the rank must be a pure tie-break when
  every pull is free.

  PYTHONPATH=src python -m benchmarks.registry_bench
"""

from __future__ import annotations

import time

import numpy as np

from benchmarks.util import QUICK, emit
from repro.core.fleet import ClusterSpec, FleetSpec, MachineType
from repro.core.image_cache import ImageCacheSpec
from repro.serving import baselines as B
from repro.serving.experiment import expand_function_clones, make_policy
from repro.serving.profiles import build_input_pool, build_profiles
from repro.serving.simulator import SimConfig, Simulator, summarize
from repro.serving.workload import ScenarioSpec, generate_scenario

TOTAL_WORKERS = 8 if QUICK else 16
N_CLUSTERS = 2
DURATION_S = 240.0 if QUICK else 360.0
RPS = 1.0 if QUICK else 2.0
POLICY = "shabari"
CLONES = 8
# interactive profile subset: exec times 0.1-3.4 s, so a 1-8 s registry
# pull is the completion time and cold placement decides SLO outcomes
INTERACTIVE = ("encrypt", "imageprocess", "linpack", "mobilenet", "qr",
               "resnet50")
# short enough that idle pools reap inside the trace: containers
# release their layer refs and the LRU actually churns (the OpenWhisk
# 600 s default would pin every pulled layer for the whole bench)
KEEP_ALIVE_S = 45.0

# fleet_bench's per-worker shape, with the cache knobs that make
# locality matter: the layer store holds well under the full clone
# catalog (LRU churns) and the 1 Gb registry makes a full image pull
# several times the classic cold curve
_STORM_MACHINE = MachineType(
    name="bench-32c-reg1g", physical_cores=32, vcpus=44, mem_mb=16 * 1024,
    vcpu_limit=44, image_store_mb=2 * 1024, registry_gbps=1.0)
# free-cache control: stores big enough for everything, pulls free —
# residual pull is 0.0 everywhere, so the affinity rank has nothing to
# rank and must reduce to the plain walk
_FREE_MACHINE = MachineType(
    name="bench-32c-regfree", physical_cores=32, vcpus=44,
    mem_mb=16 * 1024, vcpu_limit=44, image_store_mb=1e9,
    registry_gbps=float("inf"))


def _fleet(machine: MachineType) -> FleetSpec:
    per_cluster = ClusterSpec(
        machines=((machine, TOTAL_WORKERS // N_CLUSTERS),))
    return FleetSpec(clusters=(per_cluster,) * N_CLUSTERS)


STORM_FLEET = _fleet(_STORM_MACHINE)
FREE_FLEET = _fleet(_FREE_MACHINE)

# label -> SimConfig overrides; all arms run the SAME fleet and trace
# per cell, so deltas isolate what the decisions know about the cache
ARMS = (
    ("affinity", dict(image_cache=ImageCacheSpec())),
    ("blind", dict(image_cache=ImageCacheSpec(affinity=False))),
    ("flat", dict()),
)

# cell -> (params, rps scale, fleet): the storm cells run the cloned
# registry-storm trace at enough load that cold placement is constant
# work but below fleet-wide meltdown (where every arm just queues);
# the -xl variant widens the deploy wave so pull pressure is sustained
SCENARIOS = {
    "registry-storm": ({}, 4.0, STORM_FLEET),
    "registry-storm-xl": ({"spike_mult": 6.0, "spike_duration_s": 90.0},
                          4.0, STORM_FLEET),
    "free-cache-control": ({}, 4.0, FREE_FLEET),
}
# bench-cell key -> registered scenario name
_SCENARIO_NAME = {"registry-storm-xl": "registry-storm",
                  "free-cache-control": "registry-storm"}
# the cells the affinity-beats-blind gate quantifies over
STORM_CELLS = ("registry-storm", "registry-storm-xl")
# independent trace seed (router_bench 0, estimate_bench 1, fleet 2)
TRACE_SEED = 3


def _cfg(fleet: FleetSpec, **overrides) -> SimConfig:
    return SimConfig(
        fleet=fleet,
        routing="estimate",
        retry_interval_s=1.0,
        queue_timeout_s=60.0,
        keep_alive_s=KEEP_ALIVE_S,
        seed=0,
        **overrides,
    )


def _p99_cold_s(results) -> float:
    colds = [r.cold_latency_s for r in results if r.cold_start]
    if not colds:
        return 0.0
    return float(np.percentile(colds, 99))


def _run_cell(trace, profiles, pool, slo_table, fleet, overrides):
    policy = make_policy(POLICY, profiles, pool, slo_table, seed=0)
    sim = Simulator(policy=policy, profiles=profiles, input_pool=pool,
                    slo_table=slo_table, cfg=_cfg(fleet, **overrides))
    t0 = time.perf_counter()
    results = sim.run(trace)
    wall = time.perf_counter() - t0
    summary = summarize(results)
    summary["p99_cold_s"] = _p99_cold_s(results)
    eps = sim.events_processed / wall
    return summary, sim, eps


def run() -> None:
    base_profiles = build_profiles()
    base_pool = build_input_pool(seed=0)
    base_slo = B.build_slo_table(base_profiles, base_pool)
    base_profiles = {f: base_profiles[f] for f in INTERACTIVE}
    base_pool = {f: base_pool[f] for f in INTERACTIVE}
    base_slo = {k: v for k, v in base_slo.items() if k[0] in INTERACTIVE}
    # the storm's function population: clone aliases sharing base layers
    profiles, pool, slo_table = expand_function_clones(
        base_profiles, base_pool, base_slo, CLONES)

    cells = {}
    warmed = False
    for cell, (params, rps_scale, fleet) in SCENARIOS.items():
        scenario = _SCENARIO_NAME.get(cell, cell)
        spec = ScenarioSpec(scenario=scenario, rps=RPS * rps_scale,
                            duration_s=DURATION_S, seed=TRACE_SEED,
                            params=dict(params))
        trace = generate_scenario(
            spec, functions=sorted(profiles),
            inputs_per_function={f: len(pool[f]) for f in profiles},
        )
        if not warmed:
            # throwaway run on the cache-enabled arm so one-time jit
            # compiles aren't charged to the first timed cell
            _run_cell(trace[: max(len(trace) // 4, 1)], profiles, pool,
                      slo_table, fleet, dict(ARMS[0][1]))
            warmed = True
        for label, overrides in ARMS:
            summary, sim, eps = _run_cell(
                trace, profiles, pool, slo_table, fleet, dict(overrides))
            cells[(cell, label)] = summary
            caches = [w.image_cache for cl in sim.clusters
                      for w in cl.workers if w.image_cache is not None]
            hits = sum(c.hits for c in caches)
            misses = sum(c.misses for c in caches)
            evics = sum(c.evictions for c in caches)
            emit(
                f"registry_bench.{cell}.{label}",
                1e6 / max(eps, 1e-9),
                f"n={len(trace)}"
                f"|events_per_sec={eps:.0f}"
                f"|slo_viol_pct={summary['slo_violation_pct']:.2f}"
                f"|cold_start_pct={summary['cold_start_pct']:.2f}"
                f"|p99_cold_s={summary['p99_cold_s']:.3f}"
                f"|timeout_pct={summary['timeout_pct']:.2f}"
                f"|layer_hits={hits}"
                f"|layer_misses={misses}"
                f"|layer_evictions={evics}",
            )

    # headline deltas: what letting the decisions SEE the cache buys
    for cell in SCENARIOS:
        blind = cells[(cell, "blind")]
        aff = cells[(cell, "affinity")]
        emit(
            f"registry_bench.{cell}.affinity_gain",
            0.0,
            f"slo_viol_reduction_pts="
            f"{blind['slo_violation_pct'] - aff['slo_violation_pct']:.2f}"
            f"|p99_cold_reduction_s="
            f"{blind['p99_cold_s'] - aff['p99_cold_s']:.3f}"
            f"|blind={blind['slo_violation_pct']:.2f}"
            f"|affinity={aff['slo_violation_pct']:.2f}",
        )

    # CI gate 1: cache-affinity must strictly beat cache-blind on SLO
    # violations OR p99 cold-start latency in >=1 registry-storm cell
    wins = [
        c for c in STORM_CELLS
        if (cells[(c, "affinity")]["slo_violation_pct"]
            < cells[(c, "blind")]["slo_violation_pct"] - 1e-9)
        or (cells[(c, "affinity")]["p99_cold_s"]
            < cells[(c, "blind")]["p99_cold_s"] - 1e-9)
    ]
    if not wins:
        raise RuntimeError(
            "cache-affinity placement failed to beat cache-blind on any "
            "registry-storm cell: " + ", ".join(
                f"{c}: affinity slo={cells[(c, 'affinity')]['slo_violation_pct']:.2f}%"
                f"/p99_cold={cells[(c, 'affinity')]['p99_cold_s']:.3f}s"
                f" vs blind slo={cells[(c, 'blind')]['slo_violation_pct']:.2f}%"
                f"/p99_cold={cells[(c, 'blind')]['p99_cold_s']:.3f}s"
                for c in STORM_CELLS))

    # CI gate 2: with free pulls the affinity rank must be inert
    ctrl_aff = cells[("free-cache-control", "affinity")]
    ctrl_blind = cells[("free-cache-control", "blind")]
    drift = abs(ctrl_aff["slo_violation_pct"]
                - ctrl_blind["slo_violation_pct"])
    if drift > 0.5:
        raise RuntimeError(
            "cache-affinity changed behavior on the free-cache control: "
            f"affinity {ctrl_aff['slo_violation_pct']:.2f}% vs "
            f"blind {ctrl_blind['slo_violation_pct']:.2f}%")


if __name__ == "__main__":
    print("name,us_per_call,derived")
    run()
