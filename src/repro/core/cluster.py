"""Cluster model: workers, containers, capacity tracking.

Mirrors the paper's testbed (§7.1): 16 invoker workers x 90 vCPUs x
125 GB, plus the decoupled-resource bookkeeping Shabari's scheduler
needs — per-worker aggregate vCPU AND memory of active invocations
(OpenWhisk tracks only memory, which is what oversubscribes vCPUs
under static-large, Figure 8a).

Containers are (function, vcpus, mem) slots. Idle warm containers hold
no load (§5 "while idle, containers do not consume vCPU or memory") —
worker capacity is consumed by RUNNING invocations plus WARMING
reservations: when the scheduler places an invocation that needs a cold
container, the worker reserves its vCPUs/memory immediately
(:meth:`Worker.reserve`), so ``fits`` and the cluster-level load
aggregates see committed-but-still-warming capacity instead of letting
the router stack cold starts onto a free-looking worker. A reservation
either converts to a running acquisition when the cold start completes
(:meth:`Worker.commit_reservation`) or is released on timeout/cancel
(:meth:`Worker.cancel_reservation`).

Read-side signals for the front door, all incremental (no O(running
invocations) rescans per route):

* ``Worker.idle_warm`` / ``Cluster.has_idle_warm`` — warm containers
  usable NOW (``warm_at <= now``), via the per-function index;
* ``Worker.warming_soon`` / ``Cluster.warming_soon`` — uncommitted
  containers still warming whose ``warm_at`` falls within a horizon
  (background exact-size launches, §5 case 2). Invisible to the warm
  lookups above, these are placement targets for the router's
  estimate-routing mode: an invocation can bind to one and start the
  moment it turns warm;
* per-worker ``active_demand_vcpus`` / ``active_net_gbps`` aggregates —
  the §5 contention inputs, maintained by :meth:`Worker.add_active`/
  :meth:`Worker.remove_active`, so the router can score a candidate
  worker's expected co-runner slowdown in O(1).
"""

from __future__ import annotations

import dataclasses
import itertools
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.fleet import MachineType

_container_ids = itertools.count()


class WorkerArrays:
    """Struct-of-arrays backing store for per-worker mutable state.
    Each :class:`Worker` is a view into one slot of its cluster's
    shared arrays: scalar reads/writes go through the worker facade
    exactly as before, while bulk readers (the router's fleet-wide SLO
    scoring, summaries, tests) can consume a whole cluster's state as
    vectors without touching Python objects.

    Storage is split by access pattern: the contention aggregates and
    machine constants are NumPy arrays because the router's SLO
    scoring consumes them as whole vectors, while the capacity
    counters (used/reserved vcpus + memory) are plain Python lists —
    every reader of those is scalar and per-worker (``fits``, the
    scheduler's per-candidate headroom checks, the worker facade), and
    a list index returns a cheap native int where a NumPy scalar read
    costs ~10x.

    The machine-constant arrays (cores, NIC, exec factor) duplicate
    each worker's :class:`MachineType` values — they are filled once at
    cluster construction from those same objects, never written again.
    """

    __slots__ = (
        "used_vcpus", "used_mem_mb", "reserved_vcpus", "reserved_mem_mb",
        "active_demand_vcpus", "active_net_gbps",
        "physical_cores", "nic_gbps", "exec_factor",
    )

    def __init__(self, n: int):
        self.used_vcpus = [0] * n
        self.used_mem_mb = [0] * n
        self.reserved_vcpus = [0] * n
        self.reserved_mem_mb = [0] * n
        self.active_demand_vcpus = np.zeros(n, dtype=np.float64)
        self.active_net_gbps = np.zeros(n, dtype=np.float64)
        self.physical_cores = np.ones(n, dtype=np.float64)
        self.nic_gbps = np.ones(n, dtype=np.float64)
        self.exec_factor = np.ones(n, dtype=np.float64)

    def fill_machine_constants(self, machines: Sequence[MachineType]) -> None:
        for i, m in enumerate(machines):
            self.physical_cores[i] = m.physical_cores
            self.nic_gbps[i] = m.nic_gbps
            self.exec_factor[i] = m.exec_factor


@dataclasses.dataclass(slots=True)
class Container:
    cid: int
    function: str
    vcpus: int
    mem_mb: int
    worker: "Worker"
    busy: bool = False
    created_at: float = 0.0
    last_used: float = 0.0
    warm_at: float = 0.0  # when the cold start finishes
    # True while the container is warming WITH an invocation committed
    # to it and its (vcpus, mem) held as a reservation on the worker
    reserved: bool = False

    def size_key(self) -> Tuple[int, int]:
        return (self.vcpus, self.mem_mb)


@dataclasses.dataclass
class Worker:
    wid: int
    total_vcpus: int = 90
    total_mem_mb: int = 125 * 1024
    # oversubscription limit (userCPU hyperparameter, §6/§7.5)
    vcpu_limit: int = 90
    # the hardware behind this worker — the single source of the §5
    # model constants (physical cores, NIC Gbps, cold-start curve,
    # exec-speed factor) read by BOTH the simulator's charging and the
    # router's forecasting, so the two cannot drift apart
    machine: MachineType = dataclasses.field(
        default_factory=MachineType, repr=False)
    # owning-cluster backref so acquire/release can maintain the
    # cluster-level load aggregates (None for standalone Workers)
    cluster: Optional["Cluster"] = dataclasses.field(default=None, repr=False)
    # struct-of-arrays backing store (WorkerArrays) + this worker's slot
    # in it. Cluster-built workers share their cluster's arrays so bulk
    # readers can vectorize over every worker at once; a standalone
    # Worker gets a private single-slot store in __post_init__. The
    # scalar attributes below (used_vcpus, reserved_*, active_*) are
    # properties over these slots — same reads/writes as the old plain
    # fields, one storage location.
    soa: Optional[WorkerArrays] = dataclasses.field(default=None, repr=False)
    sidx: int = 0
    containers: Dict[int, Container] = dataclasses.field(default_factory=dict)
    # per-function view of ``containers`` so warm lookups touch only the
    # function's own containers instead of scanning every container on
    # the worker (insertion order matches ``containers``, so results are
    # identical to the full scan)
    by_function: Dict[str, Dict[int, Container]] = dataclasses.field(
        default_factory=dict
    )
    # per-node image/layer store (repro.core.image_cache.NodeImageCache);
    # attached by the simulator when SimConfig(image_cache=...) is set,
    # None in the flat-constant cold-start world
    image_cache: Optional[object] = dataclasses.field(
        default=None, repr=False)

    def __post_init__(self) -> None:
        if self.soa is None:
            self.soa = WorkerArrays(1)
            self.sidx = 0
            self.soa.fill_machine_constants([self.machine])

    # ------------------------------------- SoA-backed scalar views
    # used_* totals COUNT warming reservations (so ``fits`` and the
    # cluster aggregates need no special cases); reserved_* track how
    # much of the total is reservations, for observability and tests.
    # active_* are the incremental aggregates over RUNNING invocations
    # (parallel demand and object-store NIC draw) so contention lookups
    # are O(1) instead of a scan over every running invocation.
    @property
    def used_vcpus(self) -> int:
        return int(self.soa.used_vcpus[self.sidx])

    @used_vcpus.setter
    def used_vcpus(self, v: int) -> None:
        self.soa.used_vcpus[self.sidx] = v

    @property
    def used_mem_mb(self) -> int:
        return int(self.soa.used_mem_mb[self.sidx])

    @used_mem_mb.setter
    def used_mem_mb(self, v: int) -> None:
        self.soa.used_mem_mb[self.sidx] = v

    @property
    def reserved_vcpus(self) -> int:
        return int(self.soa.reserved_vcpus[self.sidx])

    @reserved_vcpus.setter
    def reserved_vcpus(self, v: int) -> None:
        self.soa.reserved_vcpus[self.sidx] = v

    @property
    def reserved_mem_mb(self) -> int:
        return int(self.soa.reserved_mem_mb[self.sidx])

    @reserved_mem_mb.setter
    def reserved_mem_mb(self, v: int) -> None:
        self.soa.reserved_mem_mb[self.sidx] = v

    @property
    def active_demand_vcpus(self) -> float:
        return float(self.soa.active_demand_vcpus[self.sidx])

    @active_demand_vcpus.setter
    def active_demand_vcpus(self, v: float) -> None:
        self.soa.active_demand_vcpus[self.sidx] = v

    @property
    def active_net_gbps(self) -> float:
        return float(self.soa.active_net_gbps[self.sidx])

    @active_net_gbps.setter
    def active_net_gbps(self, v: float) -> None:
        self.soa.active_net_gbps[self.sidx] = v

    def fits(self, vcpus: int, mem_mb: int) -> bool:
        a, i = self.soa, self.sidx
        return (
            a.used_vcpus[i] + vcpus <= self.vcpu_limit
            and a.used_mem_mb[i] + mem_mb <= self.total_mem_mb
        )

    def acquire(self, vcpus: int, mem_mb: int) -> None:
        a, i = self.soa, self.sidx
        a.used_vcpus[i] += vcpus
        a.used_mem_mb[i] += mem_mb
        if self.cluster is not None:
            self.cluster.used_vcpus += vcpus
            self.cluster.used_mem_mb += mem_mb

    def release(self, vcpus: int, mem_mb: int) -> None:
        a, i = self.soa, self.sidx
        a.used_vcpus[i] -= vcpus
        a.used_mem_mb[i] -= mem_mb
        assert a.used_vcpus[i] >= 0 and a.used_mem_mb[i] >= 0
        if self.cluster is not None:
            self.cluster.used_vcpus -= vcpus
            self.cluster.used_mem_mb -= mem_mb

    # -------------------------------------------- warming reservations
    def reserve(self, vcpus: int, mem_mb: int) -> None:
        """Acquire-on-placement: hold capacity for a cold start the
        moment it is placed, before the container finishes warming."""
        a, i = self.soa, self.sidx
        a.reserved_vcpus[i] += vcpus
        a.reserved_mem_mb[i] += mem_mb
        if self.cluster is not None:
            self.cluster.reserved_vcpus += vcpus
            self.cluster.reserved_mem_mb += mem_mb
        self.acquire(vcpus, mem_mb)

    def commit_reservation(self, vcpus: int, mem_mb: int) -> None:
        """Cold start completed: the reservation becomes a running
        acquisition. used_* already count it, so only the reserved
        slice shrinks."""
        a, i = self.soa, self.sidx
        a.reserved_vcpus[i] -= vcpus
        a.reserved_mem_mb[i] -= mem_mb
        assert a.reserved_vcpus[i] >= 0 and a.reserved_mem_mb[i] >= 0
        if self.cluster is not None:
            self.cluster.reserved_vcpus -= vcpus
            self.cluster.reserved_mem_mb -= mem_mb

    def cancel_reservation(self, vcpus: int, mem_mb: int) -> None:
        """The committed invocation will never run (queue timeout /
        cancel): give the capacity back."""
        self.commit_reservation(vcpus, mem_mb)
        self.release(vcpus, mem_mb)

    def add_active(self, demand_vcpus: float, net_gbps: float) -> None:
        a, i = self.soa, self.sidx
        a.active_demand_vcpus[i] += demand_vcpus
        a.active_net_gbps[i] += net_gbps

    def remove_active(self, demand_vcpus: float, net_gbps: float) -> None:
        a, i = self.soa, self.sidx
        a.active_demand_vcpus[i] -= demand_vcpus
        a.active_net_gbps[i] -= net_gbps
        assert a.active_demand_vcpus[i] > -1e-6 and a.active_net_gbps[i] > -1e-6
        # clamp float drift from repeated +=/-= so long runs stay exact
        if a.active_demand_vcpus[i] < 1e-9:
            a.active_demand_vcpus[i] = 0.0
        if a.active_net_gbps[i] < 1e-9:
            a.active_net_gbps[i] = 0.0

    def idle_warm(self, function: str, now: float) -> List[Container]:
        byf = self.by_function.get(function)
        if not byf:
            return []
        return [c for c in byf.values() if not c.busy and c.warm_at <= now]

    def warming_soon(self, function: str, now: float, horizon_s: float,
                     vcpus: int, mem_mb: int) -> Optional[Container]:
        """The soonest-warm UNCOMMITTED container for ``function`` that
        is at least (vcpus, mem_mb) big, still warming with ``warm_at``
        within ``horizon_s`` of ``now``, and whose reservation this
        worker can still take (``fits`` is checked per container, not
        after selection — a too-big soonest candidate must not hide a
        later one that fits).

        Only background-launched containers qualify: a cold start placed
        for a specific invocation is ``busy`` (and ``reserved``) for its
        whole warm-up, so it can never be handed to a second invocation.
        Uses the per-function index — cost is O(this function's
        containers on the worker), not O(all containers)."""
        byf = self.by_function.get(function)
        if not byf:
            return None
        best: Optional[Container] = None
        for c in byf.values():
            if c.busy or c.warm_at <= now or c.warm_at > now + horizon_s:
                continue
            if c.vcpus < vcpus or c.mem_mb < mem_mb:
                continue
            if not self.fits(c.vcpus, c.mem_mb):
                continue
            if best is None or c.warm_at < best.warm_at:
                best = c
        return best


class Cluster:
    def __init__(
        self,
        n_workers: int = 16,
        vcpus_per_worker: int = 90,
        mem_mb_per_worker: int = 125 * 1024,
        vcpu_limit: Optional[int] = None,
        legacy_scans: bool = False,
        machines: Optional[Sequence[MachineType]] = None,
    ):
        # legacy_scans restores the pre-refactor O(containers) warm
        # lookup (see Simulator's SimConfig.legacy_scans) for A/B
        # benchmarking; results are identical either way.
        self.legacy_scans = legacy_scans
        # cluster-level load aggregates, maintained by Worker.acquire/
        # release — the router's O(1) spill-target metric. Reservations
        # (committed-but-warming cold starts) are included in used_*;
        # reserved_* track that slice separately.
        self.used_vcpus = 0
        self.used_mem_mb = 0
        self.reserved_vcpus = 0
        self.reserved_mem_mb = 0
        if machines is None:
            # homogeneous legacy path: one machine type mirroring the
            # worker-shape args (vcpu_limit only overrides the worker
            # cap, not the machine's advertised vcpus)
            uniform = MachineType(
                vcpus=vcpus_per_worker,
                mem_mb=mem_mb_per_worker,
                vcpu_limit=vcpu_limit,
            )
            machines = [uniform] * n_workers
        # one struct-of-arrays store for the whole cluster: every
        # Worker below is a single-slot view into it, and bulk readers
        # (router SLO scoring, tests) vectorize over all workers at once
        self.arrays = WorkerArrays(len(machines))
        self.arrays.fill_machine_constants(machines)
        self.workers = [
            Worker(
                wid=i,
                total_vcpus=m.vcpus,
                total_mem_mb=m.mem_mb,
                vcpu_limit=m.limit,
                machine=m,
                cluster=self,
                soa=self.arrays,
                sidx=i,
            )
            for i, m in enumerate(machines)
        ]
        # cluster-level mirror of each worker's per-function container
        # index: warm lookups for a function touch only ITS containers
        # cluster-wide instead of probing all workers (most hold none).
        # Iteration order is container-creation order; selection-order
        # parity with the per-worker scans is restored by explicit
        # (wid, cid) tie-break keys at the call sites (scheduler,
        # warming_soon below).
        self.by_function: Dict[str, Dict[int, Container]] = {}
        # per-function dict of the IDLE (busy == False) subset of
        # ``by_function``: warm lookups and warming-soon scans touch
        # only containers that can actually be candidates, instead of
        # every container of the function. Maintained eagerly by
        # mark_busy/mark_idle at each busy flip (two O(1) dict ops per
        # invocation lifecycle); iteration order is irrelevant because
        # every reader selects by an explicit total (.., wid, cid) key.
        self.idle_by_function: Dict[str, Dict[int, Container]] = {}

    def mark_busy(self, c: Container) -> None:
        """Flip a container busy and drop it from the idle index."""
        c.busy = True
        byf = self.idle_by_function.get(c.function)
        if byf is not None:
            byf.pop(c.cid, None)

    def mark_idle(self, c: Container) -> None:
        """Flip a container idle (finish, cancelled cold start, idle
        creation) and register it in the idle index."""
        c.busy = False
        self.idle_by_function.setdefault(c.function, {})[c.cid] = c

    def new_container(
        self, worker: Worker, function: str, vcpus: int, mem_mb: int,
        now: float, warm_at: float,
    ) -> Container:
        c = Container(
            cid=next(_container_ids),
            function=function,
            vcpus=vcpus,
            mem_mb=mem_mb,
            worker=worker,
            created_at=now,
            last_used=now,
            warm_at=warm_at,
        )
        worker.containers[c.cid] = c
        worker.by_function.setdefault(function, {})[c.cid] = c
        self.by_function.setdefault(function, {})[c.cid] = c
        # containers are created idle; cold-start placement marks the
        # new container busy immediately after, removing it again
        self.idle_by_function.setdefault(function, {})[c.cid] = c
        return c

    def remove_container(self, c: Container) -> None:
        ic = c.worker.image_cache
        if ic is not None:
            # reaping the container drops its reference to the image's
            # layers; they stay resident but become LRU-evictable
            ic.release(c.function)
        c.worker.containers.pop(c.cid, None)
        byf = c.worker.by_function.get(c.function)
        if byf is not None:
            byf.pop(c.cid, None)
        cbf = self.by_function.get(c.function)
        if cbf is not None:
            cbf.pop(c.cid, None)
        ibf = self.idle_by_function.get(c.function)
        if ibf is not None:
            ibf.pop(c.cid, None)

    def has_idle_warm(self, function: str, now: float) -> bool:
        """Emptiness probe — the router's warm-spill pre-check. The
        cluster-level index holds exactly the union of the per-worker
        indexes, so the predicate matches Worker.idle_warm; legacy_scans
        keeps the per-worker probe for A/B."""
        if self.legacy_scans:
            return any(w.idle_warm(function, now) for w in self.workers)
        byf = self.idle_by_function.get(function)
        if not byf:
            return False
        return any(
            not c.busy and c.warm_at <= now for c in byf.values()
        )

    def warming_soon(self, function: str, now: float, horizon_s: float,
                     vcpus: int, mem_mb: int) -> Optional[Container]:
        """Cluster-wide soonest-warm uncommitted container within the
        horizon whose worker can still take its reservation — the
        estimate router's warming-soon placement candidate. The
        per-worker scan (kept under ``legacy_scans``) picks per-worker
        minima by (warm_at, insertion order) and then keeps the earliest
        worker on ties — i.e. the global min by (warm_at, wid, cid); the
        indexed path selects by that exact key."""
        if self.legacy_scans:
            best: Optional[Container] = None
            for w in self.workers:
                c = w.warming_soon(function, now, horizon_s, vcpus, mem_mb)
                if c is None:
                    continue
                if best is None or c.warm_at < best.warm_at:
                    best = c
            return best
        byf = self.idle_by_function.get(function)
        if not byf:
            return None
        best = None
        best_key = None
        deadline = now + horizon_s
        for c in byf.values():
            if c.busy or c.warm_at <= now or c.warm_at > deadline:
                continue
            if c.vcpus < vcpus or c.mem_mb < mem_mb:
                continue
            if not c.worker.fits(c.vcpus, c.mem_mb):
                continue
            key = (c.warm_at, c.worker.wid, c.cid)
            if best_key is None or key < best_key:
                best, best_key = c, key
        return best

    def idle_warm(self, function: str, now: float) -> List[Container]:
        out: List[Container] = []
        if self.legacy_scans:
            for w in self.workers:
                out.extend(
                    c for c in w.containers.values()
                    if c.function == function and not c.busy
                    and c.warm_at <= now
                )
            return out
        for w in self.workers:
            out.extend(w.idle_warm(function, now))
        return out

    def total_used(self) -> Tuple[int, int]:
        return (self.used_vcpus, self.used_mem_mb)
