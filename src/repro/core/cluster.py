"""Cluster model: workers, containers, capacity tracking.

Mirrors the paper's testbed (§7.1): 16 invoker workers x 90 vCPUs x
125 GB, plus the decoupled-resource bookkeeping Shabari's scheduler
needs — per-worker aggregate vCPU AND memory of active invocations
(OpenWhisk tracks only memory, which is what oversubscribes vCPUs
under static-large, Figure 8a).

Containers are (function, vcpus, mem) slots. Idle warm containers hold
no load (§5 "while idle, containers do not consume vCPU or memory") —
worker capacity is consumed by RUNNING invocations plus WARMING
reservations: when the scheduler places an invocation that needs a cold
container, the worker reserves its vCPUs/memory immediately
(:meth:`Worker.reserve`), so ``fits`` and the cluster-level load
aggregates see committed-but-still-warming capacity instead of letting
the router stack cold starts onto a free-looking worker. A reservation
either converts to a running acquisition when the cold start completes
(:meth:`Worker.commit_reservation`) or is released on timeout/cancel
(:meth:`Worker.cancel_reservation`).

Read-side signals for the front door, all incremental (no O(running
invocations) rescans per route):

* ``Worker.idle_warm`` / ``Cluster.has_idle_warm`` — warm containers
  usable NOW (``warm_at <= now``), via the per-function index;
* ``Worker.warming_soon`` / ``Cluster.warming_soon`` — uncommitted
  containers still warming whose ``warm_at`` falls within a horizon
  (background exact-size launches, §5 case 2). Invisible to the warm
  lookups above, these are placement targets for the router's
  estimate-routing mode: an invocation can bind to one and start the
  moment it turns warm;
* per-worker ``active_demand_vcpus`` / ``active_net_gbps`` aggregates —
  the §5 contention inputs, maintained by :meth:`Worker.add_active`/
  :meth:`Worker.remove_active`, so the router can score a candidate
  worker's expected co-runner slowdown in O(1).
"""

from __future__ import annotations

import dataclasses
import itertools
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core.fleet import MachineType

_container_ids = itertools.count()


@dataclasses.dataclass
class Container:
    cid: int
    function: str
    vcpus: int
    mem_mb: int
    worker: "Worker"
    busy: bool = False
    created_at: float = 0.0
    last_used: float = 0.0
    warm_at: float = 0.0  # when the cold start finishes
    # True while the container is warming WITH an invocation committed
    # to it and its (vcpus, mem) held as a reservation on the worker
    reserved: bool = False

    def size_key(self) -> Tuple[int, int]:
        return (self.vcpus, self.mem_mb)


@dataclasses.dataclass
class Worker:
    wid: int
    total_vcpus: int = 90
    total_mem_mb: int = 125 * 1024
    # oversubscription limit (userCPU hyperparameter, §6/§7.5)
    vcpu_limit: int = 90
    # the hardware behind this worker — the single source of the §5
    # model constants (physical cores, NIC Gbps, cold-start curve,
    # exec-speed factor) read by BOTH the simulator's charging and the
    # router's forecasting, so the two cannot drift apart
    machine: MachineType = dataclasses.field(
        default_factory=MachineType, repr=False)
    used_vcpus: int = 0
    used_mem_mb: int = 0
    # the committed-but-warming slice of used_vcpus/used_mem_mb:
    # reservations are COUNTED inside the used_* totals (so ``fits`` and
    # the cluster aggregates need no special cases); these track how
    # much of that total is reservations, for observability and tests
    reserved_vcpus: int = 0
    reserved_mem_mb: int = 0
    # owning-cluster backref so acquire/release can maintain the
    # cluster-level load aggregates (None for standalone Workers)
    cluster: Optional["Cluster"] = dataclasses.field(default=None, repr=False)
    # Incremental aggregates over RUNNING invocations (parallel demand
    # and object-store NIC draw) so contention lookups are O(1) instead
    # of a scan over every running invocation per event.
    active_demand_vcpus: float = 0.0
    active_net_gbps: float = 0.0
    containers: Dict[int, Container] = dataclasses.field(default_factory=dict)
    # per-function view of ``containers`` so warm lookups touch only the
    # function's own containers instead of scanning every container on
    # the worker (insertion order matches ``containers``, so results are
    # identical to the full scan)
    by_function: Dict[str, Dict[int, Container]] = dataclasses.field(
        default_factory=dict
    )

    def fits(self, vcpus: int, mem_mb: int) -> bool:
        return (
            self.used_vcpus + vcpus <= self.vcpu_limit
            and self.used_mem_mb + mem_mb <= self.total_mem_mb
        )

    def acquire(self, vcpus: int, mem_mb: int) -> None:
        self.used_vcpus += vcpus
        self.used_mem_mb += mem_mb
        if self.cluster is not None:
            self.cluster.used_vcpus += vcpus
            self.cluster.used_mem_mb += mem_mb

    def release(self, vcpus: int, mem_mb: int) -> None:
        self.used_vcpus -= vcpus
        self.used_mem_mb -= mem_mb
        assert self.used_vcpus >= 0 and self.used_mem_mb >= 0
        if self.cluster is not None:
            self.cluster.used_vcpus -= vcpus
            self.cluster.used_mem_mb -= mem_mb

    # -------------------------------------------- warming reservations
    def reserve(self, vcpus: int, mem_mb: int) -> None:
        """Acquire-on-placement: hold capacity for a cold start the
        moment it is placed, before the container finishes warming."""
        self.reserved_vcpus += vcpus
        self.reserved_mem_mb += mem_mb
        if self.cluster is not None:
            self.cluster.reserved_vcpus += vcpus
            self.cluster.reserved_mem_mb += mem_mb
        self.acquire(vcpus, mem_mb)

    def commit_reservation(self, vcpus: int, mem_mb: int) -> None:
        """Cold start completed: the reservation becomes a running
        acquisition. used_* already count it, so only the reserved
        slice shrinks."""
        self.reserved_vcpus -= vcpus
        self.reserved_mem_mb -= mem_mb
        assert self.reserved_vcpus >= 0 and self.reserved_mem_mb >= 0
        if self.cluster is not None:
            self.cluster.reserved_vcpus -= vcpus
            self.cluster.reserved_mem_mb -= mem_mb

    def cancel_reservation(self, vcpus: int, mem_mb: int) -> None:
        """The committed invocation will never run (queue timeout /
        cancel): give the capacity back."""
        self.commit_reservation(vcpus, mem_mb)
        self.release(vcpus, mem_mb)

    def add_active(self, demand_vcpus: float, net_gbps: float) -> None:
        self.active_demand_vcpus += demand_vcpus
        self.active_net_gbps += net_gbps

    def remove_active(self, demand_vcpus: float, net_gbps: float) -> None:
        self.active_demand_vcpus -= demand_vcpus
        self.active_net_gbps -= net_gbps
        assert self.active_demand_vcpus > -1e-6 and self.active_net_gbps > -1e-6
        # clamp float drift from repeated +=/-= so long runs stay exact
        if self.active_demand_vcpus < 1e-9:
            self.active_demand_vcpus = 0.0
        if self.active_net_gbps < 1e-9:
            self.active_net_gbps = 0.0

    def idle_warm(self, function: str, now: float) -> List[Container]:
        byf = self.by_function.get(function)
        if not byf:
            return []
        return [c for c in byf.values() if not c.busy and c.warm_at <= now]

    def warming_soon(self, function: str, now: float, horizon_s: float,
                     vcpus: int, mem_mb: int) -> Optional[Container]:
        """The soonest-warm UNCOMMITTED container for ``function`` that
        is at least (vcpus, mem_mb) big, still warming with ``warm_at``
        within ``horizon_s`` of ``now``, and whose reservation this
        worker can still take (``fits`` is checked per container, not
        after selection — a too-big soonest candidate must not hide a
        later one that fits).

        Only background-launched containers qualify: a cold start placed
        for a specific invocation is ``busy`` (and ``reserved``) for its
        whole warm-up, so it can never be handed to a second invocation.
        Uses the per-function index — cost is O(this function's
        containers on the worker), not O(all containers)."""
        byf = self.by_function.get(function)
        if not byf:
            return None
        best: Optional[Container] = None
        for c in byf.values():
            if c.busy or c.warm_at <= now or c.warm_at > now + horizon_s:
                continue
            if c.vcpus < vcpus or c.mem_mb < mem_mb:
                continue
            if not self.fits(c.vcpus, c.mem_mb):
                continue
            if best is None or c.warm_at < best.warm_at:
                best = c
        return best


class Cluster:
    def __init__(
        self,
        n_workers: int = 16,
        vcpus_per_worker: int = 90,
        mem_mb_per_worker: int = 125 * 1024,
        vcpu_limit: Optional[int] = None,
        legacy_scans: bool = False,
        machines: Optional[Sequence[MachineType]] = None,
    ):
        # legacy_scans restores the pre-refactor O(containers) warm
        # lookup (see Simulator's SimConfig.legacy_scans) for A/B
        # benchmarking; results are identical either way.
        self.legacy_scans = legacy_scans
        # cluster-level load aggregates, maintained by Worker.acquire/
        # release — the router's O(1) spill-target metric. Reservations
        # (committed-but-warming cold starts) are included in used_*;
        # reserved_* track that slice separately.
        self.used_vcpus = 0
        self.used_mem_mb = 0
        self.reserved_vcpus = 0
        self.reserved_mem_mb = 0
        if machines is None:
            # homogeneous legacy path: one machine type mirroring the
            # worker-shape args (vcpu_limit only overrides the worker
            # cap, not the machine's advertised vcpus)
            uniform = MachineType(
                vcpus=vcpus_per_worker,
                mem_mb=mem_mb_per_worker,
                vcpu_limit=vcpu_limit,
            )
            machines = [uniform] * n_workers
        self.workers = [
            Worker(
                wid=i,
                total_vcpus=m.vcpus,
                total_mem_mb=m.mem_mb,
                vcpu_limit=m.limit,
                machine=m,
                cluster=self,
            )
            for i, m in enumerate(machines)
        ]

    def new_container(
        self, worker: Worker, function: str, vcpus: int, mem_mb: int,
        now: float, warm_at: float,
    ) -> Container:
        c = Container(
            cid=next(_container_ids),
            function=function,
            vcpus=vcpus,
            mem_mb=mem_mb,
            worker=worker,
            created_at=now,
            last_used=now,
            warm_at=warm_at,
        )
        worker.containers[c.cid] = c
        worker.by_function.setdefault(function, {})[c.cid] = c
        return c

    def remove_container(self, c: Container) -> None:
        c.worker.containers.pop(c.cid, None)
        byf = c.worker.by_function.get(c.function)
        if byf is not None:
            byf.pop(c.cid, None)

    def has_idle_warm(self, function: str, now: float) -> bool:
        """Emptiness probe — the router's warm-spill pre-check; defers
        to Worker.idle_warm so the predicate has one source of truth."""
        return any(w.idle_warm(function, now) for w in self.workers)

    def warming_soon(self, function: str, now: float, horizon_s: float,
                     vcpus: int, mem_mb: int) -> Optional[Container]:
        """Cluster-wide soonest-warm uncommitted container within the
        horizon whose worker can still take its reservation — the
        estimate router's warming-soon placement candidate. Defers the
        per-container predicate (including ``fits``) to
        :meth:`Worker.warming_soon`."""
        best: Optional[Container] = None
        for w in self.workers:
            c = w.warming_soon(function, now, horizon_s, vcpus, mem_mb)
            if c is None:
                continue
            if best is None or c.warm_at < best.warm_at:
                best = c
        return best

    def idle_warm(self, function: str, now: float) -> List[Container]:
        out: List[Container] = []
        if self.legacy_scans:
            for w in self.workers:
                out.extend(
                    c for c in w.containers.values()
                    if c.function == function and not c.busy
                    and c.warm_at <= now
                )
            return out
        for w in self.workers:
            out.extend(w.idle_warm(function, now))
        return out

    def total_used(self) -> Tuple[int, int]:
        return (self.used_vcpus, self.used_mem_mb)
