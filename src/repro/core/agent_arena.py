"""Batched CSOAA agent arena: all functions' regressors in stacked tensors.

``repro.core.allocator`` historically kept one tiny ``OnlineCSC`` object
per (function, resource) pair, paying one jit'd JAX dispatch per agent
per event — ~107 µs per predict (+argmin+sync) and ~130 µs per update on
the bench machine, the dominant cost of learning-policy simulations
(the very overhead wall the paper measures in Fig. 14). The arena fuses
them:

* **Stacked state** — every agent with the same ``(n_classes, dim)``
  shape lives as one row of a ``(capacity, n_classes, dim+1)`` weight /
  AdaGrad tensor pair (:class:`AgentArena`). Capacity grows by doubling;
  a function-name→row map assigns slots, and released slots are zeroed
  and reused.
* **Deferred microbatched updates** — completed-invocation feedbacks are
  queued (:class:`ArenaEngine`) and flushed lazily. The ordering rule —
  *pending updates for function F flush before any predict for F* —
  makes served allocations bit-identical to the sequential path: updates
  touching distinct rows commute exactly (disjoint state), and same-row
  updates are applied in arrival order via conflict-free passes.
* **One fused dispatch per flush** — each pass runs as a single
  ``jax.vmap``-over-rows jit'd kernel (:data:`_batched_update` /
  :data:`_batched_predict`) with ``donate_argnums`` buffer reuse, padded
  to power-of-two batch sizes with exact no-op entries so steady state
  compiles a handful of programs and allocates nothing new per call.
* **Calibrated NumPy backend** — for the small batches that dominate a
  discrete-event loop (most events carry one predict or one update), a
  dispatch-free NumPy path beats the JAX call by a wide margin. XLA's
  CPU codegen contracts the per-class dot product and the AdaGrad
  accumulator into FMA chains, so naive NumPy is NOT bit-identical;
  :func:`_matvec_exact` / :func:`_update_exact` reproduce the FMA chain
  via double-precision emulation with a double-rounding hazard check
  (rare hazards fall back to ``libm.fmaf``). The backend is enabled per
  feature dimension only after :func:`numpy_backend` proves it
  bit-identical to the jitted reference on random samples; uncalibrated
  shapes (e.g. the one-hot formulation's concatenated features) always
  take the JAX kernel. :func:`numpy_crossover_rows` benchmarks both
  backends once per shape so the per-call choice follows measured cost.

Bit-identity with the legacy per-object path is the load-bearing
guarantee — the golden-metrics harness and the ``sim_bench`` engine A/B
both assert it — which is why the reference kernels (``_csc_predict`` /
``_csc_update``) are *defined here* and shared with the legacy
``OnlineCSC`` rather than duplicated.
"""

from __future__ import annotations

import ctypes
import ctypes.util
import dataclasses
import functools
import time
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

F32 = np.float32
F64 = np.float64

# ---------------------------------------------------------------------------
# Reference jit kernels (shared with the legacy OnlineCSC path)
# ---------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnums=(2,))
def _csc_predict(w: jax.Array, x: jax.Array, n_classes: int) -> jax.Array:
    xb = jnp.concatenate([x, jnp.ones((1,), x.dtype)])
    return w @ xb  # (n_classes,) predicted costs


@jax.jit
def _csc_update(
    w: jax.Array, g2: jax.Array, x: jax.Array, costs: jax.Array, lr: jax.Array
):
    """One-against-all least-squares step on every class's regressor."""
    xb = jnp.concatenate([x, jnp.ones((1,), x.dtype)])
    pred = w @ xb
    err = pred - costs  # (n_classes,)
    grad = err[:, None] * xb[None, :]  # (n_classes, dim+1)
    g2 = g2 + jnp.square(grad)
    step = lr * grad / (jnp.sqrt(g2) + 1e-6)
    return w - step, g2


# Batched variants: vmap over stacked rows, xb precomputed by the caller.
# The math is the inner body of the reference kernels — vmap'ing it keeps
# the per-row XLA codegen identical (asserted by vmap_backend()).


def _update_core(w, g2, xb, costs, lr):
    pred = w @ xb
    err = pred - costs
    grad = err[:, None] * xb[None, :]
    g2 = g2 + jnp.square(grad)
    step = lr * grad / (jnp.sqrt(g2) + 1e-6)
    return w - step, g2


_batched_update = jax.jit(
    jax.vmap(_update_core, in_axes=(0, 0, 0, 0, None)), donate_argnums=(0, 1)
)
_batched_predict = jax.jit(jax.vmap(lambda w, xb: w @ xb, in_axes=(0, 0)))

# largest vmapped batch ever dispatched: bigger batches are chunked to
# this, so vmap_backend()'s calibration covers every shape that can run
_MAX_BUCKET = 16


# ---------------------------------------------------------------------------
# Exact float32 FMA emulation (the NumPy fast path)
# ---------------------------------------------------------------------------

try:  # pragma: no cover - import-time environment probe
    _LIBM = ctypes.CDLL(ctypes.util.find_library("m") or "libm.so.6")
    _LIBM.fmaf.restype = ctypes.c_float
    _LIBM.fmaf.argtypes = [ctypes.c_float] * 3
except (OSError, AttributeError):  # no libm → calibration simply fails
    _LIBM = None


def _fmaf_scalar(a: float, b: float, c: float) -> np.float32:
    return np.float32(
        _LIBM.fmaf(ctypes.c_float(a), ctypes.c_float(b), ctypes.c_float(c))
    )


# hazard probes: a relative nudge of ~90 float64 ulps, orders of
# magnitude wider than the true hazard zone (~1 ulp) yet narrow enough
# that false positives are vanishingly rare
_P_HI = np.float64(1.0 + 2e-14)
_P_LO = np.float64(1.0 - 2e-14)


def _fma32(a: np.ndarray, b, c: np.ndarray) -> np.ndarray:
    """Vectorized float32 fused multiply-add: round(a*b + c) with a
    SINGLE rounding, matching hardware fmaf.

    a*b is exact in float64 (24-bit mantissas), so ``float32(float64(a*b
    + c))`` is correct except when the float64 sum lands within a float64
    ulp of a float32 rounding midpoint (the double-rounding hazard).
    Hazard lanes are detected by nudging the sum ±~90 ulps — if the two
    nudges round to different float32s, the value straddles a midpoint —
    and recomputed with libm's fmaf."""
    t64 = np.multiply(a, b, dtype=F64)
    t64 += c
    r32 = t64.astype(F32)
    hi = (t64 * _P_HI).astype(F32)
    lo = (t64 * _P_LO).astype(F32)
    if not np.array_equal(hi, lo):
        ab = np.broadcast_to(a, t64.shape).reshape(-1)
        bb = np.broadcast_to(b, t64.shape).reshape(-1)
        cb = np.broadcast_to(c, t64.shape).reshape(-1)
        flat = r32.reshape(-1)
        for i in np.nonzero((hi != lo).reshape(-1))[0]:
            flat[i] = _fmaf_scalar(float(ab[i]), float(bb[i]), float(cb[i]))
    return r32


def _matvec_exact(w: np.ndarray, xb: np.ndarray) -> np.ndarray:
    """Row-stacked ``w @ xb`` reproducing XLA's FMA-chain codegen.

    ``w`` is (rows, dim+1); ``xb`` is (dim+1,) or per-row (rows, dim+1)
    — per-row results are independent, so agents with different feature
    vectors (and even different class counts) can be stacked into one
    call. The chain is: exact first product, emulated-FMA middle steps,
    and a plain add for the bias column (xb[..., -1] == 1.0 makes the
    product exact, so float64 addition is double-rounding-safe, see
    Figueroa's 2p+2 theorem). Bit-identity holds for xb lengths 2..7 —
    every Table-2 feature schema — and is asserted per dim by
    numpy_backend() before use.

    The double-rounding hazard probes are DEFERRED: the chain runs with
    plain float64 emulation while stashing each step's unrounded sum,
    then every step is verified in one batched probe at the end; any
    flagged step (vanishingly rare) reruns the whole chain with
    per-step repair (_matvec_checked)."""
    cols = (lambda i: xb[i]) if xb.ndim == 1 else (lambda i: xb[:, i])
    d1 = w.shape[-1]
    acc = np.multiply(w[:, 0], cols(0), dtype=F64).astype(F32)
    if d1 > 2:
        mids = np.empty((d1 - 2,) + acc.shape, F64)
        for i in range(1, d1 - 1):
            t64 = np.multiply(w[:, i], cols(i), dtype=F64)
            t64 += acc
            mids[i - 1] = t64
            acc = t64.astype(F32)
        hi = (mids * _P_HI).astype(F32)
        lo = (mids * _P_LO).astype(F32)
        if not np.array_equal(hi, lo):
            return _matvec_checked(w, xb)
    # bias column: product by 1.0 is exact, add in float64 is safe
    t64 = np.multiply(w[:, d1 - 1], cols(d1 - 1), dtype=F64)
    t64 += acc
    return t64.astype(F32)


def _matvec_checked(w: np.ndarray, xb: np.ndarray) -> np.ndarray:
    """Slow sibling of _matvec_exact: per-step hazard repair."""
    cols = (lambda i: xb[i]) if xb.ndim == 1 else (lambda i: xb[:, i])
    d1 = w.shape[-1]
    acc = np.multiply(w[:, 0], cols(0), dtype=F64).astype(F32)
    for i in range(1, d1 - 1):
        acc = _fma32(w[:, i], cols(i), acc)
    t64 = np.multiply(w[:, d1 - 1], cols(d1 - 1), dtype=F64)
    t64 += acc
    return t64.astype(F32)


# Certified arg-min screen: the exact FMA chain differs from a plain
# float64 dot by at most d1 float32 roundings of intermediates, each
# bounded by 0.5 ulp of the largest partial sum — which Σ|w·x| bounds.
# The worst-case RELATIVE half-ulp is 2^-24 ≈ 5.96e-8 (value just above
# a power of two, where ulp32(v)/v ≈ 2^-23), slightly inflated by the
# (1+2^-24)^d1 growth of rounded partial sums and the float64 dot's own
# error; 1.25e-7 gives a genuine ~2x margin over all of it. When the
# screened margin separates the two smallest costs, the float64 argmin
# IS the exact chain's argmin (strict, so tie order is moot); otherwise
# the caller falls back to the exact chain. Widening the constant only
# costs fallbacks — NEVER tighten it below 2^-24 plus slack.
_SCREEN_EPS = 1.25e-07


def _argmin_screened(w: np.ndarray, xb64: np.ndarray) -> Optional[int]:
    c = w @ xb64  # float64 gemv (screen only — never served directly)
    bound = np.abs(w) @ np.abs(xb64)
    delta = bound * (w.shape[-1] * _SCREEN_EPS)
    m = int(np.argmin(c))
    lo = c - delta
    hi_m = c[m] + delta[m]
    lo[m] = np.inf
    return m if hi_m < lo.min() else None


def _update_exact(
    w: np.ndarray,
    g2: np.ndarray,
    xb: np.ndarray,
    costs: np.ndarray,
    lr: np.float32,
) -> Tuple[np.ndarray, np.ndarray]:
    """Row-stacked NumPy mirror of ``_csc_update``; XLA contracts the
    AdaGrad accumulation ``g2 + grad**2`` into an FMA, hence _fma32."""
    pred = _matvec_exact(w, xb)
    pred -= costs
    err = pred  # in place: (rows,)
    if xb.ndim == 1:
        grad = err[:, None] * xb[None, :]
    else:
        grad = err[:, None] * xb
    g2n = _fma32(grad, grad, g2)
    denom = np.sqrt(g2n)
    denom += F32(1e-6)
    step = lr * grad
    step /= denom
    return w - step, g2n


# ---------------------------------------------------------------------------
# Backend calibration: trust NumPy / vmap only where provably identical
# ---------------------------------------------------------------------------

_CAL_TRIALS = 24
_CAL_ROWS = (8, 16, 32, 40, 48)


def _reference_pair(rng, n: int, dim: int):
    w = (rng.standard_normal((n, dim + 1)) * 10.0 ** rng.uniform(-2, 2)).astype(F32)
    g2 = (rng.random((n, dim + 1)) * 10.0 ** rng.uniform(-2, 2)).astype(F32)
    x = (rng.standard_normal(dim) * 10.0 ** rng.uniform(-1, 1)).astype(F32)
    costs = (1.0 + rng.random(n) * 30).astype(F32)
    return w, g2, x, costs


@functools.lru_cache(maxsize=None)
def numpy_backend(dim: int) -> bool:
    """True iff the exact-FMA NumPy path is bit-identical to the jitted
    reference kernels for this feature dimension (checked empirically:
    XLA's chain shape is a codegen detail, not a contract)."""
    if _LIBM is None:
        return False
    rng = np.random.default_rng(0xC5C)
    lr = F32(0.5)
    for _ in range(_CAL_TRIALS):
        for n in _CAL_ROWS:
            w, g2, x, costs = _reference_pair(rng, n, dim)
            xb = np.concatenate([x, np.ones(1, F32)])
            ref_c = np.asarray(_csc_predict(jnp.asarray(w), jnp.asarray(x), n))
            if not np.array_equal(ref_c, _matvec_exact(w, xb)):
                return False
            ref_w, ref_g = _csc_update(
                jnp.asarray(w), jnp.asarray(g2), jnp.asarray(x),
                jnp.asarray(costs), jnp.asarray(lr),
            )
            got_w, got_g = _update_exact(w, g2, xb, costs, lr)
            if not (np.array_equal(np.asarray(ref_w), got_w)
                    and np.array_equal(np.asarray(ref_g), got_g)):
                return False
    return True


@functools.lru_cache(maxsize=None)
def vmap_backend(dim: int) -> bool:
    """True iff the vmapped batched kernels match per-row reference
    calls bitwise (they do on CPU XLA for every shape we've met, but the
    arena refuses to assume it)."""
    rng = np.random.default_rng(0xBA7C)
    lr = F32(0.5)
    # covers every power-of-two bucket the padded batch paths can emit
    # (dispatches are chunked at _MAX_BUCKET, so nothing larger exists)
    for k in (1, 2, 3, 4, 8, _MAX_BUCKET):
        for n in (32, 40):
            stack = [_reference_pair(rng, n, dim) for _ in range(k)]
            W = np.stack([s[0] for s in stack])
            G2 = np.stack([s[1] for s in stack])
            X = np.stack([s[2] for s in stack])
            C = np.stack([s[3] for s in stack])
            XB = np.concatenate([X, np.ones((k, 1), F32)], axis=1)
            # copies: _batched_update donates its first two buffers
            bw, bg = _batched_update(
                jnp.asarray(W), jnp.asarray(G2), jnp.asarray(XB),
                jnp.asarray(C), jnp.asarray(lr),
            )
            bc = _batched_predict(jnp.asarray(W), jnp.asarray(XB))
            for i in range(k):
                rw, rg = _csc_update(
                    jnp.asarray(W[i]), jnp.asarray(G2[i]), jnp.asarray(X[i]),
                    jnp.asarray(C[i]), jnp.asarray(lr),
                )
                rc = _csc_predict(jnp.asarray(W[i]), jnp.asarray(X[i]), n)
                if not (np.array_equal(np.asarray(bw[i]), np.asarray(rw))
                        and np.array_equal(np.asarray(bg[i]), np.asarray(rg))
                        and np.array_equal(np.asarray(bc[i]), np.asarray(rc))):
                    return False
    return True


# a microbatch never routes to JAX below this many stacked rows: one
# dispatch costs ~100 µs on CPU, several times the whole NumPy update
# for a handful of agents (72 rows = one function's vCPU+mem pair)
_NUMPY_MIN_ROWS = 512


@functools.lru_cache(maxsize=None)
def numpy_crossover_rows(dim: int, n_classes: int = 32) -> int:
    """Benchmark the NumPy path against one batched JAX dispatch and
    return the stacked-row count above which JAX wins (the per-call
    backend pick). On CPU the dispatch overhead (~60-130 µs) dwarfs the
    NumPy arithmetic until the stack is thousands of rows tall; timing
    is min-of-reps so a noisy sample can't misroute the steady-state
    singleton batches."""
    if not numpy_backend(dim):
        return 0
    rng = np.random.default_rng(3)
    lr = F32(0.5)
    best = _NUMPY_MIN_ROWS
    # beyond 4096 rows the NumPy path chunks anyway (see _flush_pass),
    # so probing larger stacks would only buy XLA compile time
    for k in (32, 128):
        rows = k * n_classes
        w = (rng.standard_normal((rows, dim + 1))).astype(F32)
        g2 = (rng.random((rows, dim + 1))).astype(F32)
        xb = np.concatenate(
            [rng.standard_normal((rows, dim)).astype(F32), np.ones((rows, 1), F32)],
            axis=1,
        )
        costs = (1.0 + rng.random(rows) * 30).astype(F32)
        W = w.reshape(k, n_classes, dim + 1)
        G2 = g2.reshape(k, n_classes, dim + 1)
        XB = xb.reshape(k, n_classes, dim + 1)[:, 0, :]
        C = costs.reshape(k, n_classes)
        _batched_update(jnp.asarray(W), jnp.asarray(G2), jnp.asarray(XB),
                        jnp.asarray(C), jnp.asarray(lr))  # trace
        t_np = min(
            _timed(lambda: _update_exact(w, g2, xb, costs, lr))
            for _ in range(3)
        )
        t_jax = min(
            _timed(lambda: jax.block_until_ready(_batched_update(
                jnp.asarray(W), jnp.asarray(G2), jnp.asarray(XB),
                jnp.asarray(C), jnp.asarray(lr))))
            for _ in range(3)
        )
        if t_np <= t_jax:
            best = max(best, rows)
        else:
            break
    return best


def _timed(fn) -> float:
    t0 = time.perf_counter()
    fn()
    return time.perf_counter() - t0


def calibrate(dims) -> None:
    """Force the one-time per-dim backend calibration + crossover
    benchmark now (results are process-cached). Benchmarks call this
    during warm-up so no timed leg pays a calibration or an XLA
    compile mid-run."""
    for d in dims:
        numpy_backend(d)
        numpy_crossover_rows(d)


# ---------------------------------------------------------------------------
# The arena proper
# ---------------------------------------------------------------------------


class AgentArena:
    """Stacked homogeneous agents: one ``(n_classes, dim+1)`` row pair
    per agent in doubling-growth weight/AdaGrad tensors."""

    def __init__(self, n_classes: int, dim: int, lr: float = 0.5,
                 capacity: int = 4):
        self.n_classes = n_classes
        self.dim = dim
        self.lr = F32(lr)
        self.w = np.zeros((capacity, n_classes, dim + 1), F32)
        self.g2 = np.zeros((capacity, n_classes, dim + 1), F32)
        self._slots: Dict[str, int] = {}
        self._free: List[int] = []

    @property
    def capacity(self) -> int:
        return self.w.shape[0]

    def slot(self, name: str) -> int:
        """Row index for ``name``, assigning (and growing) on first use."""
        s = self._slots.get(name)
        if s is not None:
            return s
        if self._free:
            s = self._free.pop()
        else:
            s = len(self._slots)
            if s >= self.capacity:  # grow by doubling
                pad = np.zeros_like(self.w)
                self.w = np.concatenate([self.w, pad])
                self.g2 = np.concatenate([self.g2, np.zeros_like(pad)])
        self._slots[name] = s
        return s

    def has(self, name: str) -> bool:
        return name in self._slots

    def release(self, name: str) -> None:
        """Free ``name``'s row for reuse; the row is zeroed so a future
        tenant starts as a fresh agent (per-function isolation)."""
        s = self._slots.pop(name, None)
        if s is not None:
            self.w[s] = 0.0
            self.g2[s] = 0.0
            self._free.append(s)


@dataclasses.dataclass
class _PendingUpdate:
    function: str
    xb: np.ndarray  # (dim+1,) featurized input with bias, float32
    obs: object  # cost_functions.Observation; costs derived at flush


class ArenaEngine:
    """The vCPU + memory arena pair behind ``ResourceAllocator``.

    Feedbacks enqueue; predicts flush. A flush drains the queue in
    conflict-free passes (each agent row at most once per pass — rows
    are disjoint state, so inter-row reordering is exact) and runs each
    pass as one fused computation: the calibrated NumPy path stacks
    every agent of equal dim (vCPU and memory regressors included) into
    a single row-stacked update; otherwise the vmapped jit kernel runs
    one dispatch per (n_classes, dim) group, padded to power-of-two
    batches with exact no-op entries and donated buffers."""

    def __init__(
        self,
        *,
        n_vcpu_classes: int,
        n_mem_classes: int,
        vcpu_cost_fn: Callable,
        mem_class_mb: int,
        lr: float = 0.5,
    ):
        from repro.core import cost_functions as CF

        self.n_vcpu_classes = n_vcpu_classes
        self.n_mem_classes = n_mem_classes
        self.vcpu_cost_fn = vcpu_cost_fn
        self.mem_class_mb = mem_class_mb
        self.lr = F32(lr)
        self._vcpu_batch_fn = CF.BATCHED_COST_FNS.get(vcpu_cost_fn)
        self._mem_batch_fn = CF.memory_costs_batch
        self._arenas: Dict[Tuple[int, int], AgentArena] = {}
        self._dims: Dict[str, int] = {}  # function → feature dim
        self._counts: Dict[str, List[int]] = {}  # eager, incl. pending
        self._pending: List[_PendingUpdate] = []
        # functions with queued updates: a predict only forces a flush
        # when ITS function is in here (updates for other functions
        # touch disjoint rows, so deferring them past this predict is
        # exact) — which lets the queue grow into bigger fused batches
        self._pending_fns: set = set()

    # ------------------------------------------------------------ slots
    def _arena(self, n_classes: int, dim: int) -> AgentArena:
        key = (n_classes, dim)
        ar = self._arenas.get(key)
        if ar is None:
            ar = AgentArena(n_classes, dim, lr=float(self.lr))
            self._arenas[key] = ar
        return ar

    def _dim_of(self, function: str, x: np.ndarray) -> int:
        dim = self._dims.setdefault(function, len(x))
        if dim != len(x):
            raise ValueError(
                f"feature dim changed for {function!r}: {dim} -> {len(x)}"
            )
        return dim

    def updates(self, function: str) -> Tuple[int, int]:
        c = self._counts.get(function)
        return (c[0], c[1]) if c else (0, 0)

    def release(self, function: str) -> None:
        dim = self._dims.pop(function, None)
        self._counts.pop(function, None)
        self._pending = [p for p in self._pending if p.function != function]
        self._pending_fns.discard(function)
        if dim is not None:
            self._arena(self.n_vcpu_classes, dim).release(function)
            self._arena(self.n_mem_classes, dim).release(function)

    # ---------------------------------------------------------- feedback
    def enqueue_update(self, function: str, x: np.ndarray, obs) -> None:
        """Defer one completed-invocation feedback (CSOAA update for
        both agents). Nothing is applied yet — the update is queued and
        applied by the next :meth:`flush`, which every predict for
        ``function`` forces first (the flush-before-predict contract:
        a prediction never reads stale rows of its OWN function;
        updates for other functions touch disjoint rows and may stay
        queued, which is what lets batches grow). ``updates()`` counts
        the queued feedback immediately, so confidence thresholds see
        it without a flush."""
        dim = self._dim_of(function, x)
        xb = np.concatenate([np.asarray(x, F32), np.ones(1, F32)])
        self._pending.append(_PendingUpdate(function, xb, obs))
        self._pending_fns.add(function)
        c = self._counts.setdefault(function, [0, 0])
        c[0] += 1
        c[1] += 1
        # make sure slots exist so growth happens off the predict path
        self._arena(self.n_vcpu_classes, dim).slot(function)
        self._arena(self.n_mem_classes, dim).slot(function)

    # ------------------------------------------------------------- flush
    def flush(self) -> None:
        """Apply every pending update. Passes preserve per-function
        order; each pass touches each agent row at most once."""
        pending = self._pending
        self._pending = []
        self._pending_fns.clear()
        while pending:
            seen = set()
            batch: List[_PendingUpdate] = []
            rest: List[_PendingUpdate] = []
            for p in pending:
                if p.function in seen:
                    rest.append(p)
                else:
                    seen.add(p.function)
                    batch.append(p)
            self._flush_pass(batch)
            pending = rest

    def _cost_matrices(self, batch: Sequence[_PendingUpdate]):
        from repro.core.cost_functions import memory_costs

        obs = [p.obs for p in batch]
        # the vectorized variants win only once the batch amortizes
        # their array-building preamble; tiny batches (the steady-state
        # case) use the scalar functions — both produce bit-identical
        # rows (tests/test_agent_arena.py)
        if len(obs) < 4 or self._vcpu_batch_fn is None:
            vc = np.stack([self.vcpu_cost_fn(o, self.n_vcpu_classes)
                           for o in obs])
            mc = np.stack([memory_costs(o, self.n_mem_classes,
                                        self.mem_class_mb) for o in obs])
        else:
            vc = self._vcpu_batch_fn(obs, self.n_vcpu_classes)
            mc = self._mem_batch_fn(obs, self.n_mem_classes, self.mem_class_mb)
        return vc, mc

    def _flush_pass(self, batch: List[_PendingUpdate]) -> None:
        by_dim: Dict[int, List[int]] = {}
        for i, p in enumerate(batch):
            by_dim.setdefault(len(p.xb) - 1, []).append(i)
        vc, mc = self._cost_matrices(batch)
        for dim, idxs in by_dim.items():
            va = self._arena(self.n_vcpu_classes, dim)
            ma = self._arena(self.n_mem_classes, dim)
            vslots = [va.slot(batch[i].function) for i in idxs]
            mslots = [ma.slot(batch[i].function) for i in idxs]
            xbs = np.stack([batch[i].xb for i in idxs])
            vcosts = np.ascontiguousarray(vc[idxs]).astype(F32)
            mcosts = np.ascontiguousarray(mc[idxs]).astype(F32)
            k = len(idxs)
            per_item = self.n_vcpu_classes + self.n_mem_classes
            if numpy_backend(dim):
                # row-disjoint chunks are exact, so oversized passes
                # (e.g. the pending-cap flush during the learning phase)
                # split instead of falling back to a fresh XLA compile
                step = max(numpy_crossover_rows(dim) // per_item, 1)
                for lo in range(0, k, step):
                    sl = slice(lo, lo + step)
                    self._update_numpy(va, vslots[sl], ma, mslots[sl],
                                       xbs[sl], vcosts[sl], mcosts[sl])
            elif vmap_backend(dim):
                self._update_jax(va, vslots, xbs, vcosts)
                self._update_jax(ma, mslots, xbs, mcosts)
            else:  # sequential reference kernels (always bit-identical)
                for j, i in enumerate(idxs):
                    x = batch[i].xb[:-1]
                    for ar, sl, cs in ((va, vslots[j], vcosts[j]),
                                       (ma, mslots[j], mcosts[j])):
                        w, g2 = _csc_update(
                            jnp.asarray(ar.w[sl]), jnp.asarray(ar.g2[sl]),
                            jnp.asarray(x), jnp.asarray(cs),
                            jnp.asarray(self.lr))
                        ar.w[sl] = np.asarray(w)
                        ar.g2[sl] = np.asarray(g2)

    def _update_numpy(self, va, vslots, ma, mslots, xbs, vcosts, mcosts):
        """One row-stacked exact update covering both resources of the
        whole pass: per-row results are independent, so vCPU (32-class)
        and memory (40-class) blocks concatenate freely."""
        nv, nm = va.n_classes, ma.n_classes
        k, d1 = xbs.shape
        if k == 1:  # steady-state fast path: one completion, both agents
            sv, sm = vslots[0], mslots[0]
            w = np.concatenate([va.w[sv], ma.w[sm]])
            g2 = np.concatenate([va.g2[sv], ma.g2[sm]])
            costs = np.concatenate([vcosts[0], mcosts[0]])
            nw, ng = _update_exact(w, g2, xbs[0], costs, self.lr)
            va.w[sv] = nw[:nv]
            va.g2[sv] = ng[:nv]
            ma.w[sm] = nw[nv:]
            ma.g2[sm] = ng[nv:]
            return
        wv = va.w[vslots].reshape(k * nv, d1)
        wm = ma.w[mslots].reshape(k * nm, d1)
        g2v = va.g2[vslots].reshape(k * nv, d1)
        g2m = ma.g2[mslots].reshape(k * nm, d1)
        w = np.concatenate([wv, wm])
        g2 = np.concatenate([g2v, g2m])
        xb = np.concatenate(
            [np.repeat(xbs, nv, axis=0), np.repeat(xbs, nm, axis=0)]
        )
        costs = np.concatenate([vcosts.reshape(-1), mcosts.reshape(-1)])
        nw, ng = _update_exact(w, g2, xb, costs, self.lr)
        split = k * nv
        va.w[vslots] = nw[:split].reshape(k, nv, d1)
        va.g2[vslots] = ng[:split].reshape(k, nv, d1)
        ma.w[mslots] = nw[split:].reshape(k, nm, d1)
        ma.g2[mslots] = ng[split:].reshape(k, nm, d1)

    @staticmethod
    def _bucket(k: int) -> int:
        return min(1 << (k - 1).bit_length(), _MAX_BUCKET)

    def _update_jax(self, ar: AgentArena, slots: List[int],
                    xbs: np.ndarray, costs: np.ndarray) -> None:
        k, d1 = xbs.shape
        for lo in range(0, k, _MAX_BUCKET):  # never exceed a calibrated shape
            sl = slots[lo:lo + _MAX_BUCKET]
            kc = len(sl)
            kb = self._bucket(kc)
            W = np.zeros((kb, ar.n_classes, d1), F32)
            G2 = np.zeros((kb, ar.n_classes, d1), F32)
            XB = np.zeros((kb, d1), F32)
            C = np.zeros((kb, ar.n_classes), F32)
            W[:kc] = ar.w[sl]
            G2[:kc] = ar.g2[sl]
            XB[:kc] = xbs[lo:lo + kc]
            C[:kc] = costs[lo:lo + kc]
            # padding entries are exact no-ops: zero xb ⇒ zero grad ⇒
            # w/g2 unchanged; padded outputs are simply discarded below
            nw, ng = _batched_update(jnp.asarray(W), jnp.asarray(G2),
                                     jnp.asarray(XB), jnp.asarray(C),
                                     jnp.asarray(self.lr))
            ar.w[sl] = np.asarray(nw)[:kc]
            ar.g2[sl] = np.asarray(ng)[:kc]

    def _predict_jax(self, ar: AgentArena, slots: List[int],
                     xbs: np.ndarray) -> np.ndarray:
        """(k, n_classes) cost rows via the fused vmapped kernel, with
        the same bucket/pad/chunk policy as _update_jax (padded rows'
        outputs are discarded)."""
        k, d1 = xbs.shape
        out = np.empty((k, ar.n_classes), F32)
        for lo in range(0, k, _MAX_BUCKET):
            sl = slots[lo:lo + _MAX_BUCKET]
            kc = len(sl)
            kb = self._bucket(kc)
            W = np.zeros((kb, ar.n_classes, d1), F32)
            XB = np.zeros((kb, d1), F32)
            W[:kc] = ar.w[sl]
            XB[:kc] = xbs[lo:lo + kc]
            costs = _batched_predict(jnp.asarray(W), jnp.asarray(XB))
            out[lo:lo + kc] = np.asarray(costs)[:kc]
        return out

    # ------------------------------------------------------------ predict
    def predict_batch(
        self, items: Sequence[Tuple[str, np.ndarray, bool, bool]]
    ) -> List[Tuple[Optional[int], Optional[int]]]:
        """Arg-min classes for a microbatch of (function, features,
        want_vcpu, want_mem). Flushes pending updates first (the
        ordering rule), then runs all wanted predictions as one fused
        computation per backend group."""
        out: List[Tuple[Optional[int], Optional[int]]] = [
            (None, None) for _ in items
        ]
        by_dim: Dict[int, List[int]] = {}
        for i, (fn, x, want_v, want_m) in enumerate(items):
            if want_v or want_m:
                by_dim.setdefault(self._dim_of(fn, x), []).append(i)
        if not by_dim:
            # nothing will read agent state, so nothing needs to flush;
            # a cap keeps the queue bounded through long learning phases
            if len(self._pending) >= 256:
                self.flush()
            return out
        if self._pending_fns and any(
                items[i][0] in self._pending_fns
                for idxs in by_dim.values() for i in idxs):
            self.flush()
        elif len(self._pending) >= 256:
            self.flush()
        if len(by_dim) == 1 and len(items) == 1:
            (dim, _), = by_dim.items()
            fn, x, want_v, want_m = items[0]
            if numpy_backend(dim):
                out[0] = self._predict_one_numpy(fn, x, dim, want_v, want_m)
                return out
        for dim, idxs in by_dim.items():
            va = self._arena(self.n_vcpu_classes, dim)
            ma = self._arena(self.n_mem_classes, dim)
            nv, nm = self.n_vcpu_classes, self.n_mem_classes
            v_items = [i for i in idxs if items[i][2]]
            m_items = [i for i in idxs if items[i][3]]
            rows = len(v_items) * nv + len(m_items) * nm
            if numpy_backend(dim) and rows <= numpy_crossover_rows(dim):
                xb_of = {
                    i: np.concatenate([np.asarray(items[i][1], F32),
                                       np.ones(1, F32)])
                    for i in idxs
                }
                w = np.concatenate(
                    [va.w[va.slot(items[i][0])] for i in v_items]
                    + [ma.w[ma.slot(items[i][0])] for i in m_items]
                ) if rows else np.zeros((0, dim + 1), F32)
                xb = np.concatenate(
                    [np.repeat(xb_of[i][None, :], nv, axis=0) for i in v_items]
                    + [np.repeat(xb_of[i][None, :], nm, axis=0) for i in m_items]
                ) if rows else np.zeros((0, dim + 1), F32)
                costs = _matvec_exact(w, xb)
                off = 0
                picks: Dict[int, List[Optional[int]]] = {
                    i: [None, None] for i in idxs
                }
                for i in v_items:
                    picks[i][0] = int(np.argmin(costs[off:off + nv]))
                    off += nv
                for i in m_items:
                    picks[i][1] = int(np.argmin(costs[off:off + nm]))
                    off += nm
                for i in idxs:
                    out[i] = (picks[i][0], picks[i][1])
            else:
                res: Dict[int, List[Optional[int]]] = {i: [None, None]
                                                       for i in idxs}
                for slot_items, ar, pos in ((v_items, va, 0), (m_items, ma, 1)):
                    if len(slot_items) >= 2 and vmap_backend(dim):
                        # one fused vmapped dispatch per agent group
                        slots = [ar.slot(items[i][0]) for i in slot_items]
                        xbs = np.zeros((len(slot_items), dim + 1), F32)
                        for j, i in enumerate(slot_items):
                            xbs[j, :dim] = items[i][1]
                            xbs[j, dim] = 1.0
                        costs = self._predict_jax(ar, slots, xbs)
                        for j, i in enumerate(slot_items):
                            res[i][pos] = int(np.argmin(costs[j]))
                    else:
                        for i in slot_items:
                            fn, x = items[i][0], items[i][1]
                            c = _csc_predict(
                                jnp.asarray(ar.w[ar.slot(fn)]),
                                jnp.asarray(x, dtype=jnp.float32),
                                ar.n_classes)
                            res[i][pos] = int(jnp.argmin(c))
                for i in idxs:
                    out[i] = (res[i][0], res[i][1])
        return out

    def _predict_one_numpy(self, fn: str, x: np.ndarray, dim: int,
                           want_v: bool, want_m: bool):
        """Dispatch-free singleton prediction: both agents' regressors
        stacked into one computation, xb broadcast across rows. The
        certified float64 screen picks the arg-min without running the
        exact FMA chain; near-ties (and all-zero agents) fall back to
        the bit-exact matvec."""
        va = self._arena(self.n_vcpu_classes, dim)
        ma = self._arena(self.n_mem_classes, dim)
        nv = self.n_vcpu_classes
        if want_v and want_m:
            w = np.concatenate([va.w[va.slot(fn)], ma.w[ma.slot(fn)]])
        elif want_v:
            w = va.w[va.slot(fn)]
        else:
            w = ma.w[ma.slot(fn)]
        xb64 = np.empty(dim + 1, F64)
        xb64[:dim] = x
        xb64[dim] = 1.0
        if want_v and want_m:
            mv = _argmin_screened(w[:nv], xb64)
            mm = _argmin_screened(w[nv:], xb64) if mv is not None else None
            if mm is not None:
                return (mv, mm)
        else:
            m = _argmin_screened(w, xb64)
            if m is not None:
                return (m, None) if want_v else (None, m)
        costs = _matvec_exact(w, xb64.astype(F32))
        if want_v and want_m:
            return (int(np.argmin(costs[:nv])), int(np.argmin(costs[nv:])))
        m = int(np.argmin(costs))
        return (m, None) if want_v else (None, m)

    def predict(self, function: str, x: np.ndarray, want_vcpu: bool,
                want_mem: bool) -> Tuple[Optional[int], Optional[int]]:
        """Singleton prediction — the event loop's steady state, so it
        skips the batch machinery entirely on the NumPy backend.
        Honors the flush-before-predict contract: pending updates for
        ``function`` are applied first (see :meth:`enqueue_update`);
        pending updates for OTHER functions are left queued unless the
        256-entry cap forces a drain."""
        if not (want_vcpu or want_mem):
            if len(self._pending) >= 256:
                self.flush()
            return (None, None)
        dim = self._dim_of(function, x)
        if numpy_backend(dim):
            if function in self._pending_fns or len(self._pending) >= 256:
                self.flush()
            return self._predict_one_numpy(function, x, dim,
                                           want_vcpu, want_mem)
        return self.predict_batch([(function, x, want_vcpu, want_mem)])[0]

    def predicted_costs(self, function: str, x: np.ndarray):
        """Full cost vectors (vcpu, mem) — diagnostics path."""
        self.flush()
        dim = self._dim_of(function, x)
        va = self._arena(self.n_vcpu_classes, dim)
        ma = self._arena(self.n_mem_classes, dim)
        xb = np.concatenate([np.asarray(x, F32), np.ones(1, F32)])
        if numpy_backend(dim):
            return (
                _matvec_exact(va.w[va.slot(function)], xb),
                _matvec_exact(ma.w[ma.slot(function)], xb),
            )
        return (
            np.asarray(_csc_predict(jnp.asarray(va.w[va.slot(function)]),
                                    jnp.asarray(x, jnp.float32),
                                    va.n_classes)),
            np.asarray(_csc_predict(jnp.asarray(ma.w[ma.slot(function)]),
                                    jnp.asarray(x, jnp.float32),
                                    ma.n_classes)),
        )

    # ------------------------------------------------------------- debug
    def weights(self, function: str):
        """(vcpu_w, vcpu_g2, mem_w, mem_g2) copies for tests; flushes."""
        self.flush()
        dim = self._dims[function]
        va = self._arena(self.n_vcpu_classes, dim)
        ma = self._arena(self.n_mem_classes, dim)
        sv, sm = va.slot(function), ma.slot(function)
        return (va.w[sv].copy(), va.g2[sv].copy(),
                ma.w[sm].copy(), ma.g2[sm].copy())
