"""Alternative ML formulations for the §4.2 study (Figure 6).

The paper empirically compares three ways to structure the online
agents before settling on one-model-per-function:

* ``per-function``   — one (vCPU, mem) agent pair per function (chosen);
* ``one-hot``        — a single agent across ALL functions; feature
  vectors are concatenated per-function blocks with the inactive
  functions zeroed (the model cannot specialize — its allocation pins
  at 9-13 vCPUs, wasting 5x more at p90);
* ``per-input-type`` — one agent per input TYPE (image, video, ...);
  functions sharing a type share a model, so the single-threaded
  function that completes first drags down the multi-threaded one
  (mobilenet vs imageprocess in the paper).

These reuse ``ResourceAllocator`` unchanged — only the agent KEY and the
feature layout differ, which is exactly the paper's point.
"""

from __future__ import annotations

from typing import Dict, List, Sequence

import numpy as np

from repro.core.allocator import Allocation, ResourceAllocator
from repro.core.cost_functions import Observation


class FormulationAllocator:
    """Wraps ResourceAllocator with a configurable agent-key/feature map."""

    def __init__(self, mode: str, functions: Sequence[str],
                 feature_dims: Dict[str, int], input_type_of: Dict[str, str],
                 **alloc_kwargs):
        assert mode in ("per-function", "one-hot", "per-input-type")
        self.mode = mode
        self.functions = list(functions)
        self.feature_dims = feature_dims
        self.input_type_of = input_type_of
        self.inner = ResourceAllocator(**alloc_kwargs)
        self._offsets: Dict[str, int] = {}
        off = 0
        for fn in self.functions:
            self._offsets[fn] = off
            off += feature_dims[fn]
        self._total_dim = off

    def _key_and_features(self, function: str, x: np.ndarray):
        if self.mode == "per-function":
            return function, x
        if self.mode == "per-input-type":
            return self.input_type_of[function], x
        # one-hot: one global agent, block-concatenated features
        big = np.zeros(self._total_dim, np.float32)
        o = self._offsets[function]
        big[o : o + len(x)] = x
        return "__all__", big

    def allocate(self, function: str, x: np.ndarray,
                 input_size_mb: float = 0.0) -> Allocation:
        key, feats = self._key_and_features(function, x)
        return self.inner.allocate(key, feats, input_size_mb)

    def allocate_batch(self, items):
        """Microbatch pass-through: shared-agent modes may map several
        items onto the same key — predictions don't mutate state, so
        duplicates in one batch are safe."""
        mapped = [self._key_and_features(fn, x) for fn, x, _ in items]
        return self.inner.allocate_batch(
            [(key, feats, items[i][2]) for i, (key, feats) in enumerate(mapped)]
        )

    def feedback(self, function: str, x: np.ndarray, obs: Observation) -> None:
        key, feats = self._key_and_features(function, x)
        self.inner.feedback(key, feats, obs)

    def flush(self) -> None:
        self.inner.flush()
