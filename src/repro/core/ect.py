"""Per-input completion-time estimation: the Shabari insight applied
to the front door's forecasts.

The router's estimate-mode scoring and SLO-native admission both hinge
on a per-function UNCONTENDED exec-time estimate. A per-function EWMA
(the PR 5 estimator, kept as the cold prior and the
``SimConfig(estimate_features=False)`` A/B fallback) is input-blind: on
a heavy-tailed input distribution it forecasts the mean for every
invocation, so the large inputs that actually decide SLO compliance are
systematically under-estimated — exactly the "static config can't see
the input" failure mode the paper measures (§3) for allocation, and
Bilal et al. (arXiv 2105.14845) quantify for right-sizing.

:class:`ECTRegressor` replaces the point estimate with a small online
regressor per function over the invocation's ALREADY-COMPUTED feature
vector — the standardized :class:`repro.core.featurizer.Featurizer`
output plus log1p(input MB) that ride the retry payload as the policy's
``aux`` cache — so no extra critical-path featurization is spent on the
estimate. The model is linear in log-exec space (the §2.1 size→time
relations are multiplicative), trained by AdaGrad on squared error, and
deterministic given the observation order, so estimate-mode runs stay
reproducible under a fixed seed.

Safeguards, each pinned by tests/test_ect_admission.py:

* cold prior — below ``ECT_WARMUP_OBS`` observations the regressor
  abstains (:meth:`predict` returns None) and callers fall back to the
  EWMA prior;
* clamp — a prediction may move at most ``ECT_CLAMP``x off the EWMA
  prior, so one early outlier cannot fling the forecast (and with it
  SLO admission) orders of magnitude away;
* dimension guard — a function whose feature schema changes mid-run
  (clone aliases, formulation sweeps) resets its state instead of
  dotting mismatched shapes.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Dict, Optional

import numpy as np

# observations before the regressor overrides the EWMA cold prior
ECT_WARMUP_OBS = 8
# AdaGrad step size on the squared log-space error
ECT_LR = 0.5
# max multiplicative distance a prediction may move off the EWMA prior
ECT_CLAMP = 8.0
# admission="slo" safety factor on a TRAINED per-input estimate: shed
# only when the irreducible forecast exceeds this multiple of the SLO
# budget, so estimator noise (~20% median multiplicative error) cannot
# shed servable work sitting near its SLO (build_slo_table sets SLOs at
# 1.4x best-case exec, putting a large mass of invocations in exactly
# that gray zone)
ECT_SLO_MARGIN = 2.0
# how far the per-input shed margin widens with the regressor's own
# measured log error: effective margin = ECT_SLO_MARGIN x
# exp(ECT_ERR_WIDEN x (err + ECT_ERR_PRIOR / sqrt(n))). The model's
# accuracy is function-specific — a function whose exec the features
# explain well (err -> 0) sheds at the base margin, while one the model
# keeps mispredicting (err ~ log 3) effectively never per-input-sheds,
# however confident a single prediction looks
ECT_ERR_WIDEN = 2.0
# EWMA weight on the per-observation |log prediction error| feed
ECT_ERR_ALPHA = 0.3
# the youth term of the margin's error bound: a just-warmed model's few
# observations understate its true error (the EWMA has barely sampled
# the input distribution), so the bound decays as 1/sqrt(n) like a
# confidence radius instead of trusting the point estimate outright
ECT_ERR_PRIOR = 2.0
# admission="slo" band for an INPUT-BLIND estimate (the EWMA, or a
# regressor echoing its prior): a mean-of-the-distribution forecast can
# sit an order of magnitude above the smallest inputs' exec times (the
# scenario suite's widest function spans ~13x around its mean), so the
# blind path sheds only when even an input that favorable would blow
# the budget
ECT_BLIND_SHED_BAND = 32.0
# observations before admission="slo" trusts ANY estimate enough to
# shed on it. Shedding is irreversible (the work is dropped), so it
# demands a far higher calibration bar than routing: a few heavy first
# draws can hold the early EWMA an order of magnitude above its
# steady-state mean, and a just-warmed regressor is still confidently
# wrong on inputs it has not seen. Budget-expired invocations are shed
# regardless — no estimate is involved in that decision.
ECT_SHED_OBS = 32


@dataclasses.dataclass
class _FnState:
    w: np.ndarray  # bias + feature dims + log1p(input MB)
    g2: np.ndarray  # AdaGrad accumulators, same shape
    n: int = 0
    # EWMA of the model's PRE-UPDATE |log error| on each observation —
    # an honest one-step-ahead accuracy track (the model never grades
    # itself on a point it has already trained on)
    err: float = 0.0


class ECTRegressor:
    """Per-function online regression of log uncontended exec seconds
    on the invocation's feature vector."""

    def __init__(self):
        self._state: Dict[str, _FnState] = {}

    @staticmethod
    def _design(features: np.ndarray, input_mb: float) -> np.ndarray:
        x = np.asarray(features, dtype=np.float64).ravel()
        return np.concatenate(
            ([1.0], x, [math.log1p(max(float(input_mb), 0.0))])
        )

    def observations(self, function: str) -> int:
        st = self._state.get(function)
        return 0 if st is None else st.n

    def log_error(self, function: str) -> float:
        """Upper bound on the model's one-step-ahead |log prediction
        error| for the function: the observed-error EWMA plus a
        ``ECT_ERR_PRIOR / sqrt(n)`` youth term (infinite before any
        observation). exp() of this is the typical multiplicative miss —
        admission widens its shed margin by it."""
        st = self._state.get(function)
        if st is None or st.n == 0:
            return math.inf
        return st.err + ECT_ERR_PRIOR / math.sqrt(st.n)

    def observe(self, function: str, features: np.ndarray, input_mb: float,
                exec_s: float, prior_s: float) -> None:
        """Fold one completed invocation's uncontended exec time into
        the function's regressor (non-positive times are ignored, like
        the EWMA path). The model learns the log RESIDUAL off
        ``prior_s`` (the function's EWMA at observation time), not the
        absolute log time: an untrained model then predicts exactly the
        prior instead of an arbitrary point inside the clamp band, so
        early-training noise degrades gracefully toward the input-blind
        estimator rather than away from it."""
        if exec_s <= 0.0 or prior_s <= 0.0:
            return
        phi = self._design(features, input_mb)
        st = self._state.get(function)
        if st is None or st.w.shape[0] != phi.shape[0]:
            st = _FnState(w=np.zeros(phi.shape[0]),
                          g2=np.zeros(phi.shape[0]))
            self._state[function] = st
        err = float(phi @ st.w) - (math.log(exec_s) - math.log(prior_s))
        st.err = (abs(err) if st.n == 0
                  else (1.0 - ECT_ERR_ALPHA) * st.err
                  + ECT_ERR_ALPHA * abs(err))
        grad = err * phi
        st.g2 += grad * grad
        st.w -= ECT_LR * grad / np.sqrt(st.g2 + 1e-12)
        st.n += 1

    def predict(self, function: str, features: np.ndarray, input_mb: float,
                prior_s: float) -> Optional[float]:
        """The function's per-input exec estimate — the EWMA prior
        scaled by the learned per-input residual — or None while the
        regressor is still inside its warm-up (callers fall back to
        ``prior_s``). Predictions are clamped to within ``ECT_CLAMP``x
        of the prior."""
        st = self._state.get(function)
        if st is None or st.n < ECT_WARMUP_OBS:
            return None
        phi = self._design(features, input_mb)
        if phi.shape[0] != st.w.shape[0]:
            return None
        est = prior_s * math.exp(float(phi @ st.w))
        return min(max(est, prior_s / ECT_CLAMP), prior_s * ECT_CLAMP)
