"""In-memory metadata store (paper Fig. 5): object features + telemetry.

The store sits beside the Resource Allocator; the worker daemons push
per-invocation performance + utilization records here over gRPC in the
paper (a method call in our runtime). The allocator drains pending
records to update its agents off the critical path.
"""

from __future__ import annotations

import collections
import dataclasses
from typing import Deque, Dict, List, Optional, Tuple

import numpy as np

from repro.core.cost_functions import Observation


@dataclasses.dataclass(slots=True)
class InvocationRecord:
    function: str
    invocation_id: int
    features: np.ndarray
    observation: Observation
    finish_time: float


class MetadataStore:
    def __init__(self, history_limit: int = 100_000):
        self._pending: Deque[InvocationRecord] = collections.deque()
        self._history: Deque[InvocationRecord] = collections.deque(maxlen=history_limit)
        self._object_meta: Dict[str, Tuple[str, dict]] = {}

    # ------------------------------------------------ object metadata
    def put_object(self, object_id: str, input_type: str, meta: dict) -> None:
        self._object_meta[object_id] = (input_type, meta)

    def get_object(self, object_id: str) -> Optional[Tuple[str, dict]]:
        return self._object_meta.get(object_id)

    # ------------------------------------------------ telemetry
    def push(self, rec: InvocationRecord) -> None:
        self._pending.append(rec)
        self._history.append(rec)

    def drain(self) -> List[InvocationRecord]:
        out = list(self._pending)
        self._pending.clear()
        return out

    def history(self, function: Optional[str] = None) -> List[InvocationRecord]:
        if function is None:
            return list(self._history)
        return [r for r in self._history if r.function == function]
