"""Per-worker daemon (paper §6): utilization sampling + completion events.

On the real testbed this is two threads — a 10 ms cgroup sampler and a
completion watcher that gRPCs (exec time, cold-start latency, vCPU/mem
utilization series) to the metadata store. In our runtime the simulator
(or the real serving engine) produces the utilization series; the daemon
reduces it to the maxima the cost functions consume and pushes the
record, closing the feedback loop (Fig. 5 step 5).
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np

from repro.core.cost_functions import Observation
from repro.core.metadata_store import InvocationRecord, MetadataStore

SAMPLE_INTERVAL_S = 0.010  # 10 ms cgroup sampling


@dataclasses.dataclass
class UtilizationTrace:
    """What the sampler captured over one invocation's lifetime."""

    vcpu_samples: np.ndarray  # fraction of a core, per sample
    mem_samples_mb: np.ndarray

    @property
    def max_vcpus(self) -> float:
        return float(np.max(self.vcpu_samples)) if self.vcpu_samples.size else 0.0

    @property
    def max_mem_mb(self) -> float:
        return float(np.max(self.mem_samples_mb)) if self.mem_samples_mb.size else 0.0


class WorkerDaemon:
    def __init__(self, store: MetadataStore):
        self.store = store

    def report_completion(
        self,
        *,
        function: str,
        invocation_id: int,
        features: np.ndarray,
        exec_time_s: float,
        slo_s: float,
        alloc_vcpus: int,
        alloc_mem_mb: int,
        trace: UtilizationTrace,
        finish_time: float,
        cold_start: bool,
        oom_killed: bool = False,
    ) -> Observation:
        obs = Observation(
            exec_time_s=exec_time_s,
            slo_s=slo_s,
            alloc_vcpus=alloc_vcpus,
            max_vcpus_used=trace.max_vcpus,
            alloc_mem_mb=alloc_mem_mb,
            max_mem_used_mb=trace.max_mem_mb,
            cold_start=cold_start,
            oom_killed=oom_killed,
        )
        self.store.push(
            InvocationRecord(
                function=function,
                invocation_id=invocation_id,
                features=features,
                observation=obs,
                finish_time=finish_time,
            )
        )
        return obs


# deterministic per-length envelopes, cached: a simulation synthesizes
# one trace per completion, and recomputing linspace + the ramp shapes
# dominated the per-finish cost. Values are identical to the uncached
# computation; only the rng jitter differs per call.
_ENVELOPE_CACHE: dict = {}
_ENVELOPE_CACHE_MAX = 512  # FIFO-evicted; ~16 MB worst case


def _envelopes(n: int):
    env = _ENVELOPE_CACHE.get(n)
    if env is None:
        if len(_ENVELOPE_CACHE) >= _ENVELOPE_CACHE_MAX:
            _ENVELOPE_CACHE.pop(next(iter(_ENVELOPE_CACHE)))
        t = np.linspace(0.0, 1.0, n)
        cpu = np.minimum(1.0, np.minimum(t / 0.1 + 1e-3, (1 - t) / 0.1 + 1e-3))
        mem = np.minimum(1.0, t / 0.3 + 0.2)
        env = (cpu, mem)
        _ENVELOPE_CACHE[n] = env
    return env


def synth_trace(max_vcpus: float, max_mem_mb: float, exec_time_s: float,
                rng: np.random.Generator) -> UtilizationTrace:
    """Build a plausible 10 ms-sampled utilization series whose maxima are
    the given values (ramp-up, plateau with jitter, ramp-down)."""
    n = max(int(exec_time_s / SAMPLE_INTERVAL_S), 4)
    n = min(n, 4096)  # cap the series length for very long invocations
    envelope, mem_envelope = _envelopes(n)
    jitter = 1.0 - 0.05 * rng.random(n)
    v = max_vcpus * envelope * jitter
    m = max_mem_mb * mem_envelope * (1 - 0.02 * rng.random(n))
    # force exact maxima
    if n:
        v[np.argmax(v)] = max_vcpus
        m[np.argmax(m)] = max_mem_mb
    return UtilizationTrace(vcpu_samples=v, mem_samples_mb=m)
