"""Heterogeneous fleet + network topology as first-class objects.

Until this layer existed, every worker in the simulation was an
identical 96-core/10 Gb clone of the paper's §7.1 testbed node and
moving an invocation's input payload to a remote cluster was free.
Both assumptions make the completion-time estimates behind
``routing="estimate"`` and ``admission="slo"`` systematically dishonest
the moment the fleet is not uniform: a "cheap-but-far" placement looks
exactly as good as an "expensive-but-near" one (the price-performance
axis Bilal et al., arXiv 2105.14845, show is where the real wins live),
and spilling a 900 MB heavy-tail input across a WAN link costs nothing.

This module supplies the missing vocabulary, in the shape cluster
simulators like Helix use (machine types and network links as
simulation objects with per-link transmission times):

* :class:`MachineType` — the per-worker hardware contract: physical
  cores and NIC bandwidth (the §5 contention denominators), advertised
  vCPUs / memory / oversubscription limit, the cold-start latency curve
  (container create cost is hardware-dependent), an execution speed
  factor relative to the reference machine, and an optional
  preemptible/price tier for spot-style scheduling policies;
* :class:`Link` / :class:`Topology` — inter-cluster bandwidth/latency.
  An invocation's input payload lives in its HOME cluster's object
  store; a remote placement first moves the payload over the link, so
  :meth:`Topology.transfer_s` is the arrival→cluster transfer time the
  runtime charges (and the router prices) on spills;
* :class:`ClusterSpec` / :class:`FleetSpec` — the composition: ordered
  machine groups per cluster plus the topology between clusters.

The DEFAULT fleet — one uniform machine type built from the
:class:`~repro.serving.simulator.SimConfig` constants, zero-cost links
(:meth:`Topology.is_free`) — reproduces the homogeneous behavior
bit-for-bit: every golden snapshot is byte-identical with
``SimConfig(fleet=None)``, the same A/B discipline as ``legacy_scans``/
``legacy_acquire``. The FleetSpec is also the single source of the §5
model constants: the simulator charges and the router forecasts from
the SAME ``MachineType`` carried on each ``Worker``, so the two can no
longer drift apart through parallel constructor arguments.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Optional, Tuple

from repro.core.image_cache import ImageSpec

#: §7.1 testbed node — the reference machine every exec_factor is
#: relative to, and the defaults SimConfig mirrors.
REF_PHYSICAL_CORES = 96
REF_VCPUS = 90
REF_MEM_MB = 125 * 1024
REF_NIC_GBPS = 10.0
REF_COLD_BASE_S = 0.45
REF_COLD_PER_GB_S = 0.12
#: per-node container-image layer store and registry downlink (only
#: consulted when ``SimConfig(image_cache=...)`` is enabled)
REF_IMAGE_STORE_MB = 20.0 * 1024
REF_REGISTRY_GBPS = 10.0

#: Lognormal jitter the simulator multiplies into every cold-start
#: draw, and its expectation E[lognormal(0, s)] = exp(s^2/2) — the
#: factor the router prices so the estimator matches the runtime's
#: mean, not its median (tests/test_image_cache.py pins the two).
COLD_JITTER_SIGMA = 0.15
COLD_JITTER_MEAN = math.exp(0.5 * COLD_JITTER_SIGMA ** 2)


@dataclasses.dataclass(frozen=True)
class MachineType:
    """One worker hardware configuration.

    ``exec_factor`` scales UNCONTENDED execution time relative to the
    reference machine (>1 = slower silicon); profiles stay
    machine-independent and calibration (``Router.observe_exec``) is
    fed reference-normalized times, so one estimator serves every type.
    ``preemptible``/``price_per_hour`` are the spot-tier metadata:
    placement prefers reliable workers (see ``ShabariScheduler``) and
    price-performance sweeps can cost a fleet without re-deriving it.
    """

    name: str = "ref-96c"
    physical_cores: int = REF_PHYSICAL_CORES
    vcpus: int = REF_VCPUS
    mem_mb: int = REF_MEM_MB
    nic_gbps: float = REF_NIC_GBPS
    cold_base_s: float = REF_COLD_BASE_S
    cold_per_gb_s: float = REF_COLD_PER_GB_S
    exec_factor: float = 1.0
    # per-worker oversubscription cap (the §6 userCPU knob); None means
    # cap at the advertised vCPUs
    vcpu_limit: Optional[int] = None
    preemptible: bool = False
    price_per_hour: float = 1.0
    # container-image layer store size and registry downlink; inert
    # unless SimConfig(image_cache=...) is set (flat-constant cold
    # starts otherwise)
    image_store_mb: float = REF_IMAGE_STORE_MB
    registry_gbps: float = REF_REGISTRY_GBPS

    @property
    def limit(self) -> int:
        return self.vcpus if self.vcpu_limit is None else self.vcpu_limit

    def cold_latency_s(self, mem_mb: int) -> float:
        """Mean-field container-create latency for this machine (the
        simulator multiplies in its lognormal jitter; the router uses
        the mean as-is)."""
        return self.cold_base_s + self.cold_per_gb_s * mem_mb / 1024.0


@dataclasses.dataclass(frozen=True)
class Link:
    """An inter-cluster network link. The default is free (infinite
    bandwidth, zero latency) — the homogeneous-world assumption, kept
    as the default so ``Topology()`` is the exact no-op."""

    gbps: float = math.inf
    latency_s: float = 0.0

    def transfer_s(self, mb: float) -> float:
        if mb <= 0.0:
            return self.latency_s
        return self.latency_s + mb * 0.008 / self.gbps


@dataclasses.dataclass(frozen=True)
class Topology:
    """Pairwise inter-cluster links. Lookups are symmetric — a link
    registered as (i, j) also serves (j, i) — and fall back to
    ``default_link`` for unlisted pairs. Intra-cluster transfer is
    always free (the payload is already in the cluster's object
    store)."""

    default_link: Link = Link()
    links: Tuple[Tuple[Tuple[int, int], Link], ...] = ()

    def __post_init__(self):
        object.__setattr__(
            self, "_table",
            {frozenset(pair): link for pair, link in self.links},
        )

    def link(self, a: int, b: int) -> Link:
        if a == b:
            return Link()
        return self._table.get(frozenset((a, b)), self.default_link)

    def transfer_s(self, src: int, dst: int, mb: float) -> float:
        """Input-payload transfer time for placing an invocation whose
        payload lives in cluster ``src`` onto cluster ``dst``."""
        if src == dst:
            return 0.0
        return self.link(src, dst).transfer_s(mb)

    def is_free(self) -> bool:
        """True when every link is zero-cost — the homogeneous-world
        fast path: the runtime skips transfer charging entirely, so
        default-fleet event streams are bit-identical to pre-topology
        behavior."""
        return all(
            link.latency_s == 0.0 and math.isinf(link.gbps)
            for link in (self.default_link, *(l for _, l in self.links))
        )


@dataclasses.dataclass(frozen=True)
class ClusterSpec:
    """Ordered machine groups composing one cluster: ((type, count),
    ...). Worker ids within the cluster follow group order, so the
    scheduler's home-hash walk sees a deterministic type layout."""

    machines: Tuple[Tuple[MachineType, int], ...]

    @property
    def n_workers(self) -> int:
        return sum(count for _, count in self.machines)

    def worker_machines(self) -> Tuple[MachineType, ...]:
        out = []
        for machine, count in self.machines:
            out.extend([machine] * count)
        return tuple(out)


@dataclasses.dataclass(frozen=True)
class FleetSpec:
    """The whole deployment: clusters (each a machine-group mix) plus
    the network topology between them. ``SimConfig(fleet=...)``
    overrides the uniform n_clusters/n_workers knobs entirely."""

    clusters: Tuple[ClusterSpec, ...]
    topology: Topology = Topology()
    # optional function -> ImageSpec assignments carried with the
    # deployment (tuple of (function, ImageSpec) pairs, hashable);
    # consulted only when SimConfig(image_cache=...) is enabled and the
    # ImageCacheSpec doesn't override them
    images: Tuple[Tuple[str, ImageSpec], ...] = ()

    @property
    def n_clusters(self) -> int:
        return len(self.clusters)

    @staticmethod
    def uniform(n_clusters: int, n_workers: int,
                machine: MachineType,
                topology: Optional[Topology] = None) -> "FleetSpec":
        """The homogeneous fleet: ``n_clusters`` x ``n_workers`` of one
        machine type, free links unless ``topology`` says otherwise."""
        spec = ClusterSpec(machines=((machine, n_workers),))
        return FleetSpec(
            clusters=tuple(spec for _ in range(n_clusters)),
            topology=topology or Topology(),
        )

    def price_per_hour(self) -> float:
        """Fleet cost rate — the denominator of any price-performance
        metric (benchmarks/fleet_bench)."""
        return sum(
            machine.price_per_hour * count
            for cl in self.clusters for machine, count in cl.machines
        )
