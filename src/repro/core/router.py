"""Front-door router: the multi-cluster tier above §5 scheduling.

The paper's testbed is a single 16-worker cluster; a production FaaS
front door balances MANY clusters, where container-pool locality and
spill-over dominate behavior under flash crowds (Fifer, arXiv
2008.12819) and multi-cluster routing is the open decision layer above
per-invocation right-sizing (arXiv 2510.02404). The router applies the
same cold-start-aware philosophy as Shabari's scheduler, one level up:

* ``hashing`` — each function is hashed to a "home" cluster and always
  routed there (warm-pool locality, no load awareness);
* ``spill-over`` (default) — route to the home cluster while it can
  serve the invocation; when the home cluster has no warm container,
  prefer a WARM container on a remote cluster over a local cold start,
  and when the home cluster is saturated, spill to the least-loaded
  remote cluster with capacity;
* ``random`` — seeded uniform cluster choice (the load-oblivious
  baseline for benchmarks/router_bench).

``route`` composes per-cluster :class:`ShabariScheduler` decisions and
is itself side-effect-free: like ``schedule``, it only inspects state,
so the runtime remains the sole owner of load mutation.

Known limitation (inherited from the simulator's load accounting, where
it predates the router): a cold-started container holds no load until
its warm-up completes, so arrivals inside that ~0.5-1 s window see an
unchanged cluster load and can herd onto the same least-loaded remote.
The fix — reserving capacity at placement rather than at start, for
both ``Worker.fits`` and ``_load`` — is a ROADMAP follow-on because it
changes admission semantics (and every golden) across the whole stack.
"""

from __future__ import annotations

import dataclasses
import hashlib
import random
from typing import List, Sequence

from repro.core.allocator import Allocation
from repro.core.cluster import Cluster
from repro.core.scheduler import Decision, ShabariScheduler

ROUTING_POLICIES = ("hashing", "spill-over", "random")


@dataclasses.dataclass
class RouteDecision:
    cluster_idx: int
    decision: Decision
    spilled: bool = False  # placed off the function's home cluster


class Router:
    def __init__(
        self,
        clusters: Sequence[Cluster],
        schedulers: Sequence[ShabariScheduler],
        *,
        routing: str = "spill-over",
        seed: int = 0,
    ):
        assert routing in ROUTING_POLICIES, routing
        assert len(clusters) == len(schedulers) > 0
        # route() composes schedulers[i] decisions with clusters[i]
        # load/warm-pool inspection; a mispaired zip would silently
        # route on the wrong cluster's state
        assert all(
            s.cluster is c for c, s in zip(clusters, schedulers)
        ), "schedulers must be paired 1:1 with clusters, in order"
        self.clusters: List[Cluster] = list(clusters)
        self.schedulers: List[ShabariScheduler] = list(schedulers)
        self.routing = routing
        self._rng = random.Random(seed)
        # per-cluster vCPU capacity is fixed for the cluster's lifetime
        self._capacity = [
            max(sum(w.vcpu_limit for w in cl.workers), 1)
            for cl in self.clusters
        ]
        # observability counters (benchmarks/router_bench)
        self.routed_home = 0
        self.spills_warm = 0  # remote warm container beat a local cold start
        self.spills_cold = 0  # home saturated; cold-started remotely

    # ------------------------------------------------------------ utils
    def home_cluster(self, function: str) -> int:
        # salted so the cluster choice is independent of the scheduler's
        # home-WORKER hash of the same name: with a shared unsalted hash
        # and gcd(n_clusters, n_workers) > 1, every function homed on
        # cluster k would also home on worker k, collapsing the
        # within-cluster cold-placement spread into packing
        h = int(hashlib.md5(b"cluster:" + function.encode()).hexdigest(), 16)
        return h % len(self.clusters)

    def _load(self, ci: int) -> float:
        """vCPU occupancy fraction — the spill-over target metric.
        O(1): the cluster maintains its load aggregate on acquire/
        release, so retry storms don't rescan workers per route."""
        return self.clusters[ci].used_vcpus / self._capacity[ci]

    # ------------------------------------------------------------ route
    def route(self, function: str, alloc: Allocation, now: float) -> RouteDecision:
        n = len(self.clusters)
        if n == 1:
            d = self.schedulers[0].schedule(function, alloc, now)
            if not d.queued:
                self.routed_home += 1
            return RouteDecision(0, d)

        if self.routing == "random":
            ci = self._rng.randrange(n)
            d = self.schedulers[ci].schedule(function, alloc, now)
            spilled = ci != self.home_cluster(function)
            if not spilled:
                if not d.queued:
                    self.routed_home += 1
            elif not d.queued:
                if d.container is not None:
                    self.spills_warm += 1
                else:
                    self.spills_cold += 1
            return RouteDecision(ci, d, spilled=spilled)

        home = self.home_cluster(function)
        d = self.schedulers[home].schedule(function, alloc, now)
        if self.routing == "hashing" or d.container is not None:
            # pinned, or a local warm hit (exact or larger) — stay home.
            # Counters record PLACEMENTS only (queued attempts and their
            # retries don't count), matching the spills_* semantics.
            if not d.queued:
                self.routed_home += 1
            return RouteDecision(home, d)

        # home has no usable warm container: it would cold-start (if it
        # has headroom) or queue. Least-loaded-first over the remotes;
        # ties break on cluster index, keeping the walk deterministic.
        home_load = self._load(home)
        remotes = sorted(
            (self._load(ci), ci) for ci in range(n) if ci != home
        )

        # cold-start-aware: a remote WARM container beats a local cold
        # start (container create latency >> cross-cluster routing) —
        # but only on a remote under LESS load than home. Spilling onto
        # an equally- or more-loaded cluster trades the cold start for
        # co-runner contention and smears the function's warm pool
        # across clusters, raising everyone's future cold-start rate.
        # route() mutates nothing, so decisions computed here stay valid
        # for the saturation pass below — no re-scheduling per remote.
        probed: dict = {}
        for load, ci in remotes:
            if load >= home_load:
                break  # sorted ascending: no better remote exists
            if not self.clusters[ci].has_idle_warm(function, now):
                continue
            rd = probed[ci] = self.schedulers[ci].schedule(function, alloc, now)
            if rd.container is not None:
                self.spills_warm += 1
                return RouteDecision(ci, rd, spilled=True)

        if not d.queued:
            # no warm container anywhere; home has capacity — cold-start
            # locally so future invocations find their pool at home
            self.routed_home += 1
            return RouteDecision(home, d)

        # home saturated: spill to the least-loaded remote cluster that
        # can actually take it (its scheduler may still find a warm
        # container the load-guarded pass above skipped)
        for _, ci in remotes:
            rd = probed.get(ci)
            if rd is None:
                rd = self.schedulers[ci].schedule(function, alloc, now)
            if not rd.queued:
                if rd.container is not None:
                    self.spills_warm += 1
                else:
                    self.spills_cold += 1
                return RouteDecision(ci, rd, spilled=True)

        return RouteDecision(home, d)  # saturated everywhere -> queued
