"""Front-door router: the multi-cluster tier above §5 scheduling.

The paper's testbed is a single 16-worker cluster; a production FaaS
front door balances MANY clusters, where container-pool locality and
spill-over dominate behavior under flash crowds (Fifer, arXiv
2008.12819) and multi-cluster routing is the open decision layer above
per-invocation right-sizing (arXiv 2510.02404). The router applies the
same cold-start-aware philosophy as Shabari's scheduler, one level up:

* ``hashing`` — each function is hashed to a "home" cluster and always
  routed there (warm-pool locality, no load awareness);
* ``spill-over`` (default) — route to the home cluster while it can
  serve the invocation; when the home cluster has no warm container,
  prefer a WARM container on a remote cluster over a local cold start,
  and when the home cluster is saturated, spill to the least-loaded
  remote cluster with capacity;
* ``random`` — seeded uniform cluster choice (the load-oblivious
  baseline for benchmarks/router_bench).

``route`` composes per-cluster :class:`ShabariScheduler` decisions and
is itself side-effect-free: like ``schedule``, it only inspects state,
so the runtime remains the sole owner of load mutation.

The ``_load`` signal is truthful about in-flight cold starts: the
runtime reserves capacity at PLACEMENT (``Worker.reserve``), so a
cold-started container counts against its cluster's load for the whole
warm-up window and arrivals inside that ~0.5-1 s window no longer herd
onto the same least-loaded remote (the old acquire-on-start behavior is
kept behind ``SimConfig(legacy_acquire=True)`` for A/B).

On top of that signal the router applies fleet-wide ADMISSION CONTROL:
when every cluster's committed load (running + reserved) exceeds the
``admission_headroom`` occupancy fraction, new arrivals are either shed
at the front door (``admission="shed"``) or held in the front-door
queue without probing any scheduler (``admission="queue"``); the
default ``admission="none"`` admits everything and lets per-cluster
queueing absorb overload, as before.
"""

from __future__ import annotations

import dataclasses
import hashlib
import random
from typing import List, Sequence

from repro.core.allocator import Allocation
from repro.core.cluster import Cluster
from repro.core.scheduler import Decision, ShabariScheduler

ROUTING_POLICIES = ("hashing", "spill-over", "random")
ADMISSION_POLICIES = ("none", "shed", "queue")


@dataclasses.dataclass
class RouteDecision:
    cluster_idx: int
    decision: Decision
    spilled: bool = False  # placed off the function's home cluster
    shed: bool = False  # rejected by fleet-wide admission control


class Router:
    def __init__(
        self,
        clusters: Sequence[Cluster],
        schedulers: Sequence[ShabariScheduler],
        *,
        routing: str = "spill-over",
        seed: int = 0,
        admission: str = "none",
        admission_headroom: float = 0.95,
    ):
        assert routing in ROUTING_POLICIES, routing
        assert admission in ADMISSION_POLICIES, admission
        assert 0.0 < admission_headroom <= 1.0 or admission == "none"
        assert len(clusters) == len(schedulers) > 0
        # route() composes schedulers[i] decisions with clusters[i]
        # load/warm-pool inspection; a mispaired zip would silently
        # route on the wrong cluster's state
        assert all(
            s.cluster is c for c, s in zip(clusters, schedulers)
        ), "schedulers must be paired 1:1 with clusters, in order"
        self.clusters: List[Cluster] = list(clusters)
        self.schedulers: List[ShabariScheduler] = list(schedulers)
        self.routing = routing
        self.admission = admission
        self.admission_headroom = admission_headroom
        self._rng = random.Random(seed)
        # per-cluster vCPU capacity is fixed for the cluster's lifetime
        self._capacity = [
            max(sum(w.vcpu_limit for w in cl.workers), 1)
            for cl in self.clusters
        ]
        # observability counters (benchmarks/router_bench + admission_bench)
        self.routed_home = 0
        self.spills_warm = 0  # remote warm container beat a local cold start
        self.spills_cold = 0  # home saturated; cold-started remotely
        self.admission_shed = 0  # arrivals rejected at the front door
        # queue-mode rejections count EVENTS, not arrivals: a held
        # arrival re-enters route() on every retry and increments this
        # each time (the router cannot tell a retry from a new arrival)
        self.admission_queue_events = 0

    # ------------------------------------------------------------ utils
    def home_cluster(self, function: str) -> int:
        # salted so the cluster choice is independent of the scheduler's
        # home-WORKER hash of the same name: with a shared unsalted hash
        # and gcd(n_clusters, n_workers) > 1, every function homed on
        # cluster k would also home on worker k, collapsing the
        # within-cluster cold-placement spread into packing
        h = int(hashlib.md5(b"cluster:" + function.encode()).hexdigest(), 16)
        return h % len(self.clusters)

    def _load(self, ci: int) -> float:
        """Committed vCPU occupancy fraction — the spill-over target and
        admission-control metric. Includes warming reservations (the
        cluster's used_vcpus count them), so in-flight cold starts are
        visible the moment they are placed. O(1): the cluster maintains
        its load aggregate on acquire/release/reserve, so retry storms
        don't rescan workers per route."""
        return self.clusters[ci].used_vcpus / self._capacity[ci]

    def _admission_reject(self) -> bool:
        """Fleet-wide overload test: every cluster's committed load
        (running + warming reservations) is past the headroom fraction.
        One under-headroom cluster is enough to admit — per-cluster
        saturation is the schedulers' business, not the front door's."""
        if self.admission == "none":
            return False
        return all(
            self._load(ci) >= self.admission_headroom
            for ci in range(len(self.clusters))
        )

    # ------------------------------------------------------------ route
    def route(self, function: str, alloc: Allocation, now: float) -> RouteDecision:
        n = len(self.clusters)
        if self._admission_reject():
            home = 0 if n == 1 else self.home_cluster(function)
            rejected = Decision(None, cold_start=False, background_launch=None,
                                queued=True)
            if self.admission == "shed":
                self.admission_shed += 1
                return RouteDecision(home, rejected, shed=True)
            self.admission_queue_events += 1  # queue-at-front-door: retry later
            return RouteDecision(home, rejected)
        if n == 1:
            d = self.schedulers[0].schedule(function, alloc, now)
            if not d.queued:
                self.routed_home += 1
            return RouteDecision(0, d)

        if self.routing == "random":
            ci = self._rng.randrange(n)
            d = self.schedulers[ci].schedule(function, alloc, now)
            spilled = ci != self.home_cluster(function)
            if not spilled:
                if not d.queued:
                    self.routed_home += 1
            elif not d.queued:
                if d.container is not None:
                    self.spills_warm += 1
                else:
                    self.spills_cold += 1
            return RouteDecision(ci, d, spilled=spilled)

        home = self.home_cluster(function)
        d = self.schedulers[home].schedule(function, alloc, now)
        if self.routing == "hashing" or d.container is not None:
            # pinned, or a local warm hit (exact or larger) — stay home.
            # Counters record PLACEMENTS only (queued attempts and their
            # retries don't count), matching the spills_* semantics.
            if not d.queued:
                self.routed_home += 1
            return RouteDecision(home, d)

        # home has no usable warm container: it would cold-start (if it
        # has headroom) or queue. Least-loaded-first over the remotes;
        # ties break on cluster index, keeping the walk deterministic.
        home_load = self._load(home)
        remotes = sorted(
            (self._load(ci), ci) for ci in range(n) if ci != home
        )

        # cold-start-aware: a remote WARM container beats a local cold
        # start (container create latency >> cross-cluster routing) —
        # but only on a remote under LESS load than home. Spilling onto
        # an equally- or more-loaded cluster trades the cold start for
        # co-runner contention and smears the function's warm pool
        # across clusters, raising everyone's future cold-start rate.
        # route() mutates nothing, so decisions computed here stay valid
        # for the saturation pass below — no re-scheduling per remote.
        probed: dict = {}
        for load, ci in remotes:
            if load >= home_load:
                break  # sorted ascending: no better remote exists
            if not self.clusters[ci].has_idle_warm(function, now):
                continue
            rd = probed[ci] = self.schedulers[ci].schedule(function, alloc, now)
            if rd.container is not None:
                self.spills_warm += 1
                return RouteDecision(ci, rd, spilled=True)

        if not d.queued:
            # no warm container anywhere; home has capacity — cold-start
            # locally so future invocations find their pool at home
            self.routed_home += 1
            return RouteDecision(home, d)

        # home saturated: spill to the least-loaded remote cluster that
        # can actually take it (its scheduler may still find a warm
        # container the load-guarded pass above skipped)
        for _, ci in remotes:
            rd = probed.get(ci)
            if rd is None:
                rd = self.schedulers[ci].schedule(function, alloc, now)
            if not rd.queued:
                if rd.container is not None:
                    self.spills_warm += 1
                else:
                    self.spills_cold += 1
                return RouteDecision(ci, rd, spilled=True)

        return RouteDecision(home, d)  # saturated everywhere -> queued
