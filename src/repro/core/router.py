"""Front-door router: the multi-cluster tier above §5 scheduling.

The paper's testbed is a single 16-worker cluster; a production FaaS
front door balances MANY clusters, where container-pool locality and
spill-over dominate behavior under flash crowds (Fifer, arXiv
2008.12819) and multi-cluster routing is the open decision layer above
per-invocation right-sizing (arXiv 2510.02404). The router applies the
same cold-start-aware philosophy as Shabari's scheduler, one level up:

Four routing modes (``SimConfig.routing`` selects):

* ``hashing`` — each function is hashed to a "home" cluster and always
  routed there (warm-pool locality, no load awareness);
* ``spill-over`` (default) — route to the home cluster while it can
  serve the invocation; when the home cluster has no warm container,
  prefer a WARM container on a remote cluster over a local cold start,
  and when the home cluster is saturated, spill to the least-loaded
  remote cluster with capacity. Spill decisions rank candidates by raw
  committed-LOAD fraction;
* ``estimate`` — score EVERY candidate cluster by estimated completion
  time (ECT) and route to the minimum (ties prefer home, then lower
  index). The ECT combines, per candidate: residual wait for a warm or
  WARMING-SOON container (an uncommitted background launch whose
  ``warm_at`` falls within ``estimate_horizon_s`` — a placement target
  no other mode can see), expected cold-start latency for the predicted
  container size, scheduling overhead, and the §5 contention slowdown
  from the candidate worker's ``active_demand_vcpus`` /
  ``active_net_gbps`` aggregates applied to a per-function execution
  estimate calibrated online from observed exec times
  (:meth:`Router.observe_exec`). Spills happen only when the estimate
  says a remote placement finishes sooner — a contended home warm pool
  loses to an idle remote cold start once the slowdown exceeds the
  cold-start price. Unlike the other modes this one does NOT degenerate
  at ``n_clusters=1``: warming-soon binding still short-circuits cold
  starts inside a single cluster;
* ``random`` — seeded uniform cluster choice (the load-oblivious
  baseline for benchmarks/router_bench).

``route`` composes per-cluster :class:`ShabariScheduler` decisions and
is itself side-effect-free: like ``schedule``, it only inspects state,
so the runtime remains the sole owner of load mutation. The one
exception is estimate mode's warming-soon choice, which returns a
``Decision.pending`` container for the RUNTIME to commit (mark busy +
reserve) — the router still mutates nothing itself. ``RouteDecision.
est_s`` carries the winning estimate for observability (None outside
estimate mode).

The ``_load`` signal is truthful about in-flight cold starts: the
runtime reserves capacity at PLACEMENT (``Worker.reserve``), so a
cold-started container counts against its cluster's load for the whole
warm-up window and arrivals inside that ~0.5-1 s window no longer herd
onto the same least-loaded remote (the old acquire-on-start behavior is
kept behind ``SimConfig(legacy_acquire=True)`` for A/B).

On top of that signal the router applies front-door ADMISSION CONTROL:

* ``admission="shed"`` / ``"queue"`` — the load-headroom test: when
  every cluster's committed load (running + reserved) exceeds the
  ``admission_headroom`` occupancy fraction, new arrivals are shed at
  the front door or held in the front-door queue without probing any
  scheduler;
* ``admission="slo"`` — the SLO-native test: instead of fleet-wide
  load, compute the MINIMUM completion-time estimate across clusters
  (the same ``_estimate`` scoring estimate routing uses, so it works
  under any routing policy) and shed exactly the invocations whose
  best estimate already exceeds their remaining SLO budget — work that
  cannot be served in time no matter where it lands, which the
  load-headroom test cannot distinguish from servable work. Functions
  with no calibration yet are always admitted (never shed on the bare
  prior);
* the default ``admission="none"`` admits everything and lets
  per-cluster queueing absorb overload, as before.

The exec estimate behind both the scoring and the SLO test is
PER-INPUT when the caller supplies the invocation's feature vector
(``route(..., features=..., input_mb=...)``): observed completions
train a per-function online regressor (:mod:`repro.core.ect`) over the
Featurizer output + input size, with the per-function EWMA as the cold
prior. ``estimate_features=False`` restores the input-blind EWMA-only
estimator for A/B.
"""

from __future__ import annotations

import dataclasses
import hashlib
import math
import random

import numpy as np
from typing import Callable, Dict, List, Optional, Sequence, Tuple

from repro.core.allocator import Allocation
from repro.core.cluster import Cluster, Worker
from repro.core.fleet import COLD_JITTER_MEAN, Topology
from repro.core.ect import (
    ECT_BLIND_SHED_BAND,
    ECT_ERR_WIDEN,
    ECT_SHED_OBS,
    ECT_SLO_MARGIN,
    ECT_WARMUP_OBS,
    ECTRegressor,
)
from repro.core.scheduler import Decision, ShabariScheduler

ROUTING_POLICIES = ("hashing", "spill-over", "estimate", "random")
ADMISSION_POLICIES = ("none", "shed", "queue", "slo")

# estimate-mode calibration: EWMA smoothing for observed per-function
# exec times, and the prior used before the first observation (seconds)
EXEC_EWMA_ALPHA = 0.3
DEFAULT_EXEC_ESTIMATE_S = 1.0


@dataclasses.dataclass(slots=True)
class RouteDecision:
    cluster_idx: int
    decision: Decision
    spilled: bool = False  # placed off the function's home cluster
    shed: bool = False  # rejected by fleet-wide admission control
    # estimate mode: the winning candidate's estimated completion time
    # (seconds from now until the invocation would finish), None for
    # every other routing policy and for queued/shed outcomes
    est_s: Optional[float] = None


class Router:
    def __init__(
        self,
        clusters: Sequence[Cluster],
        schedulers: Sequence[ShabariScheduler],
        *,
        routing: str = "spill-over",
        seed: int = 0,
        admission: str = "none",
        admission_headroom: float = 0.95,
        estimate_horizon_s: float = 1.5,
        sched_overhead_s: float = 0.001,
        topology: Optional[Topology] = None,
        price_transfer: bool = True,
        pool_key: Optional[Callable[[str], str]] = None,
        network_fed: Optional[Callable[[str], bool]] = None,
        estimate_features: bool = True,
        image_resolver=None,  # function -> ImageSpec; prices each cold
        # candidate's residual registry pull (None = flat cold curve)
    ):
        assert routing in ROUTING_POLICIES, routing
        assert admission in ADMISSION_POLICIES, admission
        assert 0.0 < admission_headroom <= 1.0 or admission == "none"
        assert len(clusters) == len(schedulers) > 0
        # route() composes schedulers[i] decisions with clusters[i]
        # load/warm-pool inspection; a mispaired zip would silently
        # route on the wrong cluster's state
        assert all(
            s.cluster is c for c, s in zip(clusters, schedulers)
        ), "schedulers must be paired 1:1 with clusters, in order"
        self.clusters: List[Cluster] = list(clusters)
        self.schedulers: List[ShabariScheduler] = list(schedulers)
        self.routing = routing
        self.admission = admission
        self.admission_headroom = admission_headroom
        # Estimate-mode hardware model: cold-start curve, §5 contention
        # denominators, and exec-speed factor all come from each
        # candidate Worker's OWN MachineType (repro.core.fleet) — the
        # exact hardware the runtime will charge, one source of truth
        # instead of parallel constructor constants that can drift.
        # The topology prices the input-payload transfer a remote
        # placement pays; price_transfer=False scores spills as free
        # (the pre-fleet assumption, kept for A/B — fleet_bench).
        assert estimate_horizon_s >= 0.0
        self.estimate_horizon_s = estimate_horizon_s
        self.sched_overhead_s = sched_overhead_s
        self.topology = topology
        self.price_transfer = price_transfer
        # transfer pricing short-circuits on free topologies (the
        # default), so uniform fleets never hash home clusters per score
        self._price_transfer_active = (
            price_transfer and topology is not None
            and not topology.is_free()
        )
        self.network_fed = network_fed
        self.image_resolver = image_resolver
        # calibration pool key: estimator state (EWMAs, observation
        # counts, the per-input regressor) is keyed by pool_key(fn) —
        # the simulator passes base_function, so clone aliases (fn::k)
        # share exec evidence instead of each relearning from scratch.
        # Identity when None.
        self._pool: Callable[[str], str] = pool_key or (lambda fn: fn)
        # per-pool EWMAs of observed UNCONTENDED exec seconds and
        # object-store NIC draw — the calibration state behind
        # _exec_estimate/_slowdown (fed by observe_exec). The exec EWMA
        # doubles as the cold prior (and clamp anchor) for the
        # per-input regressor below.
        self._exec_ewma: Dict[str, float] = {}
        self._net_ewma: Dict[str, float] = {}
        # per-function completion counts behind the EWMAs — admission
        # ("slo") refuses to shed on estimates younger than ECT_SHED_OBS
        self._exec_obs: Dict[str, int] = {}
        # per-input exec estimation: a per-function online regressor
        # over the invocation's feature vector (repro.core.ect);
        # estimate_features=False keeps the EWMA-only estimator for A/B
        self.estimate_features = estimate_features
        self._ect = ECTRegressor()
        self._rng = random.Random(seed)
        # per-cluster vCPU capacity is fixed for the cluster's lifetime
        self._capacity = [
            max(sum(w.vcpu_limit for w in cl.workers), 1)
            for cl in self.clusters
        ]
        # home_cluster is a pure function of the name; memoize the md5
        self._home_cache: Dict[str, int] = {}
        # observability counters (benchmarks/router_bench + admission_bench)
        self.routed_home = 0
        self.spills_warm = 0  # remote warm container beat a local cold start
        self.spills_cold = 0  # home saturated; cold-started remotely
        # estimate mode: invocations bound to a still-warming container
        # (counted IN ADDITION to routed_home/spills_warm)
        self.binds_warming = 0
        self.admission_shed = 0  # arrivals rejected at the front door
        # the admission="slo" slice of admission_shed: invocations whose
        # best completion-time estimate exceeded their SLO budget
        self.admission_slo_shed = 0
        # slo-mode invocations HELD instead of shed: the contended
        # estimate said "doomed" but a warm/warming-soon container's
        # optimistic (contention-free) ECT still fits the remaining
        # budget, so the arrival waits at the front door for the
        # contention to drain rather than being irreversibly dropped
        self.admission_slo_held = 0
        # queue-mode rejections count EVENTS, not arrivals: a held
        # arrival re-enters route() on every retry and increments this
        # each time (the router cannot tell a retry from a new arrival)
        self.admission_queue_events = 0

    # ------------------------------------------------------------ utils
    def home_cluster(self, function: str) -> int:
        # salted so the cluster choice is independent of the scheduler's
        # home-WORKER hash of the same name: with a shared unsalted hash
        # and gcd(n_clusters, n_workers) > 1, every function homed on
        # cluster k would also home on worker k, collapsing the
        # within-cluster cold-placement spread into packing
        h = self._home_cache.get(function)
        if h is None:
            h = int(
                hashlib.md5(b"cluster:" + function.encode()).hexdigest(), 16
            ) % len(self.clusters)
            self._home_cache[function] = h
        return h

    def _load(self, ci: int) -> float:
        """Committed vCPU occupancy fraction — the spill-over target and
        admission-control metric. Includes warming reservations (the
        cluster's used_vcpus count them), so in-flight cold starts are
        visible the moment they are placed. O(1): the cluster maintains
        its load aggregate on acquire/release/reserve, so retry storms
        don't rescan workers per route."""
        return self.clusters[ci].used_vcpus / self._capacity[ci]

    def _admission_reject(self) -> bool:
        """Fleet-wide overload test: every cluster's committed load
        (running + warming reservations) is past the headroom fraction.
        One under-headroom cluster is enough to admit — per-cluster
        saturation is the schedulers' business, not the front door's."""
        if self.admission == "none":
            return False
        # plain loop, not all(genexpr): this runs once per retry of
        # every front-door-held arrival, and a saturated fleet retries
        # in storms — generator frames would dominate the retry cost
        hr = self.admission_headroom
        for cl, cap in zip(self.clusters, self._capacity):
            if cl.used_vcpus / cap < hr:
                return False
        return True

    def try_requeue(self) -> bool:
        """Front-door fast path for RETRIES held by queue-mode
        admission: when the fleet is still past the headroom,
        ``route()`` would rebuild the identical queued decision without
        probing any scheduler — so report "still held" directly,
        replicating route()'s only side effect in that branch (the
        ``admission_queue_events`` counter). Returns False in every
        other admission mode (including "shed", whose retries must
        reach route() to be dropped) and whenever the fleet has
        headroom again."""
        if self.admission != "queue":
            return False
        # same test as _admission_reject, inlined: this is the hottest
        # call in a retry storm (once per held arrival per interval)
        hr = self.admission_headroom
        for cl, cap in zip(self.clusters, self._capacity):
            if cl.used_vcpus / cap < hr:
                return False
        self.admission_queue_events += 1
        return True

    # ------------------------------------------------- estimate scoring
    def observe_exec(self, function: str, base_exec_s: float,
                     net_gbps: float = 0.0, *, features=None,
                     input_mb: Optional[float] = None) -> None:
        """Estimator calibration hook: the runtime reports each
        completion's UNCONTENDED execution time (seconds; the §5
        contention factor already divided out, so candidate scoring can
        re-apply each candidate's own slowdown without double counting)
        and its object-store NIC draw (Gbps; 0 for non-network-fed
        functions). Both fold into per-function EWMAs
        (``EXEC_EWMA_ALPHA``); functions with no observation yet use
        ``DEFAULT_EXEC_ESTIMATE_S`` / zero draw. When the caller also
        supplies the invocation's feature vector (+ input MB), the
        observation additionally trains the per-input regressor
        (:mod:`repro.core.ect`) unless ``estimate_features`` is off.
        The feed is deterministic given the event order, so
        estimate-mode runs stay reproducible under a fixed seed.

        The reported time is REFERENCE-machine normalized (the runtime
        divides out its worker's exec-speed factor along with the
        contention slowdown), so one estimator serves every machine
        type — candidate scoring re-applies each candidate's own
        factor. State is keyed by the calibration pool
        (``pool_key``), so clone aliases share one model."""
        if base_exec_s <= 0.0:
            return
        key = self._pool(function)
        prev = self._exec_ewma.get(key)
        self._exec_ewma[key] = (
            base_exec_s if prev is None
            else (1.0 - EXEC_EWMA_ALPHA) * prev + EXEC_EWMA_ALPHA * base_exec_s
        )
        self._exec_obs[key] = self._exec_obs.get(key, 0) + 1
        prev_net = self._net_ewma.get(key)
        self._net_ewma[key] = (
            net_gbps if prev_net is None
            else (1.0 - EXEC_EWMA_ALPHA) * prev_net
            + EXEC_EWMA_ALPHA * net_gbps
        )
        if self.estimate_features and features is not None:
            # train on the residual off the pre-update EWMA (first
            # observation: off itself, a zero residual)
            self._ect.observe(key, features,
                              input_mb if input_mb is not None else 0.0,
                              base_exec_s,
                              prev if prev is not None else base_exec_s)

    def _exec_estimate(self, function: str, features=None,
                       input_mb: Optional[float] = None) -> float:
        """Per-function exec forecast: the per-input regressor when it
        is trained and the caller supplied this invocation's features,
        else the EWMA (also the regressor's cold prior and clamp
        anchor); ``DEFAULT_EXEC_ESTIMATE_S`` before any observation.
        Reference-machine seconds — callers scale by the candidate
        worker's ``exec_factor``."""
        key = self._pool(function)
        prior = self._exec_ewma.get(key, DEFAULT_EXEC_ESTIMATE_S)
        if self.estimate_features and features is not None:
            est = self._ect.predict(
                key, features,
                input_mb if input_mb is not None else 0.0, prior)
            if est is not None:
                return est
        return prior

    def _transfer_s(self, function: str, ci: int,
                    input_mb: Optional[float]) -> float:
        """Input-payload transfer price for serving ``function`` on
        cluster ``ci``: the payload lives in the home cluster's object
        store, so remote placements pay the link (exactly what the
        runtime charges). 0.0 on free topologies or with
        ``price_transfer=False`` (the transfer-BLIND A/B arm)."""
        if not self._price_transfer_active:
            return 0.0
        return self.topology.transfer_s(
            self.home_cluster(function), ci,
            input_mb if input_mb is not None else 0.0)

    def _slowdown(self, w: Worker, function: str, vcpus: float) -> float:
        """Forecast §5 contention on ``w`` if this invocation lands
        there: CPU slowdown from active parallel demand plus our own
        footprint (``vcpus`` — the size the invocation will actually
        RUN at, i.e. the bound container's size for warm/warming binds,
        which case-(2) can make larger than the request; an upper bound
        on the function's true demand), NIC slowdown from current
        object-store draw plus our own calibrated draw (the net EWMA;
        the runtime charges the arriving invocation's draw too, so the
        forecast must or it would systematically understate busy-NIC
        placements) for network-fed functions. O(1) — reads the
        worker's incremental aggregates and its own MachineType's §5
        denominators (cores, NIC) — the same values the runtime
        divides by."""
        cpu = max(
            1.0,
            (w.active_demand_vcpus + float(vcpus)) / w.machine.physical_cores,
        )
        net = 1.0
        if self.network_fed is not None and self.network_fed(function):
            own = self._net_ewma.get(self._pool(function), 0.0)
            net = max(1.0, (w.active_net_gbps + own) / w.machine.nic_gbps)
        return max(cpu, net)

    def _estimate(self, ci: int, function: str, alloc: Allocation,
                  now: float, features=None,
                  input_mb: Optional[float] = None
                  ) -> Tuple[float, str, object]:
        """Estimated completion time if cluster ``ci`` served this
        invocation, as ``(est_s, kind, payload)`` with kind one of
        ``"warm"`` / ``"warming"`` / ``"cold"`` / ``"queue"``.

        The kinds mirror what the cluster's scheduler would actually do
        (warm containers win before cold starts), so the estimate and
        the eventual binding agree; ``"queue"`` (no capacity) is
        returned with an infinite estimate — the route pass never binds
        to a cluster that cannot place."""
        cl = self.clusters[ci]
        exec_est = self._exec_estimate(function, features, input_mb)
        # transfer price for landing on this cluster (0.0 for home,
        # free topologies, or the transfer-blind A/B arm). Mirrors the
        # runtime's charging: warm placements pay it serially, cold and
        # warming placements overlap it with the warm-up wait.
        xfer = self._transfer_s(function, ci, input_mb)
        # (a) warm container usable now — the EXACT container scheduler
        # cases (1)/(2) would bind, so the contention forecast prices
        # the worker that will actually serve the invocation. The
        # slowdown is priced with the CONTAINER's size, not the
        # request's: the runtime runs the invocation at c.vcpus, which
        # a case-(2) bind can make larger than alloc.vcpus. exec_est is
        # reference-machine seconds; the bind worker's exec-speed
        # factor scales it to local silicon.
        c = self.schedulers[ci].warm_candidate(function, alloc.vcpus,
                                               alloc.mem_mb, now)
        if c is not None:
            slow = self._slowdown(c.worker, function, c.vcpus)
            est = (xfer + self.sched_overhead_s
                   + slow * (exec_est * c.worker.machine.exec_factor))
            return (est, "warm", c)
        # (b)/(c) no warm container: compare binding to a warming-soon
        # container (pay the residual warm-up) against this cluster's
        # own cold start, and forecast the cheaper. Unlike the warm
        # case there is no scheduler binding to mirror — the warming
        # bind is a router-invented placement — so a container warming
        # near the horizon edge must not shadow a faster cold start on
        # an idle worker.
        c = cl.warming_soon(function, now, self.estimate_horizon_s,
                            alloc.vcpus, alloc.mem_mb)
        warming_est = None
        if c is not None:
            # like the warm case, a warming bind runs at the container's
            # size (warming_soon only returns >= alloc candidates)
            slow = self._slowdown(c.worker, function, c.vcpus)
            warming_est = (max(c.warm_at - now, xfer)
                           + self.sched_overhead_s
                           + slow * (exec_est
                                     * c.worker.machine.exec_factor))
        w = self.schedulers[ci].cold_candidate(function, alloc.vcpus,
                                               alloc.mem_mb)
        cold_est = None
        if w is not None:
            # cold starts create an exact-size container, at the target
            # machine's own cold-start curve scaled by the EXPECTATION
            # of the simulator's lognormal jitter (COLD_JITTER_MEAN), so
            # the estimator prices the runtime's mean draw rather than
            # its median
            slow = self._slowdown(w, function, alloc.vcpus)
            cold_lat = (w.machine.cold_latency_s(alloc.mem_mb)
                        * COLD_JITTER_MEAN)
            if self.image_resolver is not None and w.image_cache is not None:
                # pull-what's-missing: the registry fetch overlaps the
                # container-create cost, so this candidate's cold price
                # is whichever of the two dominates
                cold_lat = max(cold_lat, w.image_cache.residual_pull_s(
                    self.image_resolver(function)))
            cold_est = (max(cold_lat, xfer)
                        + self.sched_overhead_s
                        + slow * (exec_est * w.machine.exec_factor))
        if warming_est is not None and (cold_est is None
                                        or warming_est <= cold_est):
            # ties prefer the warming bind: its warm-up is already paid
            # for, so no new container (and no new reservation window)
            return (warming_est, "warming", c)
        if cold_est is not None:
            return (cold_est, "cold", w)
        # (d) saturated: nothing can be placed here right now
        return (float("inf"), "queue", None)

    def _route_estimate(self, function: str, alloc: Allocation,
                        now: float, features=None,
                        input_mb: Optional[float] = None,
                        budget_s: Optional[float] = None) -> RouteDecision:
        """Minimum-ECT routing: score every cluster, bind the winner.
        Ties break toward the home cluster (warm-pool locality is free
        tie insurance), then the lower cluster index — fully
        deterministic.

        ``budget_s`` (chain stages only) makes the ranking SLACK-AWARE:
        candidates whose estimate fits the remaining end-to-end budget
        are ranked home-cluster-first — a stage with slack tolerates a
        local cold start instead of spilling to a remote warm container,
        preserving warm pools (and the warm containers themselves) for
        the stages that have no slack to spend. Candidates over budget
        keep the pure min-ECT order, so a critical-path stage (nothing
        fits) degenerates to exactly today's warm-priority behavior.
        ``budget_s=None`` is bit-identical to the pre-chain ranking."""
        n = len(self.clusters)
        home = self.home_cluster(function)
        best = None
        for ci in range(n):
            est, kind, payload = self._estimate(ci, function, alloc, now,
                                                features, input_mb)
            if kind == "queue":
                continue
            if budget_s is not None and est <= budget_s:
                key = (0, ci != home, est, ci)
            else:
                key = (1, est, ci != home, ci)
            if best is None or key < best[0]:
                best = (key, est, ci, kind, payload)
        if best is None:
            # no cluster can place it — same terminal as spill-over's
            # everything-saturated case; the runtime retries
            return RouteDecision(
                home,
                Decision(None, cold_start=False, background_launch=None,
                         queued=True),
            )
        _, est, ci, kind, payload = best
        spilled = ci != home
        if kind == "warming":
            # bind to the still-warming container: the runtime commits
            # it (busy + reservation) and starts the invocation at
            # payload.warm_at — a short wait instead of a cold start
            d = Decision(None, cold_start=False, background_launch=None,
                         pending=payload)
            self.binds_warming += 1
            if spilled:
                self.spills_warm += 1
            else:
                self.routed_home += 1
            return RouteDecision(ci, d, spilled=spilled, est_s=est)
        # the winning candidate was already probed by _estimate on state
        # that cannot have changed since, so build the Decision from it
        # directly instead of re-running schedule()'s warm/cold scans —
        # the constructions below mirror schedule()'s cases (1)-(3)
        if kind == "warm":
            c = payload
            bg = None
            if not (c.vcpus == alloc.vcpus and c.mem_mb == alloc.mem_mb):
                # case 2: proactively launch the exact size in the
                # background, like schedule() would
                sched = self.schedulers[ci]
                if sched.background_launch:
                    w = sched.cold_candidate(function, alloc.vcpus,
                                             alloc.mem_mb)
                    if w is not None:
                        bg = (w, alloc.vcpus, alloc.mem_mb)
            d = Decision(c, cold_start=False, background_launch=bg)
            if spilled:
                self.spills_warm += 1
            else:
                self.routed_home += 1
            return RouteDecision(ci, d, spilled=spilled, est_s=est)
        d = Decision(None, cold_start=True,
                     background_launch=(payload, alloc.vcpus, alloc.mem_mb))
        if spilled:
            self.spills_cold += 1
        else:
            self.routed_home += 1
        return RouteDecision(ci, d, spilled=spilled, est_s=est)

    def _slo_reject(self, function: str, alloc: Allocation, now: float,
                    slo_s: float, features, input_mb) -> bool:
        """SLO-native admission test (``admission="slo"``): shed exactly
        the invocations whose BEST completion-time estimate across the
        fleet already exceeds ``slo_s`` (the invocation's REMAINING SLO
        budget — callers subtract time already spent queueing). A
        non-positive budget is an unconditional shed: the SLO is missed
        no matter what, so running (or retrying) the invocation can
        only waste capacity. Functions with no calibration are always
        admitted — never shed on the bare prior — and an infinite
        estimate (nothing can be placed RIGHT NOW) falls through to
        normal queue/retry, which may still serve the invocation in
        time.

        The min-ECT here is the invocation's IRREDUCIBLE completion
        time: scheduling overhead plus the per-input exec estimate
        under the least-contended worker's §5 slowdown anywhere in the
        fleet. Situational latencies — cold starts, queueing — are
        deliberately NOT charged: a first arrival that must cold-start
        may well blow a tight SLO, but the container it warms is what
        makes every successor servable, so shedding on cold-start
        latency starves the warm pool and cascades (each shed prevents
        the warming that would have admitted the next arrival).
        Violations the situational latency causes are charged to the
        invocation that pays them, exactly as under every other
        admission mode.

        The shed threshold also tracks the ESTIMATE's uncertainty. An
        input-blind estimate (the EWMA, or a just-warmed regressor
        still predicting near its prior) forecasts the MEAN over an
        input distribution whose per-input SLOs track per-input exec
        times, so shedding at the mean would drop every small-input
        invocation of a high-variance function — exactly the servable
        work this mode exists to protect. A shed is also irreversible
        (the work is dropped), so estimates earn shedding rights only
        as their specific failure modes are ruled out, via two bands:

        * a MATURE input-blind estimate (``ECT_SHED_OBS`` completions —
          a few heavy first draws hold the early EWMA an order of
          magnitude above steady state) sheds past
          ``ECT_BLIND_SHED_BAND`` x the budget — beyond the whole
          multiplicative band the input distribution can occupy around
          its mean, the work is doomed whatever the input turns out to
          be;
        * a trained per-input forecast that ACTIVELY flags the input
          as heavier than the prior (prediction above the EWMA — the
          model has learned something this-input-specific, not merely
          echoed the mean) sheds past the much tighter
          ``ECT_SLO_MARGIN`` x band, which is where the heavy-tail
          capacity savings come from. The band widens with the
          regressor's own measured one-step-ahead log error
          (``ECT_ERR_WIDEN``): model accuracy is function-specific, and
          a function the features do not explain must not shed on
          confident-looking mispredictions."""
        if slo_s <= 0.0:
            return True
        key = self._pool(function)
        prior = self._exec_ewma.get(key)
        if prior is None:
            return False
        per_input = (self.estimate_features and features is not None
                     and self._ect.observations(key) >= ECT_WARMUP_OBS)
        exec_est = self._exec_estimate(function, features, input_mb)
        # irreducible ECT PER CLUSTER, then the fleet-wide best: each
        # cluster's cheapest worker (its own §5 slowdown and exec-speed
        # factor) plus that cluster's transfer price. A fleet-min
        # slowdown over all workers would let a far/slow cluster's idle
        # machine mask that no cluster can actually serve in budget.
        # On a uniform free-link fleet this reduces exactly to the old
        # fleet-min expression.
        net_fed = (self.network_fed is not None
                   and self.network_fed(function))
        own_net = self._net_ewma.get(key, 0.0) if net_fed else 0.0
        v = float(alloc.vcpus)

        def _cheapest(cl) -> float:
            a = getattr(cl, "arrays", None)
            if a is None:
                # non-SoA cluster stub (tests): scalar fallback
                return min(
                    self._slowdown(w, function, alloc.vcpus)
                    * (exec_est * w.machine.exec_factor)
                    for w in cl.workers
                )
            # vectorized §5 slowdown over the cluster's worker arrays —
            # elementwise float64 ops match the scalar math bit-for-bit
            cpu = np.maximum(1.0, (a.active_demand_vcpus + v)
                             / a.physical_cores)
            if net_fed:
                cpu = np.maximum(
                    cpu,
                    np.maximum(1.0, (a.active_net_gbps + own_net)
                               / a.nic_gbps),
                )
            return float(np.min(cpu * (exec_est * a.exec_factor)))

        est = min(
            self._transfer_s(function, ci, input_mb)
            + self.sched_overhead_s
            + _cheapest(cl)
            for ci, cl in enumerate(self.clusters)
        )
        if (self._exec_obs.get(key, 0) >= ECT_SHED_OBS
                and est > slo_s * ECT_BLIND_SHED_BAND):
            return True
        margin = ECT_SLO_MARGIN * math.exp(
            ECT_ERR_WIDEN * self._ect.log_error(key))
        return (per_input and exec_est > prior
                and est > slo_s * margin)

    def _warm_hold(self, function: str, alloc: Allocation, now: float,
                   slo_s: float, features=None,
                   input_mb: Optional[float] = None) -> bool:
        """Estimate-aware admission queueing: the contended `_slo_reject`
        estimate said "shed", but shedding is IRREVERSIBLE while holding
        is not — a held arrival re-tests on every retry and the
        non-positive-budget rule still terminates it. So before
        dropping, check whether ANY warm or warming-soon container
        could serve the invocation within budget under an OPTIMISTIC
        (contention-free) estimate: transfer + scheduling overhead +
        the exec forecast at the candidate machine's speed, plus the
        residual warm-up for a warming bind. The contended estimate
        must stay conservative (it gates an irreversible drop); the
        hold test may be optimistic because the §5 contention that
        doomed the contended figure is exactly what draining co-runners
        removes while the arrival waits. No warm capacity anywhere →
        the shed stands."""
        exec_est = self._exec_estimate(function, features, input_mb)
        for ci, sched in enumerate(self.schedulers):
            xfer = self._transfer_s(function, ci, input_mb)
            c = sched.warm_candidate(function, alloc.vcpus, alloc.mem_mb,
                                     now)
            if c is not None:
                est = (xfer + self.sched_overhead_s
                       + exec_est * c.worker.machine.exec_factor)
                if est <= slo_s:
                    return True
            c = self.clusters[ci].warming_soon(
                function, now, self.estimate_horizon_s,
                alloc.vcpus, alloc.mem_mb)
            if c is not None:
                est = (max(c.warm_at - now, xfer) + self.sched_overhead_s
                       + exec_est * c.worker.machine.exec_factor)
                if est <= slo_s:
                    return True
        return False

    # ------------------------------------------------------------ route
    def route(self, function: str, alloc: Allocation, now: float, *,
              features=None, input_mb: Optional[float] = None,
              slo_s: Optional[float] = None,
              budget_s: Optional[float] = None) -> RouteDecision:
        """Place one invocation. ``features``/``input_mb`` are the
        invocation's already-computed feature vector + input size (the
        policy's ``aux`` cache) — optional; without them every estimate
        falls back to the per-function EWMA. ``slo_s`` is the remaining
        SLO budget, read only by ``admission="slo"``. ``budget_s`` is a
        chain stage's remaining end-to-end budget — it makes estimate
        routing slack-aware (see ``_route_estimate``); None everywhere
        else."""
        n = len(self.clusters)
        if self.admission == "slo":
            if slo_s is not None and self._slo_reject(
                    function, alloc, now, slo_s, features, input_mb):
                home = 0 if n == 1 else self.home_cluster(function)
                rejected = Decision(None, cold_start=False,
                                    background_launch=None, queued=True)
                if slo_s > 0.0 and self._warm_hold(
                        function, alloc, now, slo_s, features, input_mb):
                    # hold at the front door instead of shedding: the
                    # runtime retries it like a queued arrival
                    self.admission_slo_held += 1
                    return RouteDecision(home, rejected)
                self.admission_shed += 1
                self.admission_slo_shed += 1
                return RouteDecision(home, rejected, shed=True)
        elif self._admission_reject():
            home = 0 if n == 1 else self.home_cluster(function)
            rejected = Decision(None, cold_start=False, background_launch=None,
                                queued=True)
            if self.admission == "shed":
                self.admission_shed += 1
                return RouteDecision(home, rejected, shed=True)
            self.admission_queue_events += 1  # queue-at-front-door: retry later
            return RouteDecision(home, rejected)
        if self.routing == "estimate":
            # does NOT degenerate at n == 1: warming-soon binding still
            # short-circuits single-cluster cold starts
            return self._route_estimate(function, alloc, now,
                                        features, input_mb, budget_s)
        if n == 1:
            d = self.schedulers[0].schedule(function, alloc, now)
            if not d.queued:
                self.routed_home += 1
            return RouteDecision(0, d)

        if self.routing == "random":
            ci = self._rng.randrange(n)
            d = self.schedulers[ci].schedule(function, alloc, now)
            spilled = ci != self.home_cluster(function)
            if not spilled:
                if not d.queued:
                    self.routed_home += 1
            elif not d.queued:
                if d.container is not None:
                    self.spills_warm += 1
                else:
                    self.spills_cold += 1
            return RouteDecision(ci, d, spilled=spilled)

        home = self.home_cluster(function)
        d = self.schedulers[home].schedule(function, alloc, now)
        if self.routing == "hashing" or d.container is not None:
            # pinned, or a local warm hit (exact or larger) — stay home.
            # Counters record PLACEMENTS only (queued attempts and their
            # retries don't count), matching the spills_* semantics.
            if not d.queued:
                self.routed_home += 1
            return RouteDecision(home, d)

        # home has no usable warm container: it would cold-start (if it
        # has headroom) or queue. Least-loaded-first over the remotes;
        # ties break on cluster index, keeping the walk deterministic.
        home_load = self._load(home)
        remotes = sorted(
            (self._load(ci), ci) for ci in range(n) if ci != home
        )

        # cold-start-aware: a remote WARM container beats a local cold
        # start (container create latency >> cross-cluster routing) —
        # but only on a remote under LESS load than home. Spilling onto
        # an equally- or more-loaded cluster trades the cold start for
        # co-runner contention and smears the function's warm pool
        # across clusters, raising everyone's future cold-start rate.
        # route() mutates nothing, so decisions computed here stay valid
        # for the saturation pass below — no re-scheduling per remote.
        probed: dict = {}
        for load, ci in remotes:
            if load >= home_load:
                break  # sorted ascending: no better remote exists
            if not self.clusters[ci].has_idle_warm(function, now):
                continue
            rd = probed[ci] = self.schedulers[ci].schedule(function, alloc, now)
            if rd.container is not None:
                self.spills_warm += 1
                return RouteDecision(ci, rd, spilled=True)

        if not d.queued:
            # no warm container anywhere; home has capacity — cold-start
            # locally so future invocations find their pool at home
            self.routed_home += 1
            return RouteDecision(home, d)

        # home saturated: spill to the least-loaded remote cluster that
        # can actually take it (its scheduler may still find a warm
        # container the load-guarded pass above skipped)
        for _, ci in remotes:
            rd = probed.get(ci)
            if rd is None:
                rd = self.schedulers[ci].schedule(function, alloc, now)
            if not rd.queued:
                if rd.container is not None:
                    self.spills_warm += 1
                else:
                    self.spills_cold += 1
                return RouteDecision(ci, rd, spilled=True)

        return RouteDecision(home, d)  # saturated everywhere -> queued
