"""Cost functions for the online CSOAA agents (paper §4.3.1-§4.3.2).

Given one completed invocation's observation, produce the per-class cost
vector used to update the agent. The lowest cost is 1; costs grow
linearly away from the target class, with underpredictions (classes
below the target) penalized more steeply than overpredictions.

vCPU variants (Figure 7a):

* Absolute  — every X=0.5 s of SLO violation adds one vCPU class above
  the maximum actually utilized; every Y=1.5 s of slack removes one.
  More aggressive after violations (the variant the paper ships).
* Proportional — scales the current class by exec_time/SLO.

When the SLO was violated but the invocation used <90% of its allocated
vCPUs, the violation is attributed to external factors (contention,
infeasible SLO), and the target is the class actually utilized — NOT a
larger one (this is what keeps single-threaded functions from inflating,
Figure 9b).

Memory (§4.3.2): no SLO feature (no swap — allocation doesn't change
speed, it only must exceed utilization); target = observed utilization
class; underprediction penalty is steeper (OOM kills the invocation).
"""

from __future__ import annotations

import dataclasses
import math

import numpy as np

ABS_X_SECONDS = 0.5  # violation seconds per +1 vCPU class
ABS_Y_SECONDS = 1.5  # slack seconds per -1 vCPU class
HIGH_UTIL_THRESHOLD = 0.9
UNDER_SLOPE = 3.0  # cost slope below the target class
OVER_SLOPE = 1.0  # cost slope above the target class
MEM_UNDER_SLOPE = 6.0  # OOM is worse than an SLO miss
MEM_CLASS_MB = 128  # one class = 128 MB (paper) / 256 MB HBM (TPU mode)


@dataclasses.dataclass(frozen=True, slots=True)
class Observation:
    """What the worker daemon reports for one completed invocation."""

    exec_time_s: float
    slo_s: float
    alloc_vcpus: int
    max_vcpus_used: float
    alloc_mem_mb: int
    max_mem_used_mb: float
    cold_start: bool = False
    oom_killed: bool = False

    @property
    def slo_met(self) -> bool:
        return self.exec_time_s <= self.slo_s

    @property
    def vcpu_util(self) -> float:
        return self.max_vcpus_used / max(self.alloc_vcpus, 1)


def _linear_costs(n_classes: int, target_idx: int,
                  under_slope: float = UNDER_SLOPE,
                  over_slope: float = OVER_SLOPE) -> np.ndarray:
    idx = np.arange(n_classes, dtype=np.float64)
    below = np.maximum(target_idx - idx, 0.0)
    above = np.maximum(idx - target_idx, 0.0)
    return 1.0 + under_slope * below + over_slope * above


def _clamp(i: int, n: int) -> int:
    return max(0, min(n - 1, i))


def absolute_vcpu_costs(obs: Observation, n_classes: int) -> np.ndarray:
    """Classes are vCPU counts 1..n_classes; index c => c+1 vCPUs."""
    cur = _clamp(obs.alloc_vcpus - 1, n_classes)
    used = _clamp(int(math.ceil(obs.max_vcpus_used)) - 1, n_classes)
    if obs.slo_met:
        # vCPUs beyond those utilized cannot have contributed to meeting
        # the SLO (Figure 9b: sentiment never inflates) — start from the
        # utilized class, then the slack says how much further down is
        # safe: one class per Y seconds of slack.
        slack = obs.slo_s - obs.exec_time_s
        down = int(slack / ABS_Y_SECONDS)
        target = min(cur, used) - down
    else:
        if obs.vcpu_util < HIGH_UTIL_THRESHOLD:
            # violation not caused by the allocation — external factors
            target = used
        else:
            violation = obs.exec_time_s - obs.slo_s
            up = 1 + int(violation / ABS_X_SECONDS)
            target = used + up
    return _linear_costs(n_classes, _clamp(target, n_classes))


def proportional_vcpu_costs(obs: Observation, n_classes: int) -> np.ndarray:
    cur = _clamp(obs.alloc_vcpus - 1, n_classes)
    used = _clamp(int(math.ceil(obs.max_vcpus_used)) - 1, n_classes)
    if obs.slo_met:
        scale = obs.exec_time_s / max(obs.slo_s, 1e-9)
        target = int(math.ceil((min(cur, used) + 1) * scale)) - 1
    else:
        if obs.vcpu_util < HIGH_UTIL_THRESHOLD:
            target = used
        else:
            scale = obs.exec_time_s / max(obs.slo_s, 1e-9)
            target = int(math.ceil((used + 1) * scale)) - 1
            target = max(target, used + 1)
    return _linear_costs(n_classes, _clamp(target, n_classes))


def memory_costs(obs: Observation, n_classes: int,
                 class_mb: int = MEM_CLASS_MB) -> np.ndarray:
    """Classes are memory sizes: index c => (c+1)*class_mb MB."""
    if obs.oom_killed:
        # All we know: the true need exceeds the allocation.
        target = _clamp(int(math.ceil(obs.alloc_mem_mb / class_mb)), n_classes)
    else:
        target = _clamp(
            int(math.ceil(obs.max_mem_used_mb / class_mb)) - 1, n_classes
        )
    return _linear_costs(n_classes, target, under_slope=MEM_UNDER_SLOPE)


# ---------------------------------------------------------------------------
# Batched variants (agent-arena flush path)
#
# One call produces the (k, n_classes) cost matrix for k completed
# invocations — the microbatch the arena applies in a single fused
# update. Each row is BIT-IDENTICAL to the corresponding per-observation
# function above (same float64 arithmetic, element-wise; asserted by
# tests/test_agent_arena.py), so deferring cost computation to flush
# time cannot change a single update.
# ---------------------------------------------------------------------------


def _linear_costs_batch(n_classes: int, targets: np.ndarray,
                        under_slope: float = UNDER_SLOPE,
                        over_slope: float = OVER_SLOPE) -> np.ndarray:
    idx = np.arange(n_classes, dtype=np.float64)[None, :]
    t = targets.astype(np.float64)[:, None]
    below = np.maximum(t - idx, 0.0)
    above = np.maximum(idx - t, 0.0)
    return 1.0 + under_slope * below + over_slope * above


def _clamp_batch(i: np.ndarray, n: int) -> np.ndarray:
    return np.clip(i, 0, n - 1)


def _trunc_div(a: np.ndarray, b: float) -> np.ndarray:
    """``int(a / b)`` per element: truncation toward zero, matching the
    scalar path's Python ``int()`` (np.floor_divide would round down)."""
    return np.trunc(a / b).astype(np.int64)


def absolute_vcpu_costs_batch(observations, n_classes: int) -> np.ndarray:
    obs = list(observations)
    exec_s = np.array([o.exec_time_s for o in obs], np.float64)
    slo_s = np.array([o.slo_s for o in obs], np.float64)
    alloc = np.array([o.alloc_vcpus for o in obs], np.int64)
    used_f = np.array([o.max_vcpus_used for o in obs], np.float64)
    util = np.array([o.vcpu_util for o in obs], np.float64)
    cur = _clamp_batch(alloc - 1, n_classes)
    used = _clamp_batch(np.ceil(used_f).astype(np.int64) - 1, n_classes)
    met = exec_s <= slo_s
    down = _trunc_div(slo_s - exec_s, ABS_Y_SECONDS)
    up = 1 + _trunc_div(exec_s - slo_s, ABS_X_SECONDS)
    target = np.where(
        met,
        np.minimum(cur, used) - down,
        np.where(util < HIGH_UTIL_THRESHOLD, used, used + up),
    )
    return _linear_costs_batch(n_classes, _clamp_batch(target, n_classes))


def proportional_vcpu_costs_batch(observations, n_classes: int) -> np.ndarray:
    obs = list(observations)
    exec_s = np.array([o.exec_time_s for o in obs], np.float64)
    slo_s = np.array([o.slo_s for o in obs], np.float64)
    alloc = np.array([o.alloc_vcpus for o in obs], np.int64)
    used_f = np.array([o.max_vcpus_used for o in obs], np.float64)
    util = np.array([o.vcpu_util for o in obs], np.float64)
    cur = _clamp_batch(alloc - 1, n_classes)
    used = _clamp_batch(np.ceil(used_f).astype(np.int64) - 1, n_classes)
    met = exec_s <= slo_s
    scale = exec_s / np.maximum(slo_s, 1e-9)
    met_target = np.ceil((np.minimum(cur, used) + 1) * scale).astype(np.int64) - 1
    viol_target = np.maximum(
        np.ceil((used + 1) * scale).astype(np.int64) - 1, used + 1
    )
    target = np.where(
        met,
        met_target,
        np.where(util < HIGH_UTIL_THRESHOLD, used, viol_target),
    )
    return _linear_costs_batch(n_classes, _clamp_batch(target, n_classes))


def memory_costs_batch(observations, n_classes: int,
                       class_mb: int = MEM_CLASS_MB) -> np.ndarray:
    obs = list(observations)
    alloc = np.array([o.alloc_mem_mb for o in obs], np.float64)
    used = np.array([o.max_mem_used_mb for o in obs], np.float64)
    oom = np.array([o.oom_killed for o in obs], bool)
    target = np.where(
        oom,
        np.ceil(alloc / class_mb).astype(np.int64),
        np.ceil(used / class_mb).astype(np.int64) - 1,
    )
    return _linear_costs_batch(
        n_classes, _clamp_batch(target, n_classes),
        under_slope=MEM_UNDER_SLOPE,
    )


# per-observation → batched lookup for configurable cost callables
BATCHED_COST_FNS = {
    absolute_vcpu_costs: absolute_vcpu_costs_batch,
    proportional_vcpu_costs: proportional_vcpu_costs_batch,
    memory_costs: memory_costs_batch,
}
