"""Shabari's Scheduler (paper §5).

Given the Resource Allocator's (vcpus, mem) prediction for an
invocation, decide which container/worker runs it:

  1. a warm idle container of the EXACT predicted size;
  2. else the warm idle container LARGER but closest to the prediction —
     and proactively launch an exact-size container in the background,
     off the critical path, for future invocations;
  3. else cold-start an exact-size container.

Cold placement hashes the function to a "home server" (cache locality,
like OpenWhisk) and walks forward from it while workers lack capacity;
if none fits, the invocation queues for retry. A packing alternative
(Hermod-style: fill one server before the next) is included for the
Figure 7b ablation — it loses at high load because co-locating many
network-hungry invocations saturates the server.

Load accounting uses BOTH vCPU and memory per worker (OpenWhisk's
memory-only policy is what oversubscribes vCPUs, §5 reason 3), with the
``userCPU`` oversubscription limit from §6. ``Worker.fits`` counts
committed-but-warming reservations (acquire-on-placement,
``repro.core.cluster``), so the cold-placement walk skips workers whose
capacity is already promised to in-flight cold starts instead of
stacking onto them; warming containers are ``busy`` and therefore never
candidates for the warm-routing cases (1)/(2).
"""

from __future__ import annotations

import dataclasses
import hashlib
from typing import List, Optional, Tuple

from repro.core.allocator import Allocation
from repro.core.cluster import Cluster, Container, Worker


@dataclasses.dataclass(slots=True)
class Decision:
    container: Optional[Container]
    cold_start: bool
    # exact-size container to launch in the background (case 2)
    background_launch: Optional[Tuple[Worker, int, int]]
    queued: bool = False  # no capacity anywhere
    # estimate-routing only (repro.core.router): a still-warming
    # uncommitted container the invocation binds to — it starts the
    # moment ``pending.warm_at`` arrives, paying only the residual
    # warm-up instead of a full cold start. The scheduler itself never
    # sets this; the router does, after the warming-soon candidate won
    # the completion-time estimate.
    pending: Optional[Container] = None


class ShabariScheduler:
    def __init__(
        self,
        cluster: Cluster,
        *,
        placement: str = "hashing",  # hashing | packing (Fig. 7b)
        keep_alive_s: float = 600.0,  # OpenWhisk default keep-alive
        route_larger: bool = True,  # Shabari case (2); off = OpenWhisk mode
        background_launch: bool = True,  # Shabari's proactive exact-size spawn
        image_resolver=None,  # function -> ImageSpec; enables the
        # cache-affinity cold rank (None = plain walk, the default)
    ):
        assert placement in ("hashing", "packing")
        self.cluster = cluster
        self.placement = placement
        self.keep_alive_s = keep_alive_s
        self.route_larger = route_larger
        self.background_launch = background_launch
        self.image_resolver = image_resolver
        # md5 home hashing is deterministic per function name; memoize
        # it (and the rotated walk order per home slot — the worker list
        # is fixed for the cluster's lifetime) so the per-placement cost
        # is two dict hits instead of a digest + list build
        self._home_cache: dict = {}
        self._order_cache: dict = {}

    # ------------------------------------------------------------ utils
    def _home_worker(self, function: str) -> int:
        h = self._home_cache.get(function)
        if h is None:
            h = int(hashlib.md5(function.encode()).hexdigest(), 16) % len(
                self.cluster.workers)
            self._home_cache[function] = h
        return h

    def _workers_from_home(self, function: str) -> List[Worker]:
        start = self._home_worker(function)
        order = self._order_cache.get(start)
        if order is None:
            ws = self.cluster.workers
            order = [ws[(start + i) % len(ws)] for i in range(len(ws))]
            self._order_cache[start] = order
        return order

    def _pick_cold_worker(self, function: str, vcpus: int, mem_mb: int) -> Optional[Worker]:
        if self.placement == "hashing":
            order = self._workers_from_home(function)
        else:  # packing: most-loaded first (fill before spilling)
            order = sorted(
                self.cluster.workers, key=lambda w: -(w.used_vcpus + 1e-9)
            )
        # type-aware placement: the first fitting RELIABLE worker in
        # walk order wins; preemptible (spot-tier) workers serve only
        # as a fallback when no reliable worker fits — a cold start
        # seeds the function's warm pool for its whole keep-alive, and
        # pools on reclaimable machines are the ones that vanish.
        # Identical to the plain walk on all-reliable fleets.
        resolver = self.image_resolver
        if resolver is not None:
            return self._pick_cold_affinity(resolver(function), vcpus,
                                            mem_mb, order)
        fallback: Optional[Worker] = None
        for w in order:
            if not w.fits(vcpus, mem_mb):
                continue
            if not w.machine.preemptible:
                return w
            if fallback is None:
                fallback = w
        return fallback

    # a cold placement seeds the function's warm pool on that node for
    # its whole keep-alive; above this post-placement utilization the
    # node is too contended for that pool to be USABLE (warm routing
    # re-checks fits() at request time), so locality there is worthless
    CROWD_FRAC = 0.75

    def _pick_cold_affinity(self, image, vcpus: int, mem_mb: int,
                            order: List[Worker]) -> Optional[Worker]:
        """Cache-affinity cold rank: among fitting workers, minimize the
        residual registry pull (seconds of missing layers), breaking
        ties by walk order — so a free registry (zero pull everywhere)
        degenerates to the plain walk exactly. A worker already past
        CROWD_FRAC utilization is priced as if cache-cold (residual +
        full pull): a warm pool stranded on a saturated node fails the
        fits() check at request time, forfeiting the locality benefit,
        so crowded nodes only win when nothing else is cheaper. Reliable
        workers still dominate the preemptible fallback tier."""
        frac = self.CROWD_FRAC
        best: Optional[Worker] = None
        best_key = None
        fallback: Optional[Worker] = None
        fb_key = None
        for i, w in enumerate(order):
            if not w.fits(vcpus, mem_mb):
                continue
            ic = w.image_cache
            pull = ic.residual_pull_s(image)
            if (w.used_vcpus + vcpus > frac * w.vcpu_limit
                    or w.used_mem_mb + mem_mb > frac * w.total_mem_mb):
                pull += ic.full_pull_s(image)
            key = (pull, i)
            if not w.machine.preemptible:
                if best_key is None or key < best_key:
                    best, best_key = w, key
            elif best is None and (fb_key is None or key < fb_key):
                fallback, fb_key = w, key
        return best if best is not None else fallback

    def cold_candidate(self, function: str, vcpus: int,
                       mem_mb: int) -> Optional[Worker]:
        """Side-effect-free read: the worker a cold start for
        ``function`` at (vcpus, mem_mb) WOULD land on right now, or None
        when no worker fits. The router's estimate mode scores this
        worker's contention aggregates; ``schedule`` makes the same walk
        on the same state, so the answer matches the eventual binding."""
        return self._pick_cold_worker(function, vcpus, mem_mb)

    def warm_candidate(self, function: str, vcpus: int, mem_mb: int,
                       now: float) -> Optional[Container]:
        """Side-effect-free read: the warm container ``schedule`` would
        route this (function, size) to — an exact-size container (LRU
        first, case 1), else the smallest strictly-larger one (case 2,
        only when ``route_larger``), else None. ``schedule`` itself
        binds through this method, so the router's estimate mode scores
        the contention of the worker that will actually serve the
        invocation, not merely *a* warm worker."""
        if self.cluster.legacy_scans:
            # pre-index selection, kept for A/B: materialize the
            # worker-major warm list and stable-sort it
            warm = self.cluster.idle_warm(function, now)
            exact = [c for c in warm if c.vcpus == vcpus and c.mem_mb == mem_mb
                     and c.worker.fits(vcpus, mem_mb)]
            if exact:
                exact.sort(key=lambda c: c.last_used)
                return exact[0]
            if not self.route_larger:
                return None
            larger = [
                c for c in warm
                if c.vcpus >= vcpus and c.mem_mb >= mem_mb
                and c.worker.fits(c.vcpus, c.mem_mb)
            ]
            if not larger:
                return None
            larger.sort(key=lambda c: (c.vcpus - vcpus, c.mem_mb - mem_mb))
            return larger[0]
        # Indexed path: one pass over the cluster's IDLE containers of
        # this function (mark_busy/mark_idle keep that index exact), so
        # busy containers never even surface. Selection parity with the
        # legacy stable sorts: the worker-major warm list is ordered by
        # (wid, cid) — worker list order, then per-worker insertion
        # order, and cids increase with creation time — so "stable sort
        # by k, take first" is exactly "min by (k, wid, cid)". The
        # legacy larger-branch also admits exact-size containers, but
        # an exact-size candidate either passes the identical
        # fits(vcpus, mem_mb) test (then the exact branch wins with its
        # (0, 0) size-delta key anyway) or fails it in both branches —
        # so bucketing exact and strictly-larger separately is safe.
        idle = self.cluster.idle_by_function.get(function)
        if not idle:
            return None
        soa = self.cluster.arrays
        used_v = soa.used_vcpus
        used_m = soa.used_mem_mb
        best_exact = None
        exact_key = None
        best_larger = None
        larger_key = None
        want_larger = self.route_larger
        for c in idle.values():
            if exact_key is not None and c.last_used > exact_key[0]:
                # the index is insertion-ordered and every insertion
                # happens at last_used == sim-now, so last_used is
                # non-decreasing along this iteration: once an exact
                # fit is in hand, only same-last_used ties can still
                # beat it on the (last_used, wid, cid) key
                break
            if c.busy or c.warm_at > now:
                continue
            cv, cm = c.vcpus, c.mem_mb
            if cv < vcpus or cm < mem_mb:
                continue
            w = c.worker
            i = w.sidx
            if cv == vcpus and cm == mem_mb:
                if (used_v[i] + vcpus <= w.vcpu_limit
                        and used_m[i] + mem_mb <= w.total_mem_mb):
                    key = (c.last_used, w.wid, c.cid)
                    if exact_key is None or key < exact_key:
                        best_exact, exact_key = c, key
            elif want_larger and best_exact is None:
                if (used_v[i] + cv <= w.vcpu_limit
                        and used_m[i] + cm <= w.total_mem_mb):
                    key = (cv - vcpus, cm - mem_mb, w.wid, c.cid)
                    if larger_key is None or key < larger_key:
                        best_larger, larger_key = c, key
        if best_exact is not None:
            return best_exact
        return best_larger

    # -------------------------------------------------------- schedule
    def schedule(self, function: str, alloc: Allocation, now: float) -> Decision:
        """Place one invocation. Does not mutate load — the runtime calls
        ``start``/``finish`` as the invocation actually runs."""
        vcpus, mem = alloc.vcpus, alloc.mem_mb

        # (1)/(2) warm routing: exact-size container, else smallest
        # strictly-larger (selection shared with the router's estimate
        # scoring via warm_candidate)
        chosen = self.warm_candidate(function, vcpus, mem, now)
        if chosen is not None:
            if chosen.vcpus == vcpus and chosen.mem_mb == mem:
                return Decision(chosen, cold_start=False,
                                background_launch=None)
            # case 2: proactively launch the exact size in the background
            bg = None
            if self.background_launch:
                w = self._pick_cold_worker(function, vcpus, mem)
                if w is not None:
                    # idle containers carry no load; free to launch now
                    bg = (w, vcpus, mem)
            return Decision(chosen, cold_start=False, background_launch=bg)

        # (3) cold start at the exact size; _pick_cold_worker scanned
        # every worker, so None means no capacity anywhere — queue
        w = self._pick_cold_worker(function, vcpus, mem)
        if w is None:
            return Decision(None, cold_start=True, background_launch=None,
                            queued=True)
        return Decision(None, cold_start=True, background_launch=(w, vcpus, mem))

    # ----------------------------------------------------- lifecycle
    def reap_idle(self, now: float) -> int:
        """Apply the keep-alive policy; returns number reaped."""
        reaped = 0
        for w in self.cluster.workers:
            dead = [
                c for c in w.containers.values()
                if not c.busy and now - c.last_used > self.keep_alive_s
            ]
            for c in dead:
                self.cluster.remove_container(c)
                reaped += 1
        return reaped
