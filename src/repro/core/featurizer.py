"""Input Featurizer (paper §4.3.1, Appendix A Table 2).

Extracts descriptive, performance-relevant features per input TYPE (not
content understanding — "our models learn the descriptive features of
inputs that may affect performance"). Feature lists mirror Table 2:

  image : width, height, channels, x-dpi, y-dpi, file size
  matrix: rows, cols, density
  video : width, height, duration, bitrate, avg frame rate, encoding
  csv   : rows, cols, file size
  json  : outer length, file size
  audio : channels, sample rate, duration, bit rate, is_flac
  request (TPU adaptation): prompt tokens, batch, max new tokens,
          image tiles, audio seconds — the serving-side analogue.

Inputs arrive as metadata dicts (the datastore path of the paper — the
featurization happened in the background when the object was persisted;
``Featurizer.extract`` is the lookup). Unknown types fall back to the
invocation payload, exactly as in §6.

Features are standardized online (running mean/var per function) before
reaching the linear CSOAA agents — raw file sizes span 6 orders of
magnitude and would swamp a linear model otherwise.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Sequence

import numpy as np

_ENCODINGS = ["mp4", "mpeg4", "h264", "h265", "vp9", "av1", "webm"]

FEATURE_SCHEMAS: Dict[str, List[str]] = {
    "image": ["width", "height", "channels", "dpi_x", "dpi_y", "file_size"],
    "matrix": ["rows", "cols", "density"],
    "video": ["width", "height", "duration", "bitrate", "fps", "encoding"],
    "csv": ["rows", "cols", "file_size"],
    "json": ["outer_len", "file_size"],
    "audio": ["channels", "sample_rate", "duration", "bitrate", "is_flac"],
    "string": ["length"],
    "batch_of_strings": ["count", "total_length"],
    "url": ["length"],
    "file": ["file_size"],
    "training_set": ["file_size", "rows", "cols"],
    "request": [
        "prompt_tokens",
        "batch",
        "max_new_tokens",
        "image_tiles",
        "audio_seconds",
    ],
    "payload": ["payload"],
}


def _encode_enum(value, table: Sequence[str]) -> float:
    try:
        return float(table.index(str(value).lower()) + 1)
    except ValueError:
        return 0.0


@dataclasses.dataclass
class RunningStats:
    """Online per-dimension standardization (Welford)."""

    n: int
    mean: np.ndarray
    m2: np.ndarray

    @classmethod
    def create(cls, dim: int) -> "RunningStats":
        return cls(0, np.zeros(dim), np.zeros(dim))

    def update(self, x: np.ndarray) -> None:
        self.n += 1
        delta = x - self.mean
        self.mean += delta / self.n
        self.m2 += delta * (x - self.mean)

    def normalize(self, x: np.ndarray) -> np.ndarray:
        if self.n < 2:
            return np.zeros_like(x)
        std = np.sqrt(self.m2 / (self.n - 1)) + 1e-6
        return (x - self.mean) / std


class Featurizer:
    """Per-input-type feature extraction + online standardization.

    One instance serves the whole platform; standardization state is kept
    per function (the agents are per function, §4.2)."""

    def __init__(self):
        self._stats: Dict[str, RunningStats] = {}
        # Background-extracted object features (the metadata-store path).
        self._object_cache: Dict[str, np.ndarray] = {}

    # ------------------------------------------------------------ raw
    def raw_features(self, input_type: str, meta: Dict) -> np.ndarray:
        schema = FEATURE_SCHEMAS.get(input_type)
        if schema is None:
            schema = FEATURE_SCHEMAS["payload"]
            meta = {"payload": float(meta.get("payload", 0.0))}
        vals = []
        for name in schema:
            v = meta.get(name, 0.0)
            if name == "encoding":
                v = _encode_enum(v, _ENCODINGS)
            elif name == "is_flac":
                v = 1.0 if v else 0.0
            vals.append(float(v))
        # log1p compresses the dynamic range of size-like features.
        out = np.asarray(vals, dtype=np.float64)
        sizelike = [i for i, nm in enumerate(schema)
                    if nm in ("file_size", "rows", "cols", "length",
                              "total_length", "bitrate", "prompt_tokens")]
        for i in sizelike:
            out[i] = math.log1p(max(out[i], 0.0))
        return out

    # ------------------------------------------------- background path
    def persist_object(self, object_id: str, input_type: str, meta: Dict) -> None:
        """Called when a data object lands in the datastore — feature
        extraction off the critical path (§4.3.1)."""
        self._object_cache[object_id] = self.raw_features(input_type, meta)

    def has_object(self, object_id: str) -> bool:
        return object_id in self._object_cache

    # ------------------------------------------------------- invocation
    def extract(self, function: str, input_type: str, meta: Dict,
                object_id: str = "") -> np.ndarray:
        """Features for one invocation, standardized per function.

        Cached object features are used when available (no critical-path
        cost); otherwise extraction happens inline (storage-trigger path).
        """
        if object_id and object_id in self._object_cache:
            raw = self._object_cache[object_id]
        else:
            raw = self.raw_features(input_type, meta)
        key = function
        stats = self._stats.get(key)
        if stats is None or stats.mean.shape[0] != raw.shape[0]:
            stats = RunningStats.create(raw.shape[0])
            self._stats[key] = stats
        stats.update(raw)
        return stats.normalize(raw).astype(np.float32)

    def feature_dim(self, input_type: str) -> int:
        return len(FEATURE_SCHEMAS.get(input_type, FEATURE_SCHEMAS["payload"]))
