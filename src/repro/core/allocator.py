"""Shabari's Resource Allocator (paper §4).

``OnlineCSC`` is the cost-sensitive one-against-all multi-class
classifier (the Vowpal Wabbit ``csoaa`` algorithm the paper uses): per
class a linear regressor predicts the cost of assigning that class; the
arg-min class wins. Updates are importance-free online least-squares
steps with AdaGrad per-coordinate rates.

``ResourceAllocator`` owns two agents per function — one for vCPUs, one
for memory — (independent per-resource-type decisions, Takeaway #3) plus
the paper's safeguards:

* confidence thresholds — predictions are used only after the agent has
  observed ``conf`` invocations (memory threshold = 2x vCPU threshold);
  until then a large default allocation lets the agent learn safely;
* memory floor — the predicted allocation is never below the input
  object size; otherwise the default maximum is used (§4.3.2).

Two engines implement the same agents (``engine=`` selects; metrics are
bit-identical, asserted by the golden harness and the sim_bench A/B):

* ``"arena"`` (default) — all functions' regressors live in stacked
  ``(capacity, n_classes, dim+1)`` tensors
  (:class:`repro.core.agent_arena.ArenaEngine`): feedbacks are deferred
  into microbatches flushed before the next prediction, and small
  batches run on a calibrated dispatch-free NumPy backend. Fig. 14
  overheads on the dev container (benchmarks/fig14_overheads.py):
  predict ~180 µs → ~105 µs (both agents, argmin included), update
  ~230 µs eager jit → ~3 µs enqueue + ~60 µs amortized batched flush
  per completion; end to end the engine A/B is worth ~3.8x events/sec
  on a Shabari heavy-tail simulation (sim_bench). The paper's
  Vowpal-Wabbit-over-gRPC numbers are 2-4 ms predictions / 4-5 ms
  updates — an order of magnitude above either engine, so the
  reproduction's conclusions are insensitive to the engine choice;
  simulation wall-clock is not.
* ``"legacy"`` — one jit'd dispatch per tiny per-function ``OnlineCSC``
  object per event (the pre-arena path, kept for A/B benchmarking and
  pinned by the ``tests/goldens/legacy-engine/`` snapshot).

The predicted (vcpus, mem) is also the RESERVATION footprint: under
acquire-on-placement (``repro.core.cluster``) a cold-started invocation
holds exactly this allocation from placement through warm-up, so
over-prediction now costs admission headroom (``Router._load``) for the
whole cold-start window, not just execution-time waste — one more
reason the cost functions penalize over-allocation.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.agent_arena import ArenaEngine, _csc_predict, _csc_update
from repro.core.cost_functions import (
    MEM_CLASS_MB,
    Observation,
    absolute_vcpu_costs,
    memory_costs,
)

DEFAULT_VCPU_CLASSES = 32
DEFAULT_MEM_CLASSES = 40  # 40 x 128 MB = 5 GB
DEFAULT_VCPUS = 10  # learning-phase default (§6)
DEFAULT_MEM_CLASS = 32  # 32 x 128 MB = 4 GB default max (§7.2)
VCPU_CONFIDENCE = 10  # 8-12 sufficed for every function (§7.5)
MEM_CONFIDENCE = 2 * VCPU_CONFIDENCE


@dataclasses.dataclass(frozen=True)
class Allocation:
    vcpus: int
    mem_mb: int
    # Per-resource prediction provenance: each flag is True only when the
    # corresponding agent is past its confidence threshold AND its
    # prediction survived the safeguards (a memory prediction below the
    # input-object floor falls back to the default, so it is NOT a
    # prediction the system is actually serving).
    vcpu_predicted: bool = False
    mem_predicted: bool = False

    @property
    def predicted(self) -> bool:
        """True only when BOTH resources come from past-confidence agents
        (the vCPU flag alone used to masquerade as this aggregate while
        memory still served the 4 GB default)."""
        return self.vcpu_predicted and self.mem_predicted


class OnlineCSC:
    """Cost-sensitive one-against-all online classifier (legacy engine:
    one jit'd dispatch per call)."""

    def __init__(self, n_classes: int, dim: int, lr: float = 0.5, seed: int = 0):
        self.n_classes = n_classes
        self.dim = dim
        self.lr = jnp.float32(lr)
        self.w = jnp.zeros((n_classes, dim + 1), jnp.float32)
        self.g2 = jnp.zeros((n_classes, dim + 1), jnp.float32)
        self.updates = 0

    def predict_lazy(self, x: np.ndarray) -> jax.Array:
        """Arg-min class as a 0-d device array WITHOUT a host sync: the
        dispatch is issued here, the blocking transfer happens only when
        the caller converts the index (``int(...)``) at the point of
        consumption — so two agents' predictions overlap instead of
        serializing on the first sync."""
        costs = _csc_predict(self.w, jnp.asarray(x, jnp.float32), self.n_classes)
        return jnp.argmin(costs)

    def predict(self, x: np.ndarray) -> int:
        return int(self.predict_lazy(x))

    def predicted_costs(self, x: np.ndarray) -> np.ndarray:
        return np.asarray(
            _csc_predict(self.w, jnp.asarray(x, jnp.float32), self.n_classes)
        )

    def update(self, x: np.ndarray, costs: np.ndarray) -> None:
        self.w, self.g2 = _csc_update(
            self.w,
            self.g2,
            jnp.asarray(x, jnp.float32),
            jnp.asarray(costs, jnp.float32),
            self.lr,
        )
        self.updates += 1


@dataclasses.dataclass
class _FunctionAgents:
    vcpu: OnlineCSC
    mem: OnlineCSC


class ResourceAllocator:
    """Per-function online agents + defaults + safeguards (paper §4)."""

    def __init__(
        self,
        *,
        n_vcpu_classes: int = DEFAULT_VCPU_CLASSES,
        n_mem_classes: int = DEFAULT_MEM_CLASSES,
        vcpu_confidence: int = VCPU_CONFIDENCE,
        mem_confidence: int = MEM_CONFIDENCE,
        default_vcpus: int = DEFAULT_VCPUS,
        default_mem_class: int = DEFAULT_MEM_CLASS,
        vcpu_cost_fn: Callable = absolute_vcpu_costs,
        mem_class_mb: int = MEM_CLASS_MB,
        engine: str = "arena",
    ):
        if engine not in ("arena", "legacy"):
            raise ValueError(f"unknown allocator engine {engine!r}")
        self.n_vcpu_classes = n_vcpu_classes
        self.n_mem_classes = n_mem_classes
        self.vcpu_confidence = vcpu_confidence
        self.mem_confidence = mem_confidence
        self.default_vcpus = default_vcpus
        self.default_mem_class = default_mem_class
        self.vcpu_cost_fn = vcpu_cost_fn
        self.mem_class_mb = mem_class_mb
        self.engine = engine
        self._agents: Dict[str, _FunctionAgents] = {}
        self._arena: Optional[ArenaEngine] = None
        if engine == "arena":
            self._arena = ArenaEngine(
                n_vcpu_classes=n_vcpu_classes,
                n_mem_classes=n_mem_classes,
                vcpu_cost_fn=vcpu_cost_fn,
                mem_class_mb=mem_class_mb,
            )

    # ------------------------------------------------------------------
    def _get(self, function: str, dim: int) -> _FunctionAgents:
        ag = self._agents.get(function)
        if ag is None:
            ag = _FunctionAgents(
                vcpu=OnlineCSC(self.n_vcpu_classes, dim),
                mem=OnlineCSC(self.n_mem_classes, dim),
            )
            self._agents[function] = ag
        return ag

    def _finish_allocation(
        self,
        vcpu_class: Optional[int],
        mem_class: Optional[int],
        input_size_mb: float,
    ) -> Allocation:
        """Predicted classes (or None while below confidence) → served
        allocation, applying the defaults and the §4.3.2 memory floor."""
        if vcpu_class is not None:
            vcpus, vcpu_predicted = vcpu_class + 1, True
        else:
            vcpus, vcpu_predicted = self.default_vcpus, False
        if mem_class is not None:
            mem_mb, mem_predicted = (mem_class + 1) * self.mem_class_mb, True
            # Safeguard: allocation must exceed the input object size.
            # Falling back to the default means the served memory is NOT
            # a prediction, so the flag drops with it.
            if mem_mb < input_size_mb:
                mem_mb = self.default_mem_class * self.mem_class_mb
                mem_predicted = False
        else:
            mem_mb = self.default_mem_class * self.mem_class_mb
            mem_predicted = False
        return Allocation(vcpus=vcpus, mem_mb=mem_mb,
                          vcpu_predicted=vcpu_predicted,
                          mem_predicted=mem_predicted)

    def allocate(
        self, function: str, features: np.ndarray, input_size_mb: float = 0.0
    ) -> Allocation:
        """Predict (vcpus, memory) for one invocation (paper Fig. 5 step 3)."""
        if self._arena is not None:
            uv, um = self._arena.updates(function)
            v_cls, m_cls = self._arena.predict(
                function, features,
                uv >= self.vcpu_confidence, um >= self.mem_confidence)
            return self._finish_allocation(v_cls, m_cls, input_size_mb)
        ag = self._get(function, len(features))
        want_v = ag.vcpu.updates >= self.vcpu_confidence
        want_m = ag.mem.updates >= self.mem_confidence
        # both dispatches issue before either index is consumed — the
        # host sync happens inside _finish_allocation's int() conversions
        v_lazy = ag.vcpu.predict_lazy(features) if want_v else None
        m_lazy = ag.mem.predict_lazy(features) if want_m else None
        return self._finish_allocation(
            int(v_lazy) if v_lazy is not None else None,
            int(m_lazy) if m_lazy is not None else None,
            input_size_mb,
        )

    def allocate_batch(
        self, items: Sequence[Tuple[str, np.ndarray, float]]
    ) -> List[Allocation]:
        """Allocations for a microbatch of (function, features,
        input_size_mb) — same-timestamp arrivals fused into one arena
        dispatch. Pending feedback for every function flushes first, so
        each served allocation is bit-identical to the sequential path."""
        if self._arena is None:
            return [self.allocate(*it) for it in items]
        wants = []
        for fn, x, size in items:
            uv, um = self._arena.updates(fn)
            wants.append((fn, x, uv >= self.vcpu_confidence,
                          um >= self.mem_confidence))
        classes = self._arena.predict_batch(wants)
        return [
            self._finish_allocation(v_cls, m_cls, items[i][2])
            for i, (v_cls, m_cls) in enumerate(classes)
        ]

    def feedback(self, function: str, features: np.ndarray, obs: Observation) -> None:
        """Close the loop with the daemon's observation (Fig. 5 step 5).

        Arena engine: the update is ENQUEUED, not applied — it flushes
        (with every other pending update, in one fused dispatch) before
        the next prediction that could observe it."""
        if self._arena is not None:
            self._arena.enqueue_update(function, features, obs)
            return
        ag = self._get(function, len(features))
        ag.vcpu.update(features, self.vcpu_cost_fn(obs, self.n_vcpu_classes))
        ag.mem.update(
            features, memory_costs(obs, self.n_mem_classes, self.mem_class_mb)
        )

    def flush(self) -> None:
        """Apply any deferred feedback now (arena engine; legacy updates
        are always applied eagerly). Needed only when reading agent
        state out-of-band — the predict path flushes itself."""
        if self._arena is not None:
            self._arena.flush()

    def release(self, function: str) -> None:
        """Drop a function's agents (arena: frees the rows for reuse)."""
        if self._arena is not None:
            self._arena.release(function)
        else:
            self._agents.pop(function, None)

    def agent_updates(self, function: str) -> Tuple[int, int]:
        if self._arena is not None:
            return self._arena.updates(function)
        ag = self._agents.get(function)
        return (ag.vcpu.updates, ag.mem.updates) if ag else (0, 0)
