"""Shabari's Resource Allocator (paper §4).

``OnlineCSC`` is the cost-sensitive one-against-all multi-class
classifier (the Vowpal Wabbit ``csoaa`` algorithm the paper uses): per
class a linear regressor predicts the cost of assigning that class; the
arg-min class wins. Updates are importance-free online least-squares
steps with AdaGrad per-coordinate rates — small, fast, jit-compiled
(the paper measures 2-4 ms predictions / 4-5 ms updates; ours are µs
once traced, see benchmarks/overheads.py).

``ResourceAllocator`` owns two agents per function — one for vCPUs, one
for memory — (independent per-resource-type decisions, Takeaway #3) plus
the paper's safeguards:

* confidence thresholds — predictions are used only after the agent has
  observed ``conf`` invocations (memory threshold = 2x vCPU threshold);
  until then a large default allocation lets the agent learn safely;
* memory floor — the predicted allocation is never below the input
  object size; otherwise the default maximum is used (§4.3.2).

The predicted (vcpus, mem) is also the RESERVATION footprint: under
acquire-on-placement (``repro.core.cluster``) a cold-started invocation
holds exactly this allocation from placement through warm-up, so
over-prediction now costs admission headroom (``Router._load``) for the
whole cold-start window, not just execution-time waste — one more
reason the cost functions penalize over-allocation.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.cost_functions import (
    MEM_CLASS_MB,
    Observation,
    absolute_vcpu_costs,
    memory_costs,
)

DEFAULT_VCPU_CLASSES = 32
DEFAULT_MEM_CLASSES = 40  # 40 x 128 MB = 5 GB
DEFAULT_VCPUS = 10  # learning-phase default (§6)
DEFAULT_MEM_CLASS = 32  # 32 x 128 MB = 4 GB default max (§7.2)
VCPU_CONFIDENCE = 10  # 8-12 sufficed for every function (§7.5)
MEM_CONFIDENCE = 2 * VCPU_CONFIDENCE


@dataclasses.dataclass(frozen=True)
class Allocation:
    vcpus: int
    mem_mb: int
    # Per-resource prediction provenance: each flag is True only when the
    # corresponding agent is past its confidence threshold AND its
    # prediction survived the safeguards (a memory prediction below the
    # input-object floor falls back to the default, so it is NOT a
    # prediction the system is actually serving).
    vcpu_predicted: bool = False
    mem_predicted: bool = False

    @property
    def predicted(self) -> bool:
        """True only when BOTH resources come from past-confidence agents
        (the vCPU flag alone used to masquerade as this aggregate while
        memory still served the 4 GB default)."""
        return self.vcpu_predicted and self.mem_predicted


@functools.partial(jax.jit, static_argnums=(2,))
def _csc_predict(w: jax.Array, x: jax.Array, n_classes: int) -> jax.Array:
    xb = jnp.concatenate([x, jnp.ones((1,), x.dtype)])
    return w @ xb  # (n_classes,) predicted costs


@jax.jit
def _csc_update(
    w: jax.Array, g2: jax.Array, x: jax.Array, costs: jax.Array, lr: jax.Array
):
    """One-against-all least-squares step on every class's regressor."""
    xb = jnp.concatenate([x, jnp.ones((1,), x.dtype)])
    pred = w @ xb
    err = pred - costs  # (n_classes,)
    grad = err[:, None] * xb[None, :]  # (n_classes, dim+1)
    g2 = g2 + jnp.square(grad)
    step = lr * grad / (jnp.sqrt(g2) + 1e-6)
    return w - step, g2


class OnlineCSC:
    """Cost-sensitive one-against-all online classifier."""

    def __init__(self, n_classes: int, dim: int, lr: float = 0.5, seed: int = 0):
        self.n_classes = n_classes
        self.dim = dim
        self.lr = jnp.float32(lr)
        self.w = jnp.zeros((n_classes, dim + 1), jnp.float32)
        self.g2 = jnp.zeros((n_classes, dim + 1), jnp.float32)
        self.updates = 0

    def predict(self, x: np.ndarray) -> int:
        costs = _csc_predict(self.w, jnp.asarray(x, jnp.float32), self.n_classes)
        return int(jnp.argmin(costs))

    def predicted_costs(self, x: np.ndarray) -> np.ndarray:
        return np.asarray(
            _csc_predict(self.w, jnp.asarray(x, jnp.float32), self.n_classes)
        )

    def update(self, x: np.ndarray, costs: np.ndarray) -> None:
        self.w, self.g2 = _csc_update(
            self.w,
            self.g2,
            jnp.asarray(x, jnp.float32),
            jnp.asarray(costs, jnp.float32),
            self.lr,
        )
        self.updates += 1


@dataclasses.dataclass
class _FunctionAgents:
    vcpu: OnlineCSC
    mem: OnlineCSC


class ResourceAllocator:
    """Per-function online agents + defaults + safeguards (paper §4)."""

    def __init__(
        self,
        *,
        n_vcpu_classes: int = DEFAULT_VCPU_CLASSES,
        n_mem_classes: int = DEFAULT_MEM_CLASSES,
        vcpu_confidence: int = VCPU_CONFIDENCE,
        mem_confidence: int = MEM_CONFIDENCE,
        default_vcpus: int = DEFAULT_VCPUS,
        default_mem_class: int = DEFAULT_MEM_CLASS,
        vcpu_cost_fn: Callable = absolute_vcpu_costs,
        mem_class_mb: int = MEM_CLASS_MB,
    ):
        self.n_vcpu_classes = n_vcpu_classes
        self.n_mem_classes = n_mem_classes
        self.vcpu_confidence = vcpu_confidence
        self.mem_confidence = mem_confidence
        self.default_vcpus = default_vcpus
        self.default_mem_class = default_mem_class
        self.vcpu_cost_fn = vcpu_cost_fn
        self.mem_class_mb = mem_class_mb
        self._agents: Dict[str, _FunctionAgents] = {}

    # ------------------------------------------------------------------
    def _get(self, function: str, dim: int) -> _FunctionAgents:
        ag = self._agents.get(function)
        if ag is None:
            ag = _FunctionAgents(
                vcpu=OnlineCSC(self.n_vcpu_classes, dim),
                mem=OnlineCSC(self.n_mem_classes, dim),
            )
            self._agents[function] = ag
        return ag

    def allocate(
        self, function: str, features: np.ndarray, input_size_mb: float = 0.0
    ) -> Allocation:
        """Predict (vcpus, memory) for one invocation (paper Fig. 5 step 3)."""
        ag = self._get(function, len(features))
        vcpu_predicted = ag.vcpu.updates >= self.vcpu_confidence
        if vcpu_predicted:
            vcpus = ag.vcpu.predict(features) + 1
        else:
            vcpus = self.default_vcpus
        mem_predicted = ag.mem.updates >= self.mem_confidence
        if mem_predicted:
            mem_class = ag.mem.predict(features) + 1
            mem_mb = mem_class * self.mem_class_mb
            # Safeguard: allocation must exceed the input object size.
            # Falling back to the default means the served memory is NOT
            # a prediction, so the flag drops with it.
            if mem_mb < input_size_mb:
                mem_mb = self.default_mem_class * self.mem_class_mb
                mem_predicted = False
        else:
            mem_mb = self.default_mem_class * self.mem_class_mb
        return Allocation(vcpus=vcpus, mem_mb=mem_mb,
                          vcpu_predicted=vcpu_predicted,
                          mem_predicted=mem_predicted)

    def feedback(self, function: str, features: np.ndarray, obs: Observation) -> None:
        """Close the loop with the daemon's observation (Fig. 5 step 5)."""
        ag = self._get(function, len(features))
        ag.vcpu.update(features, self.vcpu_cost_fn(obs, self.n_vcpu_classes))
        ag.mem.update(
            features, memory_costs(obs, self.n_mem_classes, self.mem_class_mb)
        )

    def agent_updates(self, function: str) -> Tuple[int, int]:
        ag = self._agents.get(function)
        return (ag.vcpu.updates, ag.mem.updates) if ag else (0, 0)
