"""Performance-centric interface: per-invocation SLOs (paper §3, §7.1).

Shabari's interface lets every invocation carry its own execution-time
SLO. The evaluation sets SLO = multiplier x median isolated execution
time at the best vCPU count (1..32) for that (function, input) — a much
tighter bar than Cypress's max+20%. ``SLORegistry`` computes and caches
these from the function profiles, mirroring §7.1's isolated profiling
runs.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Optional, Tuple

import numpy as np

DEFAULT_SLO_MULTIPLIER = 1.4  # the paper's default (Figure 13 sweeps it)


@dataclasses.dataclass(frozen=True)
class InvocationRequest:
    """What a client submits: function, input, SLO (Fig. 5 step 1)."""

    function: str
    input_type: str
    meta: Dict
    slo_s: float
    object_id: str = ""
    input_size_mb: float = 0.0


class SLORegistry:
    """SLO = multiplier x best-allocation median isolated exec time."""

    def __init__(
        self,
        isolated_exec_time: Callable[[str, Dict, int], float],
        *,
        multiplier: float = DEFAULT_SLO_MULTIPLIER,
        max_vcpus: int = 32,
        profile_runs: int = 5,
    ):
        self._exec = isolated_exec_time
        self.multiplier = multiplier
        self.max_vcpus = max_vcpus
        self.profile_runs = profile_runs
        self._cache: Dict[Tuple[str, str], float] = {}

    def slo_for(self, function: str, input_key: str, meta: Dict) -> float:
        key = (function, input_key)
        if key not in self._cache:
            best = np.inf
            for v in range(1, self.max_vcpus + 1):
                times = [
                    self._exec(function, meta, v) for _ in range(self.profile_runs)
                ]
                best = min(best, float(np.median(times)))
            self._cache[key] = self.multiplier * best
        return self._cache[key]
