"""Per-node container-image/layer cache and registry pull model.

Shabari's testbed (and the depsched simulator it cites) treat a cold
start as *pull what's missing*: a container image is an ordered stack of
content-addressed layers, nodes keep a finite local layer store, and the
registry only ships the layers the node doesn't already hold.  This
module provides the vocabulary:

- ``ImageSpec``     — an immutable ordered layer stack (digest, MB).
  Clone aliases (``fn::k``) of the same base function share everything
  but a tiny per-alias config layer, and *all* functions share the
  OS/runtime base layers — exactly how real registries dedupe.
- ``NodeImageCache`` — one per worker: finite store bytes, LRU eviction
  that never evicts pinned or in-use layers, and hit/miss/evict
  counters.  ``pull()`` charges only the missing bytes over the node's
  registry bandwidth (same ``MB * 8 / 1000 / gbps`` wire math as
  ``fleet.Link``).
- ``ImageCacheSpec`` — the ``SimConfig(image_cache=...)`` knob.  The
  ``None`` default keeps the flat-constant cold model and costs nothing.

The simulator overlaps the pull with the flat ``cold_base_s`` unpack
cost: effective cold latency = max(classic cold curve, residual pull).
A fully-resident image therefore reproduces the flat baseline exactly.
"""

from __future__ import annotations

import dataclasses
import hashlib
from typing import Dict, List, Mapping, Optional, Tuple

# Universal layers every image stacks on: a distro base and a language
# runtime.  Shared across *all* functions, so one pull warms the fleet.
OS_BASE_LAYER = ("base/os", 120.0)
RUNTIME_LAYER = ("base/runtime", 240.0)
BASE_LAYERS: Tuple[Tuple[str, float], ...] = (OS_BASE_LAYER, RUNTIME_LAYER)

# MB on the wire -> seconds at 1 Gbps (mirrors fleet.Link.transfer_s).
_S_PER_MB_PER_GBPS = 8.0 / 1000.0


@dataclasses.dataclass(frozen=True)
class ImageSpec:
    """An ordered stack of (digest, size_mb) layers, base-first."""

    name: str
    layers: Tuple[Tuple[str, float], ...]

    @property
    def total_mb(self) -> float:
        return sum(mb for _, mb in self.layers)

    @property
    def digests(self) -> Tuple[str, ...]:
        return tuple(d for d, _ in self.layers)


def _base_function(fn: str) -> str:
    # Local strip of the ``::k`` clone-alias suffix (mirrors
    # repro.serving.profiles.base_function without a core->serving import).
    return fn.split("::", 1)[0]


def _app_layers(base_fn: str) -> Tuple[Tuple[str, float], ...]:
    """Deterministic per-base-function app layers: two dependency layers
    plus a small code layer, sizes hashed from the function name."""
    h = int.from_bytes(
        hashlib.md5(base_fn.encode()).digest()[:8], "big")
    deps0 = 100.0 + (h % 400)            # 100-499 MB
    deps1 = 50.0 + ((h >> 16) % 250)     # 50-299 MB
    code = 5.0 + ((h >> 32) % 45)        # 5-49 MB
    return (
        (f"app/{base_fn}/deps0", deps0),
        (f"app/{base_fn}/deps1", deps1),
        (f"app/{base_fn}/code", code),
    )


# Per-alias config layer: tiny, so siblings of a pulled clone miss
# almost nothing.
ALIAS_LAYER_MB = 2.0


def default_images(functions) -> Dict[str, ImageSpec]:
    """Build the default image catalog for a set of function names.

    Clone aliases (``fn::k``) share every layer of their base function's
    image except a 2 MB per-alias config layer; all images share the
    OS/runtime base layers.
    """
    out: Dict[str, ImageSpec] = {}
    for fn in functions:
        bf = _base_function(fn)
        layers = BASE_LAYERS + _app_layers(bf)
        if fn != bf:
            layers = layers + ((f"alias/{fn}", ALIAS_LAYER_MB),)
        out[fn] = ImageSpec(name=fn, layers=layers)
    return out


@dataclasses.dataclass(frozen=True)
class ImageCacheSpec:
    """``SimConfig(image_cache=...)`` knob.

    - ``images``: explicit function -> ImageSpec assignments as a tuple
      of pairs (hashable).  ``None`` falls back to the fleet's
      ``FleetSpec.images`` assignments, then to ``default_images()``
      over the run's function population.
    - ``affinity``: when True the scheduler ranks cold placements by
      residual pull seconds and the router prices each candidate's
      residual pull; when False the cache still charges pulls but every
      decision stays cache-blind (the A/B arm).
    - ``pin_base``: pin the shared OS/runtime base layers so LRU churn
      never evicts them.
    """

    images: Optional[Tuple[Tuple[str, ImageSpec], ...]] = None
    affinity: bool = True
    pin_base: bool = True


class NodeImageCache:
    """One worker's layer store: finite bytes, LRU eviction (pinned and
    in-use layers exempt), and a registry link for pull pricing."""

    __slots__ = ("store_mb", "registry_gbps", "used_mb", "hits", "misses",
                 "evictions", "_layers", "_pinned", "_inuse_images",
                 "_tick")

    def __init__(self, store_mb: float, registry_gbps: float = 10.0,
                 pinned: Tuple[str, ...] = ()):
        self.store_mb = float(store_mb)
        self.registry_gbps = float(registry_gbps)
        self.used_mb = 0.0
        self.hits = 0
        self.misses = 0
        self.evictions = 0
        # digest -> [size_mb, last_used_tick, in_use_count]
        self._layers: Dict[str, List] = {}
        self._pinned = set(pinned)
        # image name -> [ImageSpec, container_count]
        self._inuse_images: Dict[str, List] = {}
        self._tick = 0

    # ------------------------------------------------------------ probes
    def resident(self, digest: str) -> bool:
        return digest in self._layers

    def missing_mb(self, image: ImageSpec) -> float:
        """Bytes the registry would have to ship for this image now.
        Read-only: safe for scheduler/router candidate probes."""
        layers = self._layers
        return sum(mb for d, mb in image.layers if d not in layers)

    def residual_pull_s(self, image: ImageSpec) -> float:
        """Seconds to pull the missing layers over the registry link."""
        gbps = self.registry_gbps
        if gbps == float("inf"):
            return 0.0
        return self.missing_mb(image) * _S_PER_MB_PER_GBPS / gbps

    def full_pull_s(self, image: ImageSpec) -> float:
        """Seconds a from-scratch pull of the whole image would take —
        the scale of the locality benefit this node could ever offer."""
        gbps = self.registry_gbps
        if gbps == float("inf"):
            return 0.0
        return image.total_mb * _S_PER_MB_PER_GBPS / gbps

    # ----------------------------------------------------------- actions
    def pull(self, image: ImageSpec) -> float:
        """Materialise ``image`` on this node and return the pull time in
        seconds (0.0 on a full cache hit).  Missing layers are fetched,
        LRU-evicting unpinned idle layers to make room; every layer of
        the image is then marked in-use until ``release()``."""
        self._tick += 1
        tick = self._tick
        layers = self._layers
        need: List[Tuple[str, float]] = []
        for d, mb in image.layers:
            ent = layers.get(d)
            if ent is not None:
                self.hits += 1
                ent[1] = tick
            else:
                self.misses += 1
                need.append((d, mb))
        missing_mb = 0.0
        if need:
            missing_mb = sum(mb for _, mb in need)
            # the in-flight image's own layers are off-limits: a hit
            # above isn't refcounted until the loop below, and evicting
            # it here would un-materialise the image mid-pull
            self._evict_for(missing_mb, protect=image.digests)
            for d, mb in need:
                layers[d] = [mb, tick, 0]
                self.used_mb += mb
        # refcount: the new container holds every layer of its image
        for d, _ in image.layers:
            layers[d][2] += 1
        ref = self._inuse_images.get(image.name)
        if ref is None:
            self._inuse_images[image.name] = [image, 1]
        else:
            ref[1] += 1
        gbps = self.registry_gbps
        if missing_mb == 0.0 or gbps == float("inf"):
            return 0.0
        return missing_mb * _S_PER_MB_PER_GBPS / gbps

    def release(self, function: str) -> None:
        """Drop one container's reference to ``function``'s image (called
        when the container is reaped); layers become evictable once no
        container references them."""
        ref = self._inuse_images.get(function)
        if ref is None:
            return
        image, count = ref[0], ref[1]
        layers = self._layers
        for d, _ in image.layers:
            ent = layers.get(d)
            if ent is not None and ent[2] > 0:
                ent[2] -= 1
        if count <= 1:
            del self._inuse_images[function]
        else:
            ref[1] = count - 1

    def pin(self, digests) -> None:
        self._pinned.update(digests)

    def _evict_for(self, incoming_mb: float,
                   protect: Tuple[str, ...] = ()) -> None:
        """LRU-evict idle unpinned layers until ``incoming_mb`` fits.
        If pinned/in-use/protected layers make that impossible the store
        is allowed to overflow — a pull in flight can't be refused."""
        if self.used_mb + incoming_mb <= self.store_mb:
            return
        keep = self._pinned.union(protect) if protect else self._pinned
        victims = sorted(
            ((ent[1], d) for d, ent in self._layers.items()
             if ent[2] == 0 and d not in keep))
        for _, d in victims:
            if self.used_mb + incoming_mb <= self.store_mb:
                break
            self.used_mb -= self._layers.pop(d)[0]
            self.evictions += 1
