"""Shabari core: delayed, input-aware, per-resource-type allocation.

The paper's contribution (§3-§5): an online cost-sensitive multi-class
classification agent per (function, resource type), a slack-driven cost
function, an input featurizer, and a cold-start-aware scheduler.
"""

from repro.core.allocator import Allocation, OnlineCSC, ResourceAllocator
from repro.core.cost_functions import (
    absolute_vcpu_costs,
    memory_costs,
    proportional_vcpu_costs,
)
from repro.core.featurizer import Featurizer
from repro.core.metadata_store import MetadataStore
from repro.core.router import Router
from repro.core.scheduler import ShabariScheduler

__all__ = [
    "OnlineCSC",
    "ResourceAllocator",
    "Allocation",
    "Featurizer",
    "Router",
    "ShabariScheduler",
    "MetadataStore",
    "absolute_vcpu_costs",
    "proportional_vcpu_costs",
    "memory_costs",
]
