"""Distribution layer: sharding rules for params, optimizer state, caches."""
