"""Sharding rules: parameter/optimizer/cache/input PartitionSpecs per arch.

Policy (DESIGN.md §6):

* Tensor parallelism over the ``model`` axis follows Megatron pairing:
  column-parallel in-projections (wq/wk/wv/wg/wu/in_proj), row-parallel
  out-projections (wo/wd/out_proj) so each block needs one reduction.
* FSDP: during training every matrix additionally shards one remaining
  dim over the data axes (("pod","data") on the multi-pod mesh) so
  optimizer state scales with the full chip count. Inference ("serve")
  keeps weights model-sharded only, unless the config is too big to
  replicate across data rows (``fsdp_serve`` — arctic/internvl2).
* MoE experts shard over ``model`` when E divides the axis; otherwise
  (mixtral's 8 experts on a 16-wide axis) the expert FFN dim shards.
* Every rule is guarded by divisibility — a dim that doesn't divide the
  axis stays unsharded rather than failing to lower.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig

# Archs whose bf16 weights cannot be replicated across data rows at serve
# time on 16 GB chips (see DESIGN.md §6).
FSDP_SERVE_ARCHS = {"internvl2-76b", "arctic-480b"}


@dataclasses.dataclass(frozen=True)
class MeshInfo:
    mesh: Mesh
    data_axes: Tuple[str, ...]  # ("pod","data") or ("data",)
    model_axis: str = "model"

    @property
    def model_size(self) -> int:
        return self.mesh.shape[self.model_axis]

    @property
    def data_size(self) -> int:
        return int(np.prod([self.mesh.shape[a] for a in self.data_axes]))


def mesh_info(mesh: Mesh) -> MeshInfo:
    axes = mesh.axis_names
    data_axes = tuple(a for a in axes if a != "model")
    return MeshInfo(mesh=mesh, data_axes=data_axes)


def _div(n: int, k: int) -> bool:
    return k > 0 and n % k == 0


class _Ruler:
    """Builds guarded PartitionSpecs for one (config, mesh, mode)."""

    def __init__(self, cfg: ModelConfig, mi: MeshInfo, mode: str):
        assert mode in ("train", "serve")
        self.cfg = cfg
        self.mi = mi
        self.mode = mode
        self.m = mi.model_axis
        self.dp = mi.data_axes if len(mi.data_axes) > 1 else mi.data_axes[0]
        self.msize = mi.model_size
        self.dsize = mi.data_size
        self.fsdp = mode == "train" or cfg.name in FSDP_SERVE_ARCHS

    def _axis(self, dim: int, axis_name, size: int):
        return axis_name if _div(dim, size) else None

    def matrix(self, shape: Tuple[int, ...], model_dim: int, fsdp_dim: int) -> P:
        """Spec for a (possibly layer-stacked) matrix.

        model_dim / fsdp_dim index into the *trailing* ndims of the
        logical (unstacked) weight; negative indexing from the end.
        """
        nd = len(shape)
        spec: list = [None] * nd
        mdim = nd + model_dim if model_dim < 0 else model_dim
        spec[mdim] = self._axis(shape[mdim], self.m, self.msize)
        if self.fsdp and fsdp_dim is not None:
            fdim = nd + fsdp_dim if fsdp_dim < 0 else fsdp_dim
            if fdim != mdim:
                spec[fdim] = self._axis(shape[fdim], self.dp, self.dsize)
        return P(*spec)

    def replicated(self, shape) -> P:
        return P(*([None] * len(shape)))

    def fsdp_only(self, shape: Tuple[int, ...], fsdp_dim: int) -> P:
        """No tensor parallelism; shard one dim over data axes if FSDP."""
        nd = len(shape)
        spec: list = [None] * nd
        if self.fsdp:
            fdim = nd + fsdp_dim if fsdp_dim < 0 else fsdp_dim
            spec[fdim] = self._axis(shape[fdim], self.dp, self.dsize)
        return P(*spec)


def _leaf_spec(r: _Ruler, name: str, arr) -> P:
    """Spec for one parameter leaf by name. Stacked layer axis (leading L)
    is handled by the rules operating on trailing dims."""
    cfg, shape, nd = r.cfg, arr.shape, arr.ndim

    if name == "wq":  # (.., D, out) column-parallel — whole heads only
        if _div(cfg.num_heads, r.msize):
            return r.matrix(shape, model_dim=-1, fsdp_dim=-2)
        return r.fsdp_only(shape, fsdp_dim=-2)
    if name in ("wk", "wv"):
        # GQA: shard only when kv heads split evenly over the model axis;
        # splitting inside a head (qwen kv=2 on a 16-wide axis) forces
        # per-layer all-gathers of K/V.
        if _div(cfg.num_kv_heads, r.msize):
            return r.matrix(shape, model_dim=-1, fsdp_dim=-2)
        return r.fsdp_only(shape, fsdp_dim=-2)
    if name == "wo":  # (.., q_dim, D) row-parallel
        if _div(cfg.num_heads, r.msize):
            return r.matrix(shape, model_dim=-2, fsdp_dim=-1)
        return r.fsdp_only(shape, fsdp_dim=-2)
    if name in ("wg", "wu"):
        if nd >= 3 and shape[-3] == cfg.num_experts and cfg.num_experts > 1:
            # MoE experts (.., E, D, F)
            if _div(cfg.num_experts, r.msize):
                return r.matrix(shape, model_dim=-3, fsdp_dim=-1)
            return r.matrix(shape, model_dim=-1, fsdp_dim=-2)
        return r.matrix(shape, model_dim=-1, fsdp_dim=-2)
    if name == "wd":
        if nd >= 3 and shape[-3] == cfg.num_experts and cfg.num_experts > 1:
            if _div(cfg.num_experts, r.msize):
                return r.matrix(shape, model_dim=-3, fsdp_dim=-2)
            return r.matrix(shape, model_dim=-2, fsdp_dim=-1)
        return r.matrix(shape, model_dim=-2, fsdp_dim=-1)
    if name == "bq":  # (.., out)
        if _div(cfg.num_heads, r.msize):
            return r.matrix(shape, model_dim=-1, fsdp_dim=None)
        return r.replicated(shape)
    if name in ("bk", "bv"):
        if _div(cfg.num_kv_heads, r.msize):
            return r.matrix(shape, model_dim=-1, fsdp_dim=None)
        return r.replicated(shape)
    if name == "router":
        return r.replicated(shape)
    if name == "in_proj":  # (.., D, Z) column-parallel
        return r.matrix(shape, model_dim=-1, fsdp_dim=-2)
    if name == "out_proj":  # (.., d_in, D) row-parallel
        return r.matrix(shape, model_dim=-2, fsdp_dim=-1)
    if name == "conv_w":  # (.., K, C)
        return r.matrix(shape, model_dim=-1, fsdp_dim=None)
    if name == "conv_b":
        return r.matrix(shape, model_dim=-1, fsdp_dim=None)
    if name == "embed":  # (V, D)
        if _div(shape[-2], r.msize):
            return r.matrix(shape, model_dim=-2, fsdp_dim=-1)
        return r.matrix(shape, model_dim=-1, fsdp_dim=-2)
    if name == "lm_head":  # (D, V)
        if _div(shape[-1], r.msize):
            return r.matrix(shape, model_dim=-1, fsdp_dim=-2)
        return r.matrix(shape, model_dim=-2, fsdp_dim=-1)
    if name in ("enc_pos", "dec_pos"):
        return r.replicated(shape)
    # norms, A_log, dt_bias, D, scalars
    return r.replicated(shape)


def param_specs(cfg: ModelConfig, mesh: Mesh, mode: str):
    """PartitionSpec pytree matching ``init_params(cfg)``'s structure."""
    mi = mesh_info(mesh)
    r = _Ruler(cfg, mi, mode)

    def walk(tree, path=()):
        if isinstance(tree, dict):
            return {k: walk(v, path + (k,)) for k, v in tree.items()}
        return _leaf_spec(r, path[-1], tree)

    return walk


def param_spec_tree(cfg: ModelConfig, mesh: Mesh, mode: str, params_shape):
    """Apply the rules to a concrete params (or ShapeDtypeStruct) tree."""
    mi = mesh_info(mesh)
    r = _Ruler(cfg, mi, mode)

    def walk(tree, name="param"):
        if isinstance(tree, dict):
            return {k: walk(v, k) for k, v in tree.items()}
        return _leaf_spec(r, name, tree)

    return walk(params_shape)


def cache_spec_tree(cfg: ModelConfig, mesh: Mesh, cache_shape) -> Dict[str, P]:
    """Specs for the decode cache pytree (stacked layer leading axis)."""
    mi = mesh_info(mesh)
    r = _Ruler(cfg, mi, "serve")
    dp = r.dp
    out: Dict[str, P] = {}
    for name, leaf in cache_shape.items():
        shape = leaf.shape
        if name == "pos":
            out[name] = P(dp if _div(shape[0], r.dsize) else None)
        elif name in ("k", "v", "xk", "xv"):
            # (L, B, W, Hkv, hd): batch over data; kv-heads over model when
            # divisible, else the window dim carries the model axis. When
            # batch is unshardable (long_500k B=1) the window dim carries
            # the data axes instead, spreading the cache pod-wide.
            b = dp if _div(shape[1], r.dsize) else None
            w = None if b is not None else (dp if _div(shape[2], r.dsize) else None)
            if _div(shape[3], r.msize):
                out[name] = P(None, b, w, r.m, None)
            elif w is None and _div(shape[2], r.msize):
                out[name] = P(None, b, r.m, None, None)
            else:
                out[name] = P(None, b, w, None, None)
        elif name == "conv":  # (L, B, K-1, C)
            b = dp if _div(shape[1], r.dsize) else None
            out[name] = P(None, b, None, r.m if _div(shape[3], r.msize) else None)
        elif name == "ssd":  # (L, B, H, P, N)
            b = dp if _div(shape[1], r.dsize) else None
            out[name] = P(None, b, r.m if _div(shape[2], r.msize) else None, None, None)
        else:  # pragma: no cover
            raise ValueError(name)
    return out


def input_spec_tree(cfg: ModelConfig, mesh: Mesh, shape: ShapeConfig, specs) -> Dict[str, Any]:
    """Specs for the step-function inputs produced by ``input_specs``."""
    mi = mesh_info(mesh)
    r = _Ruler(cfg, mi, "serve")
    dp = r.dp
    out: Dict[str, Any] = {}
    for name, leaf in specs.items():
        if name == "cache":
            out[name] = cache_spec_tree(cfg, mesh, leaf)
            continue
        b = dp if _div(leaf.shape[0], r.dsize) else None
        out[name] = P(b, *([None] * (len(leaf.shape) - 1)))
    return out


def opt_state_specs(param_specs_tree) -> Dict[str, Any]:
    """Optimizer state mirrors the parameter sharding."""
    return {
        "step": P(),
        "m": param_specs_tree,
        "v": param_specs_tree,
    }


def named(mesh: Mesh, spec_tree):
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s),
        spec_tree,
        is_leaf=lambda x: isinstance(x, P),
    )
