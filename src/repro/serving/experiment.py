"""End-to-end experiment runner: trace -> policy -> simulator -> summary.

One call reproduces one bar of the paper's Figure 8 (a policy at an RPS).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.serving import baselines as B
from repro.serving.profiles import build_input_pool, build_profiles
from repro.serving.simulator import (
    InvocationResult,
    SimConfig,
    Simulator,
    summarize,
)
from repro.serving.workload import ScenarioSpec, generate_scenario, generate_trace

POLICIES = (
    "static-medium",
    "static-large",
    "parrotfish",
    "aquatope",
    "cypress",
    "shabari",
    "shabari-openwhisk-sched",  # Fig. 10 ablation: allocator w/o scheduler
    "shabari-proportional",     # Fig. 7a ablation
    "shabari-packing",          # Fig. 7b ablation
)


def make_policy(name: str, profiles, pool, slo_table, seed: int = 0):
    from repro.core.cost_functions import proportional_vcpu_costs

    if name == "static-medium":
        return B.StaticPolicy(12, 3 * 1024, "static-medium")
    if name == "static-large":
        return B.StaticPolicy(20, 5 * 1024, "static-large")
    if name == "parrotfish":
        return B.ParrotfishPolicy(profiles, pool, seed=seed)
    if name == "aquatope":
        return B.AquatopePolicy(
            profiles, pool, lambda fn, idx: slo_table[(fn, idx)], seed=seed
        )
    if name == "cypress":
        return B.CypressPolicy(profiles, pool, seed=seed)
    if name == "shabari":
        return B.ShabariPolicy()
    if name == "shabari-legacy-engine":
        # the pre-arena allocator path (one jit dispatch per agent per
        # event); allocations are bit-identical to "shabari" — pinned by
        # tests/goldens/legacy-engine/ and the sim_bench engine A/B
        p = B.ShabariPolicy(engine="legacy")
        p.name = "shabari-legacy-engine"
        return p
    if name == "shabari-openwhisk-sched":
        p = B.ShabariPolicy()
        p.name = "shabari-openwhisk-sched"
        p.uses_shabari_scheduler = False
        return p
    if name == "shabari-proportional":
        p = B.ShabariPolicy(vcpu_cost_fn=proportional_vcpu_costs)
        p.name = "shabari-proportional"
        return p
    if name == "shabari-packing":
        p = B.ShabariPolicy()
        p.name = "shabari-packing"
        p.placement = "packing"
        return p
    if name in ("shabari-one-hot", "shabari-per-input-type"):
        return B.FormulationPolicy(name.replace("shabari-", ""), profiles)
    raise ValueError(name)


@dataclasses.dataclass
class ExperimentResult:
    policy: str
    rps: float
    summary: Dict[str, float]
    results: List[InvocationResult]
    container_sizes: Dict[str, int]
    # end-to-end chain metrics (Simulator.chain_summary()); None unless
    # the SimConfig enabled cfg.chains
    chain_summary: Optional[Dict[str, float]] = None


def _run_policy_on_trace(
    policy_name: str,
    trace,
    profiles,
    pool,
    slo_table,
    *,
    seed: int,
    rps: float,
    sim_cfg: Optional[SimConfig],
    vcpu_confidence: Optional[int] = None,
    mem_confidence: Optional[int] = None,
    keep_results: bool = False,
) -> ExperimentResult:
    """Shared tail of run_experiment/run_scenario: policy -> simulator
    -> summary."""
    policy = make_policy(policy_name, profiles, pool, slo_table, seed=seed)
    if vcpu_confidence is not None and hasattr(policy, "allocator"):
        policy.allocator.vcpu_confidence = vcpu_confidence
    if mem_confidence is not None and hasattr(policy, "allocator"):
        policy.allocator.mem_confidence = mem_confidence

    # Baselines that keep OpenWhisk's memory-centric load accounting get a
    # per-worker vCPU limit of +inf (vCPUs oversubscribe, §5 reason 3).
    cfg = sim_cfg or SimConfig(seed=seed)
    if not policy.uses_shabari_scheduler:
        cfg = dataclasses.replace(cfg, vcpu_limit=10_000)

    sim = Simulator(
        policy=policy, profiles=profiles, input_pool=pool,
        slo_table=slo_table, cfg=cfg,
    )
    results = sim.run(trace)
    summary = summarize(results)
    sizes = {fn: len(s) for fn, s in sim.container_sizes.items()}
    return ExperimentResult(
        policy=policy_name, rps=rps, summary=summary,
        results=results if keep_results else [],
        container_sizes=sizes,
        chain_summary=sim.chain_summary(),
    )


def run_experiment(
    policy_name: str,
    *,
    rps: float = 4.0,
    duration_s: float = 600.0,
    seed: int = 0,
    slo_multiplier: float = 1.4,
    sim_cfg: Optional[SimConfig] = None,
    vcpu_confidence: Optional[int] = None,
    mem_confidence: Optional[int] = None,
    keep_results: bool = False,
) -> ExperimentResult:
    profiles = build_profiles()
    pool = build_input_pool(seed=0)  # input pool fixed across policies
    slo_table = B.build_slo_table(profiles, pool, multiplier=slo_multiplier)
    trace = generate_trace(
        rps=rps,
        functions=sorted(profiles.keys()),
        inputs_per_function={f: len(pool[f]) for f in profiles},
        duration_s=duration_s,
        seed=seed,
    )
    return _run_policy_on_trace(
        policy_name, trace, profiles, pool, slo_table,
        seed=seed, rps=rps, sim_cfg=sim_cfg,
        vcpu_confidence=vcpu_confidence, mem_confidence=mem_confidence,
        keep_results=keep_results,
    )


# ---------------------------------------------------------------------------
# Scenario-matrix entry point
# ---------------------------------------------------------------------------


def expand_function_clones(
    profiles: Dict,
    pool: Dict,
    slo_table: Dict,
    clones: int,
) -> Tuple[Dict, Dict, Dict]:
    """Clone each function into ``clones`` independently-named aliases
    (``fn``, ``fn::1``, ...) sharing its profile, input pool, and SLOs.

    Aliases behave like distinct functions everywhere identity matters —
    warm-container reuse, home-worker hashing, per-function allocator
    agents — which is how cold-storm gets "many unique rare functions"
    out of the paper's 12 profiled behaviors."""
    if clones <= 1:
        return profiles, pool, slo_table
    P: Dict = {}
    L: Dict = {}
    S: Dict = {}
    for fn in profiles:
        for k in range(clones):
            alias = fn if k == 0 else f"{fn}::{k}"
            P[alias] = profiles[fn]
            L[alias] = pool[fn]
            for idx in range(len(pool[fn])):
                S[(alias, idx)] = slo_table[(fn, idx)]
    return P, L, S


def run_scenario(
    policy_name: str,
    spec: ScenarioSpec,
    *,
    slo_multiplier: float = 1.4,
    sim_cfg: Optional[SimConfig] = None,
    vcpu_confidence: Optional[int] = None,
    mem_confidence: Optional[int] = None,
    keep_results: bool = False,
) -> ExperimentResult:
    """Run one (policy, scenario) cell of the evaluation matrix.

    Like :func:`run_experiment` but the trace comes from the scenario
    registry, and cold-storm's ``clones`` param expands the function
    set before policies are built (so offline profilers profile every
    alias, exactly as they would real distinct functions)."""
    profiles = build_profiles()
    pool = build_input_pool(seed=0)  # input pool fixed across policies
    slo_table = B.build_slo_table(profiles, pool, multiplier=slo_multiplier)

    default_clones = 6 if spec.scenario in ("cold-storm",
                                            "registry-storm") else 1
    clones = int(spec.param("clones", default_clones))
    profiles, pool, slo_table = expand_function_clones(
        profiles, pool, slo_table, clones
    )

    trace = generate_scenario(
        spec,
        functions=sorted(profiles.keys()),
        inputs_per_function={f: len(pool[f]) for f in profiles},
    )
    return _run_policy_on_trace(
        policy_name, trace, profiles, pool, slo_table,
        seed=spec.seed, rps=spec.rps, sim_cfg=sim_cfg,
        vcpu_confidence=vcpu_confidence, mem_confidence=mem_confidence,
        keep_results=keep_results,
    )
