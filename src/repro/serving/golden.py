"""Golden-metrics scenarios: the single source of truth for the
deterministic regression harness.

One tiny fixed-seed spec per registered scenario, run on a small
4-worker cluster with the full Shabari stack (featurizer -> CSOAA
allocator -> scheduler -> simulator). ``summarize()`` outputs are
snapshotted to ``tests/goldens/<scenario>.json`` and asserted within
tolerance by ``tests/test_goldens.py``, so any PR that changes
allocator, scheduler, workload, or simulator behavior trips a golden
diff instead of sailing through.

To intentionally change behavior, regenerate and commit the snapshots:

    PYTHONPATH=src python scripts/refresh_goldens.py
"""

from __future__ import annotations

import dataclasses
from typing import Dict

from repro.core.fleet import ClusterSpec, FleetSpec, Link, MachineType, Topology
from repro.core.image_cache import ImageCacheSpec
from repro.serving.chains import default_chains
from repro.serving.experiment import run_scenario
from repro.serving.simulator import SimConfig
from repro.serving.workload import ScenarioSpec, list_scenarios

GOLDEN_POLICY = "shabari"

# metric-comparison tolerances: runs are deterministic on one machine;
# the slack only absorbs libm last-ulp differences across platforms
RTOL = 1e-5
ATOL = 1e-8

# The acquire-on-placement A/B: these scenarios are also snapshotted
# under tests/goldens/legacy-acquire/ with SimConfig(legacy_acquire=
# True), pinning the pre-reservation accounting so the two semantics
# stay independently regression-tested (tests/test_reservation.py).
LEGACY_ACQUIRE_SCENARIOS = ("multi-cluster", "oversubscribe", "poisson-steady")

# The allocator-engine A/B: snapshotted under tests/goldens/
# legacy-engine/ with ResourceAllocator(engine="legacy") — the
# per-object pre-arena path. Unlike the acquire A/B this is NOT a
# semantics fork: the snapshot must equal the main golden bit-for-bit
# (the arena is a pure fast path), which tests/test_agent_arena.py
# asserts, so a numerics drift in either engine trips CI.
LEGACY_ENGINE_SCENARIOS = ("heavy-tail-inputs",)

# The event-loop A/B: snapshotted under tests/goldens/
# legacy-event-loop/ with SimConfig(legacy_event_loop=True) — the
# pre-refactor single-heapq hot loop. Like the engine A/B this is NOT
# a semantics fork: the snapshot must equal the main golden
# bit-for-bit (the array-backed loop + calendar queue is a pure fast
# path), which tests/test_event_loop.py asserts, so drift in either
# loop trips CI. oversubscribe is the pin because its golden exercises
# retries, sheds, and queue timeouts — the event classes the fast
# loop's merge logic reorders most easily if it is wrong.
LEGACY_EVENT_LOOP_SCENARIOS = ("oversubscribe",)

# The completion-time-estimate routing mode: snapshotted under
# tests/goldens/estimate-routing/ with SimConfig(routing="estimate"),
# so the new front-door policy is regression-pinned independently while
# every main golden keeps pinning the default spill-over behavior
# (tests/test_router.py asserts the pin).
ESTIMATE_ROUTING_SCENARIOS = ("multi-cluster",)

# The image-cache A/B: registry-storm's MAIN golden runs with
# SimConfig(image_cache=ImageCacheSpec()) — pull-what's-missing cold
# starts plus cache-affinity placement — and is ALSO snapshotted under
# tests/goldens/cache-disabled/ with image_cache=None, pinning the
# flat-constant cold model on the same trace. This IS a semantics fork
# (cold latencies differ), so the two snapshots are independently
# regression-tested (tests/test_image_cache.py).
CACHE_DISABLED_SCENARIOS = ("registry-storm",)

# The chain-slack A/B: chain-pipeline's MAIN golden runs with
# SimConfig(chain_slack="aware") — per-stage budgets decomposed from
# the end-to-end SLO via critical-path analysis — and is ALSO
# snapshotted under tests/goldens/chain-uniform/ with
# chain_slack="uniform" (flat e2e/depth split per stage). This IS a
# semantics fork (admission and estimate routing see different
# budgets), so the two snapshots are independently regression-tested
# (tests/test_chains.py asserts the pin).
CHAIN_UNIFORM_SCENARIOS = ("chain-pipeline",)


# Heterogeneous-fleet goldens (repro.core.fleet). Both fleets keep the
# main goldens' 4-worker footprint (2 clusters x 2 workers of 32-vCPU/
# 16-GB machines) so metrics stay comparable across scenarios:
#
# * hetero-fleet — cluster 0 is the reference fast tier, cluster 1 a
#   cheap/slow spot tier (half the cores, slower NIC and cold starts,
#   1.35x exec time, preemptible), free links: pins the per-machine
#   cold-curve / contention / exec-factor / preemptible-last paths;
# * wan-spill — uniform fast machines, but the clusters sit across a
#   1 Gb / 50 ms WAN link, under estimate routing: pins transfer
#   charging and the router's transfer pricing on spills.
_GOLDEN_FAST = MachineType(
    name="fast-32c", physical_cores=32, vcpus=32, mem_mb=16 * 1024)
_GOLDEN_SLOW = MachineType(
    name="slow-16c", physical_cores=16, vcpus=32, mem_mb=16 * 1024,
    nic_gbps=5.0, cold_base_s=0.65, cold_per_gb_s=0.18, exec_factor=1.35,
    preemptible=True, price_per_hour=0.4)
_GOLDEN_HETERO_FLEET = FleetSpec(clusters=(
    ClusterSpec(machines=((_GOLDEN_FAST, 2),)),
    ClusterSpec(machines=((_GOLDEN_SLOW, 2),)),
))
_GOLDEN_WAN_FLEET = FleetSpec(
    clusters=(
        ClusterSpec(machines=((_GOLDEN_FAST, 2),)),
        ClusterSpec(machines=((_GOLDEN_FAST, 2),)),
    ),
    topology=Topology(default_link=Link(gbps=1.0, latency_s=0.05)),
)
# registry-storm fleet: same 4-worker/32-vCPU footprint, but each node
# keeps only a 4 GB layer store behind a 2 Gb registry downlink — small
# enough that the clone catalog churns the LRU and slow enough that a
# full pull dwarfs the classic cold curve, so cache-affinity placement
# has real physics to exploit
_GOLDEN_REGISTRY = MachineType(
    name="fast-32c-reg2g", physical_cores=32, vcpus=32, mem_mb=16 * 1024,
    image_store_mb=4 * 1024, registry_gbps=2.0)
_GOLDEN_REGISTRY_FLEET = FleetSpec(
    clusters=(ClusterSpec(machines=((_GOLDEN_REGISTRY, 4),)),))

# per-scenario SimConfig overrides: multi-cluster splits the same
# 4-worker footprint into 2 clusters x 2 workers behind the spill-over
# router, so the golden actually exercises the front door; the two
# fleet scenarios swap in an explicit FleetSpec (which overrides the
# uniform n_clusters/n_workers knobs entirely)
_GOLDEN_SIM_OVERRIDES: Dict[str, Dict] = {
    "multi-cluster": {"n_clusters": 2, "n_workers": 2},
    "hetero-fleet": {"fleet": _GOLDEN_HETERO_FLEET},
    "wan-spill": {"fleet": _GOLDEN_WAN_FLEET, "routing": "estimate"},
    # registry-storm pins the image-cache subsystem: finite per-node
    # layer stores (small enough to churn on the clone catalog) over a
    # slow registry downlink, with cache-affinity placement on
    "registry-storm": {"image_cache": ImageCacheSpec(),
                       "fleet": _GOLDEN_REGISTRY_FLEET},
    # the chain goldens turn the workload dimension on: trigger
    # arrivals start DAG instances and downstream stages are spawned by
    # the simulator. chain-pipeline runs the full slack-aware stack
    # (estimate routing scored against remaining e2e budget + SLO
    # admission with the warm-hold fork); fan-out-join pins the join
    # barrier + fan-out pre-warm under estimate routing alone, so the
    # two goldens localize regressions to different chain subsystems.
    "chain-pipeline": {"chains": (default_chains()["pipeline"],),
                       "routing": "estimate", "admission": "slo"},
    "fan-out-join": {"chains": (default_chains()["fanout"],),
                     "routing": "estimate"},
}


def golden_sim_config(scenario: str = "") -> SimConfig:
    """A deliberately small cluster (4 x 32 vCPU x 16 GB) so contention,
    queueing, and (for oversubscribe) timeouts all actually fire inside
    a two-minute trace. The short queue timeout / slow retry cadence
    keep the saturating scenarios from degenerating into retry storms —
    goldens must stay cheap enough for tier-1."""
    cfg = SimConfig(
        n_workers=4,
        vcpus_per_worker=32,
        physical_cores=32,
        mem_mb_per_worker=16 * 1024,
        vcpu_limit=32,
        retry_interval_s=1.0,
        queue_timeout_s=45.0,
        seed=0,
    )
    return dataclasses.replace(cfg, **_GOLDEN_SIM_OVERRIDES.get(scenario, {}))


# soften the two saturating shapes just enough that a queue backlog
# drains within the golden window (full-strength versions run in
# benchmarks/scenario_matrix.py)
_GOLDEN_PARAMS = {
    "flash-crowd": {"spike_mult": 5.0},
    "oversubscribe": {"load_mult": 2.0},
    "registry-storm": {"spike_mult": 3.0},
}


def golden_specs() -> Dict[str, ScenarioSpec]:
    return {
        name: ScenarioSpec(
            scenario=name, rps=2.0, duration_s=120.0, seed=0,
            params=dict(_GOLDEN_PARAMS.get(name, {})),
        )
        for name in list_scenarios()
    }


def run_golden(scenario: str, *, legacy_acquire: bool = False,
               legacy_engine: bool = False,
               estimate_routing: bool = False,
               legacy_event_loop: bool = False,
               cache_disabled: bool = False,
               chain_uniform: bool = False) -> Dict[str, float]:
    spec = golden_specs()[scenario]
    cfg = golden_sim_config(scenario)
    if legacy_acquire:
        cfg = dataclasses.replace(cfg, legacy_acquire=True)
    if estimate_routing:
        cfg = dataclasses.replace(cfg, routing="estimate")
    if legacy_event_loop:
        cfg = dataclasses.replace(cfg, legacy_event_loop=True)
    if cache_disabled:
        cfg = dataclasses.replace(cfg, image_cache=None)
    if chain_uniform:
        cfg = dataclasses.replace(cfg, chain_slack="uniform")
    policy = "shabari-legacy-engine" if legacy_engine else GOLDEN_POLICY
    res = run_scenario(policy, spec, sim_cfg=cfg)
    summary = res.summary
    if res.chain_summary is not None:
        # chain scenarios fold the end-to-end DAG metrics into the
        # golden (keys are chain_-prefixed, so no collision)
        summary = {**summary, **res.chain_summary}
    return summary
