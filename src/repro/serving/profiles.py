"""Performance/utilization profiles for the paper's 12 functions (Table 1).

Each profile supplies what the 17-node testbed supplies in the paper:
execution time, vCPU utilization, and memory footprint for a given
(input, vCPU allocation) — parameterized to reproduce the §2
measurement-study observations:

* positive but NON-linear size→time relationships (§2.1, Figure 2);
* input properties beyond size matter: ``videoprocess`` parallelism and
  memory are driven by RESOLUTION — same-size videos differ ~70% in
  vCPUs used (Figure 3);
* bounded parallelism: imageprocess/sentiment/encrypt/speech2text/qr are
  single-threaded; matmult/linpack/compress/lrtrain/resnet scale then
  saturate (§2.2, Figure 4);
* decoupled intensities: videoprocess/matmult/linpack/lrtrain are
  compute-heavy with low memory use; sentiment is memory-bound at
  1 vCPU (§2.3);
* larger inputs of multi-threaded functions run noisier — ``compress``
  shows ~50% execution-time variability at 2 GB (Figure 2c).

The model: exec = t0 + serial(meta) + parallel(meta)/min(v, par(meta)),
times a contention factor supplied by the simulator, times lognormal
noise that grows with input size for multi-threaded functions.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Callable, Dict, List, Tuple

import numpy as np


@dataclasses.dataclass(frozen=True)
class FunctionProfile:
    name: str
    input_type: str
    # work components (seconds of single-core time)
    t0: float  # fixed startup/serial floor
    serial: Callable[[Dict], float]
    parallel: Callable[[Dict], float]
    max_parallelism: Callable[[Dict], float]
    mem_mb: Callable[[Dict], float]
    noise_base: float = 0.03  # lognormal sigma at the smallest inputs
    noise_size_coef: float = 0.0  # extra sigma per unit of size_scale(meta)
    size_scale: Callable[[Dict], float] = lambda m: 0.0

    def exec_time(self, meta: Dict, vcpus: int, rng: np.random.Generator,
                  contention: float = 1.0) -> float:
        par = max(1.0, min(float(vcpus), self.max_parallelism(meta)))
        t = self.t0 + self.serial(meta) + self.parallel(meta) / par
        sigma = self.noise_base + self.noise_size_coef * self.size_scale(meta)
        t *= float(rng.lognormal(mean=0.0, sigma=sigma))
        return t * max(contention, 1.0)

    def vcpus_used(self, meta: Dict, vcpus: int) -> float:
        """Peak parallel occupancy given the allocation."""
        par = max(1.0, min(float(vcpus), self.max_parallelism(meta)))
        ser = self.t0 + self.serial(meta)
        pw = self.parallel(meta)
        if pw <= 0:
            return 1.0
        # time-weighted peak: during the parallel phase, par cores are busy
        return min(float(vcpus), par)

    def exec_and_demand(self, meta: Dict, vcpus: int,
                        rng: np.random.Generator) -> Tuple[float, float]:
        """Fused ``(exec_time(contention=1), vcpus_used)`` — one pass
        over the per-input lambdas instead of two (the simulator's hot
        start path calls both for every invocation). Identical values
        and the identical single rng draw."""
        v = float(vcpus)
        par = max(1.0, min(v, self.max_parallelism(meta)))
        pw = self.parallel(meta)
        t = self.t0 + self.serial(meta) + pw / par
        sigma = self.noise_base + self.noise_size_coef * self.size_scale(meta)
        t *= float(rng.lognormal(mean=0.0, sigma=sigma))
        return t, (1.0 if pw <= 0 else min(v, par))

    def mem_used_mb(self, meta: Dict) -> float:
        return self.mem_mb(meta)


def _mb(x: float) -> float:
    return x / 1e6


_BASE_CACHE: Dict[str, str] = {}


def base_function(fn: str) -> str:
    """Strip a clone suffix (``matmult::3`` -> ``matmult``).

    Scenario generators (cold-storm) clone the 12 paper functions into
    many independently-named aliases; everything keyed on the function's
    BEHAVIOR (profile shape, network-fed set, input-size model) must
    look through the alias. Memoized — the hot loop asks per event and
    the alias universe is small."""
    base = _BASE_CACHE.get(fn)
    if base is None:
        base = _BASE_CACHE[fn] = fn.split("::", 1)[0]
    return base


# ---------------------------------------------------------------------------
# The 12 functions
# ---------------------------------------------------------------------------


def build_profiles() -> Dict[str, FunctionProfile]:
    P: Dict[str, FunctionProfile] = {}

    # matmult: n in 500..80000; beyond ~10k the matrices are sparse
    # (density shrinks), capping the dense working set at ~2.5 GB.
    P["matmult"] = FunctionProfile(
        name="matmult", input_type="matrix", t0=0.15,
        serial=lambda m: 2e-9 * m["rows"] * m["cols"],
        parallel=lambda m: 5.2e-11 * m["rows"] ** 1.5 * m["cols"] ** 1.5
        * max(m.get("density", 1.0), 0.05),
        max_parallelism=lambda m: min(32.0, 4.0 + m["rows"] / 2500.0),
        mem_mb=lambda m: 60.0
        + 3 * 8e-6 * min(m["rows"], 10_000.0) * min(m["cols"], 10_000.0),
        noise_base=0.04, noise_size_coef=0.03,
        size_scale=lambda m: m["rows"] / 80000.0,
    )

    # linpack: n in 500..8000 (solve, n^3)
    P["linpack"] = FunctionProfile(
        name="linpack", input_type="matrix", t0=0.12,
        serial=lambda m: 1e-8 * m["rows"] * m["cols"] ** 0.5,
        parallel=lambda m: 1.8e-9 * m["rows"] ** 3 / 1e2,
        max_parallelism=lambda m: min(24.0, 2.0 + m["rows"] / 600.0),
        mem_mb=lambda m: 50.0 + 2 * 8e-6 * m["rows"] * m["cols"],
        noise_base=0.05, noise_size_coef=0.02,
        size_scale=lambda m: m["rows"] / 8000.0,
    )

    # imageprocess: single-threaded resize/filter. Two regimes: beyond
    # ~2 MP the working set spills cache and the per-pixel cost grows —
    # the non-linear size->time relation of Figure 2 (contra Cypress's
    # linear assumption).
    P["imageprocess"] = FunctionProfile(
        name="imageprocess", input_type="image", t0=0.08,
        serial=lambda m: 6.6e-7 * (m["width"] * m["height"]) ** 0.92
        * (1.0 + m["width"] * m["height"] / 2.5e6),
        parallel=lambda m: 0.0,
        max_parallelism=lambda m: 1.0,
        mem_mb=lambda m: 40.0 + 4e-6 * m["width"] * m["height"] * m["channels"],
        noise_base=0.04,
    )

    # videoprocess: parallelism and memory driven by RESOLUTION, not size.
    # high-res (>=1280x720): heavy frames -> fewer decode threads useful,
    # bigger frame buffers; low-res: many slices in flight -> up to 48 cores.
    P["videoprocess"] = FunctionProfile(
        name="videoprocess", input_type="video", t0=0.3,
        serial=lambda m: 0.04 * m["duration"],
        parallel=lambda m: 1.9e-6 * m["bitrate"] * m["duration"] / 8.0,
        # scalar min/max == np.clip here (clip is min(hi, max(x, lo)))
        # without the per-call ufunc dispatch on a python float
        max_parallelism=lambda m: min(
            48.0, max(56.0 * 9.2e5 / (m["width"] * m["height"]), 6.0)
        ),
        mem_mb=lambda m: 90.0 + 9e-6 * m["width"] * m["height"] * 24
        + 2e-7 * m["bitrate"],
        noise_base=0.05, noise_size_coef=0.04,
        size_scale=lambda m: m["duration"] / 120.0,
    )

    # encrypt: single-threaded, linear in payload length
    P["encrypt"] = FunctionProfile(
        name="encrypt", input_type="string", t0=0.05,
        serial=lambda m: 1.2e-4 * m["length"],
        parallel=lambda m: 0.0,
        max_parallelism=lambda m: 1.0,
        mem_mb=lambda m: 30.0 + 1e-3 * m["length"],
        noise_base=0.03,
    )

    # mobilenet inference: mild parallelism (intra-op), const + pixels
    P["mobilenet"] = FunctionProfile(
        name="mobilenet", input_type="image", t0=0.35,
        serial=lambda m: 0.12 + 1.5e-8 * m["width"] * m["height"],
        parallel=lambda m: 4.6e-6 * (m["width"] * m["height"]) ** 0.95,
        max_parallelism=lambda m: 4.0,
        mem_mb=lambda m: 260.0 + 6e-6 * m["width"] * m["height"],
        noise_base=0.05,
    )

    # sentiment: memory-bound, single-threaded (embedding tables)
    P["sentiment"] = FunctionProfile(
        name="sentiment", input_type="batch_of_strings", t0=0.25,
        serial=lambda m: 7e-3 * m["count"] + 2.4e-6 * m["total_length"],
        parallel=lambda m: 0.0,
        max_parallelism=lambda m: 1.0,
        mem_mb=lambda m: 800.0 + 0.6 * m["count"],
        noise_base=0.04,
    )

    # speech2text: single-threaded decode, linear in duration
    P["speech2text"] = FunctionProfile(
        name="speech2text", input_type="audio", t0=0.5,
        serial=lambda m: 0.9 * m["duration"],
        parallel=lambda m: 0.0,
        max_parallelism=lambda m: 1.0,
        mem_mb=lambda m: 350.0 + 1.6 * m["duration"],
        noise_base=0.05,
    )

    # qr: trivial single-threaded
    P["qr"] = FunctionProfile(
        name="qr", input_type="url", t0=0.04,
        serial=lambda m: 2.5e-4 * m["length"],
        parallel=lambda m: 0.0,
        max_parallelism=lambda m: 1.0,
        mem_mb=lambda m: 25.0 + 0.05 * m["length"],
        noise_base=0.03,
    )

    # lrtrain: data-parallel epochs; work ~ rows*cols
    P["lrtrain"] = FunctionProfile(
        name="lrtrain", input_type="training_set", t0=0.4,
        serial=lambda m: 1.2e-8 * m["rows"] * m["cols"],
        parallel=lambda m: 2.8e-6 * m["rows"] * m["cols"],
        max_parallelism=lambda m: min(24.0, 2.0 + m["rows"] / 8e4),
        mem_mb=lambda m: 150.0 + 16e-6 * m["rows"] * m["cols"],
        noise_base=0.05, noise_size_coef=0.03,
        size_scale=lambda m: m["rows"] / 1e6,
    )

    # compress: multi-threaded (zstd-like), variability grows with size
    P["compress"] = FunctionProfile(
        name="compress", input_type="file", t0=0.2,
        serial=lambda m: 2e-9 * m["file_size"],
        parallel=lambda m: 6.5e-8 * m["file_size"],
        max_parallelism=lambda m: min(
            20.0, 2.0 + _mb(m["file_size"]) / 64.0
        ),
        mem_mb=lambda m: 120.0 + 0.25 * _mb(m["file_size"]),
        noise_base=0.05, noise_size_coef=0.22,
        size_scale=lambda m: _mb(m["file_size"]) / 2000.0,
    )

    # resnet-50 inference: saturating parallel gains (Figure 4b)
    P["resnet50"] = FunctionProfile(
        name="resnet50", input_type="image", t0=0.4,
        serial=lambda m: 0.18 + 2e-8 * m["width"] * m["height"],
        parallel=lambda m: 1.8e-5 * (m["width"] * m["height"]) ** 0.92,
        max_parallelism=lambda m: min(
            12.0, 3.0 + m["width"] * m["height"] / 1.2e6
        ),
        mem_mb=lambda m: 700.0 + 8e-6 * m["width"] * m["height"],
        noise_base=0.05,
    )

    return P


# ---------------------------------------------------------------------------
# Input pools (Table 1 size ranges; videoprocess gets the two §2.1 sets)
# ---------------------------------------------------------------------------


def build_input_pool(seed: int = 0) -> Dict[str, List[Dict]]:
    rng = np.random.default_rng(seed)
    pool: Dict[str, List[Dict]] = {}

    def sizes(lo, hi, n, log=True):
        if log:
            return np.exp(np.linspace(math.log(lo), math.log(hi), n))
        return np.linspace(lo, hi, n)

    pool["matmult"] = [
        {"rows": float(n), "cols": float(n), "density": float(rng.uniform(0.3, 1.0))}
        for n in sizes(500, 80000, 9)
    ]
    pool["linpack"] = [
        {"rows": float(n), "cols": float(n), "density": 1.0}
        for n in sizes(500, 8000, 11)
    ]

    def image_inputs(n, lo=12e3, hi=4.6e6):
        out = []
        for fs in sizes(lo, hi, n):
            # file size -> resolution (jpeg ~ 0.5 byte/pixel), 3-4 channels
            pixels = fs * 2.2
            ar = rng.uniform(0.6, 1.8)
            w = math.sqrt(pixels * ar)
            out.append({
                "width": float(w), "height": float(pixels / w),
                "channels": float(rng.choice([1, 3, 3, 4])),
                "dpi_x": 72.0, "dpi_y": 72.0, "file_size": float(fs),
            })
        return out

    pool["imageprocess"] = image_inputs(14)
    pool["mobilenet"] = image_inputs(14)
    pool["resnet50"] = image_inputs(9, lo=184e3)

    # videoprocess: set-1 (varying resolution) + set-2 (constant 1280x720)
    vids = []
    for fs in sizes(2.2e6, 6.1e6, 3):
        for (w, h) in ((640, 360), (1280, 720), (1920, 1080)):
            dur = fs * 8.0 / (w * h * 0.07)  # duration from size & res
            vids.append({
                "width": float(w), "height": float(h),
                "duration": float(np.clip(dur, 4, 180)),
                "bitrate": float(fs * 8.0 / np.clip(dur, 4, 180)),
                "fps": 30.0, "encoding": "h264", "file_size": float(fs),
            })
    pool["videoprocess"] = vids[:5] + [
        {"width": 1280.0, "height": 720.0,
         "duration": float(np.clip(fs * 8 / (1280 * 720 * 0.07), 4, 180)),
         "bitrate": float(1280 * 720 * 0.07),
         "fps": 30.0, "encoding": "mp4", "file_size": float(fs)}
        for fs in sizes(2.2e6, 6.1e6, 3)
    ]

    pool["encrypt"] = [{"length": float(n)} for n in sizes(500, 50000, 7)]
    pool["sentiment"] = [
        {"count": float(n), "total_length": float(n) * 80.0}
        for n in sizes(50, 3000, 12)
    ]
    pool["speech2text"] = [
        {"channels": 1.0, "sample_rate": 16000.0,
         "duration": float(fs / 32000.0),  # 16 kHz x 2 B/sample
         "bitrate": 256000.0, "is_flac": bool(rng.random() < 0.3),
         "file_size": float(fs)}
        for fs in sizes(48e3, 12e6, 8)
    ]
    pool["qr"] = [{"length": float(n)} for n in sizes(25, 480, 11, log=False)]
    pool["lrtrain"] = [
        {"file_size": float(fs), "rows": float(fs / 100.0), "cols": 25.0}
        for fs in sizes(10e6, 100e6, 4)
    ]
    pool["compress"] = [{"file_size": float(fs)} for fs in sizes(64e6, 2e9, 7)]
    return pool


def input_size_mb(fn: str, meta: Dict) -> float:
    fn = base_function(fn)
    fs = meta.get("file_size")
    if fs is not None:
        return fs / 1e6
    if fn in ("matmult", "linpack"):
        return 8e-6 * meta["rows"] * meta["cols"]
    if fn == "sentiment":
        return meta["total_length"] / 1e6
    return 0.001
