"""Discrete-event cluster simulator — the stand-in for the paper's
17-node OpenWhisk testbed (§7.1).

The policies, allocator, featurizer, scheduler, daemon, and metadata
store are the REAL implementations from ``repro.core``; the simulator
only supplies what the hardware supplied in the paper: time, utilization
and contention. Modeled effects, each tied to a paper observation:

* cold starts: container create latency grows with container size;
* vCPU contention: when the sum of ACTIVE parallel demand on a worker
  exceeds its physical cores, co-located invocations slow down
  proportionally (why static-large still violates SLOs, §7.2);
* network contention: object-store-fed functions (matmult, lrtrain,
  imageprocess, compress, ...) share a 10 Gb NIC per worker — the effect
  that sinks Hermod-style packing (Figure 7b);
* OOM kills: an invocation whose footprint exceeds its allocation dies
  partway through (§4.3.2 safeguards exist because of this);
* queueing + timeouts: invocations that cannot be placed retry and
  eventually time out (the §7.5 oversubscription study). The
  Allocation — and the policy's featurization cache (aux) — is decided
  ONCE at first arrival and carried through retries; timed-out
  invocations report it without re-entering the policy (pre-fix
  behavior behind ``SimConfig.legacy_retry_alloc``).

Event-loop microbatching: consecutive same-timestamp arrivals are
popped together and offered to ``Policy.begin_arrival_batch`` before
being processed in order, so a learning policy (the agent arena,
``repro.core.agent_arena``) serves them with one fused predict
dispatch; pending agent updates always flush before any prediction for
the same function, keeping served allocations bit-identical to the
sequential path.

``SimConfig(n_clusters=N)`` scales the testbed to N such clusters
behind a front-door :class:`repro.core.router.Router`; ``routing``
picks one of four policies — home-cluster ``hashing``, cold-start-aware
``spill-over`` (default), completion-time-estimate ``estimate``
(minimum-ECT placement including still-warming containers within
``estimate_horizon_s``, calibrated online from observed exec times),
and ``random``. The simulator feeds the estimator via
``Router.observe_exec`` at every completion and commits estimate-mode
``Decision.pending`` bindings (busy + reservation on a warming
container, start at its ``warm_at``).

Resource lifecycle: capacity is acquired at PLACEMENT, not at start — a
placed cold start reserves its container's (vcpus, mem) for the whole
warm-up window, so ``Worker.fits`` and ``Router._load`` see committed-
but-warming capacity (``SimConfig.legacy_acquire`` restores the old
acquire-on-start accounting for A/B). ``SimConfig.admission`` adds
front-door admission control (shed / queue) under fleet-wide overload.
"""

from __future__ import annotations

import dataclasses
import heapq
import itertools
from collections import deque
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.cluster import Cluster, Container, Worker
from repro.core.cost_functions import Observation
from repro.core.daemon import (SAMPLE_INTERVAL_S, UtilizationTrace,
                               WorkerDaemon, synth_trace)
from repro.core.fleet import COLD_JITTER_SIGMA, FleetSpec, MachineType
from repro.core.image_cache import (ImageCacheSpec, NodeImageCache,
                                    default_images)
from repro.core.metadata_store import InvocationRecord, MetadataStore
from repro.serving.event_queue import CalendarQueue
from repro.serving.profiles import FunctionProfile, base_function, input_size_mb
from repro.serving.workload import Arrival

# functions that pull inputs over the network from the object store (§5)
NETWORK_FED = {"matmult", "lrtrain", "imageprocess", "compress",
               "videoprocess", "speech2text", "resnet50", "mobilenet"}
NIC_GBPS = 10.0


@dataclasses.dataclass
class SimConfig:
    n_workers: int = 16  # workers PER CLUSTER (total = n_workers * n_clusters)
    vcpus_per_worker: int = 90
    physical_cores: int = 96
    mem_mb_per_worker: int = 125 * 1024
    vcpu_limit: int = 90
    cold_base_s: float = 0.45
    cold_per_gb_s: float = 0.12
    sched_overhead_s: float = 0.001
    retry_interval_s: float = 0.5
    queue_timeout_s: float = 300.0
    keep_alive_s: float = 600.0
    seed: int = 0
    # How co-runner contention is applied to an invocation:
    #   "snapshot" (default) — the slowdown is computed ONCE at start
    #     time from the co-runners active at that instant and held for
    #     the invocation's whole run. This is the original semantics;
    #     with it, metrics match the pre-refactor per-event scan.
    #   "dynamic" — the slowdown is re-evaluated whenever a co-runner
    #     starts or finishes on the same worker: remaining work is
    #     rescaled and the finish event re-queued. Closer to real
    #     cgroup CPU-share behavior; metrics differ from snapshot.
    contention_mode: str = "snapshot"
    # Compatibility switch for A/B benchmarking (benchmarks/sim_bench):
    # restore the pre-refactor O(N) loops — the per-event scan over
    # every running invocation for contention demand, and the
    # per-schedule scan over every container for warm lookups — instead
    # of the incremental per-worker aggregates and per-function index.
    # Metrics are identical either way; only speed differs. Only
    # meaningful with contention_mode="snapshot".
    legacy_scans: bool = False
    # Multi-cluster front door (repro.core.router): number of clusters
    # behind the router and the routing policy applied per arrival —
    # "hashing" | "spill-over" | "estimate" | "random". With
    # n_clusters=1 the first, second, and fourth degenerate to the
    # single-cluster path; "estimate" does NOT degenerate — its
    # warming-soon binding (below) still short-circuits cold starts
    # inside one cluster.
    n_clusters: int = 1
    routing: str = "spill-over"
    # Estimate-mode horizon (SECONDS): a still-warming uncommitted
    # container whose warm_at lies within this many seconds of the
    # arrival is a placement target — the invocation binds to it
    # (Decision.pending), the runtime reserves its capacity, and it
    # starts the moment the container turns warm, paying the residual
    # warm-up instead of a full cold start. Larger horizons trade
    # certain short waits against speculative cold starts; the default
    # covers the full cold-start range of the paper's container sizes
    # (~0.5-1.3 s). Read only when routing == "estimate".
    estimate_horizon_s: float = 1.5
    # Compatibility switch for A/B benchmarking (benchmarks/sim_bench):
    # restore the pre-fix retry path — one policy.allocate (a jit'd jax
    # dispatch for learning policies) per 0.5 s RETRY of a queued
    # invocation, run even when the invocation is about to time out —
    # instead of caching the Allocation in the retry payload.
    legacy_retry_alloc: bool = False
    # Resource lifecycle (benchmarks/admission_bench A/B). Default is
    # acquire-on-PLACEMENT: a cold-started invocation reserves its
    # container's (vcpus, mem) the moment it is placed, so Worker.fits,
    # the per-worker aggregates, and Router._load all see committed-but-
    # warming capacity; the reservation converts to a running
    # acquisition when the cold start completes and is released if the
    # invocation's queue timeout lapses first. legacy_acquire=True
    # restores acquire-on-START (capacity held only once the container
    # is warm), under which arrivals inside the warm-up window see a
    # free-looking worker and stack cold starts onto it.
    legacy_acquire: bool = False
    # Router-level admission control. The load-headroom modes act under
    # fleet-wide overload — when EVERY cluster's committed load exceeds
    # admission_headroom, "shed" drops the arrival at the front door
    # (recorded as a shed result, an SLO violation) and "queue" holds
    # it in the front-door retry queue without probing any scheduler.
    # "slo" is the SLO-native mode: ignore load headroom and instead
    # shed exactly the invocations whose minimum completion-time
    # estimate across clusters already exceeds their remaining SLO
    # budget — work that cannot be served in time no matter where it
    # lands (uncalibrated functions are always admitted). "none"
    # (default) admits everything, as before.
    admission: str = "none"
    admission_headroom: float = 0.95
    # Per-input exec estimation (the tentpole of the SLO-native PR):
    # when True (default), the feature vector + input size a policy
    # caches in its retry aux (the Featurizer output ShabariPolicy
    # already computes) feed the router's per-function online regressor
    # (repro.core.ect), so estimate routing and SLO admission see
    # heavy-tail inputs coming instead of forecasting the EWMA mean for
    # every invocation. False restores the input-blind EWMA-only
    # estimator for A/B (benchmarks/estimate_bench). Policies that
    # cache no features (the static/offline baselines) always use the
    # EWMA path regardless.
    estimate_features: bool = True
    # Heterogeneous fleet + network topology (repro.core.fleet). None
    # (default) builds the uniform fleet the flags above describe —
    # n_clusters x n_workers of one machine type mirroring
    # physical_cores / vcpus_per_worker / vcpu_limit /
    # mem_mb_per_worker / cold_base_s / cold_per_gb_s / NIC_GBPS, with
    # zero-cost links — and is bit-identical to pre-fleet behavior. An
    # explicit FleetSpec OVERRIDES those per-worker/per-cluster flags
    # entirely (each Worker takes its MachineType's shape; note this
    # includes the OpenWhisk-baseline vcpu_limit override in
    # repro.serving.experiment, which is a no-op under an explicit
    # fleet) and charges arrival→cluster input-payload transfer time on
    # remote placements over non-free links.
    fleet: Optional[FleetSpec] = None
    # Compatibility switch for A/B benchmarking (benchmarks/sim_bench
    # scale tier) and equality testing (tests/test_event_loop.py):
    # restore the pre-refactor hot loop — one global heapq over every
    # event (arrivals pre-pushed, so a 24 h trace seeds a million-entry
    # heap) and the full synth_trace utilization series per completion —
    # instead of the array-backed loop (arrival stream kept as a sorted
    # array, calendar-bucketed queue for scheduled events, slim daemon
    # path that draws the identical rng stream without materializing
    # samples nobody reads). Metrics and goldens are byte-identical
    # either way; only speed differs. The same flush-before-read
    # discipline applies on both paths (pending agent updates flush
    # before any same-function prediction).
    legacy_event_loop: bool = False
    # Estimate-mode A/B for the fleet refactor: when True (default) the
    # router PRICES the same input-payload transfer time the simulator
    # charges on remote placements (plus each machine's cold curve and
    # exec-speed factor — those are always priced via Worker.machine).
    # False makes estimate routing transfer-BLIND: it scores remote
    # clusters as if spilling were free, the pre-fleet assumption
    # (benchmarks/fleet_bench gates the gap). No effect on what the
    # simulator charges.
    estimate_transfer: bool = True
    # Locality-aware cold starts (repro.core.image_cache): an
    # ImageCacheSpec attaches a finite per-node layer store to every
    # worker and cold latency becomes pull-what's-missing — the
    # registry fetch of the image's non-resident layers (over the
    # machine's registry_gbps downlink) overlapped with the classic
    # cold curve. ImageCacheSpec(affinity=True) additionally ranks
    # cold placement by residual pull and prices it in estimate
    # routing; affinity=False keeps decisions cache-blind (the A/B
    # arm, benchmarks/registry_bench). The None default is the flat
    # -constant cold model with a zero-overhead fast path: no cache
    # objects, no per-arrival lookups, rng stream untouched — every
    # pre-existing golden is byte-identical.
    image_cache: Optional[ImageCacheSpec] = None
    # Function-chain/DAG workloads (repro.serving.chains): a tuple of
    # ChainSpec makes every trace arrival of a spec's trigger function
    # start a chain instance — upstream completions spawn downstream
    # stage arrivals (join barriers wait for ALL parents; the child's
    # input is the pool entry nearest the summed in-edge payloads), and
    # per-stage SLO budgets come from the chain's END-TO-END SLO
    # instead of the per-invocation slo_table. The None default is a
    # zero-overhead fast path (no runtime object, no per-event hooks'
    # work, rng stream untouched): every pre-existing golden is
    # byte-identical.
    chains: Optional[Tuple] = None
    # How the end-to-end budget decomposes into per-stage allowances:
    # "aware" (default) reserves the longest expected path below the
    # stage (critical-path slack analysis) and feeds the remaining
    # budget to estimate routing as ``budget_s``; "uniform" is the
    # slack-blind A/B arm — the e2e SLO split evenly over the critical
    # path's depth, no routing budget (benchmarks/chain_bench).
    chain_slack: str = "aware"
    # Fifer-style proactive scaling: when the running stage-N
    # invocations feeding a stage-N+1 function outnumber its idle
    # warm+warming containers on its home cluster, launch one
    # uncommitted warming container (the existing warming-soon index)
    # sized from the function's last allocation. Read only when
    # ``chains`` is set.
    chain_prewarm: bool = True


@dataclasses.dataclass(slots=True)
class InvocationResult:
    invocation_id: int
    function: str
    arrival_t: float
    start_t: float = 0.0
    finish_t: float = 0.0
    exec_s: float = 0.0
    slo_s: float = 0.0
    alloc_vcpus: int = 0
    alloc_mem_mb: int = 0
    used_vcpus: float = 0.0
    used_mem_mb: float = 0.0
    cold_start: bool = False
    cold_latency_s: float = 0.0
    queued_s: float = 0.0
    oom_killed: bool = False
    timed_out: bool = False
    shed: bool = False  # rejected by router admission control

    @property
    def slo_violated(self) -> bool:
        if self.timed_out or self.oom_killed or self.shed:
            return True
        return (self.finish_t - self.arrival_t) > self.slo_s + 1e-9

    @property
    def wasted_vcpus(self) -> float:
        return max(self.alloc_vcpus - self.used_vcpus, 0.0)

    @property
    def wasted_mem_mb(self) -> float:
        return max(self.alloc_mem_mb - self.used_mem_mb, 0.0)


class Policy:
    """Interface each resource-management system implements."""

    name = "base"
    uses_shabari_scheduler = True

    def allocate(self, arrival: Arrival, meta: Dict, sim: "Simulator"):
        raise NotImplementedError

    def allocate_with_aux(self, arrival: Arrival, meta: Dict,
                          sim: "Simulator", aux=None):
        """``allocate`` plus an opaque per-invocation cache. The
        simulator threads ``aux`` through the retry payload alongside
        the cached Allocation, so any path that re-enters allocation
        (``SimConfig.legacy_retry_alloc``) reuses the first attempt's
        featurized input + input size instead of re-running the
        Featurizer every 0.5 s retry."""
        return self.allocate(arrival, meta, sim), aux

    def begin_arrival_batch(self, items: List[Tuple[Arrival, Dict]],
                            sim: "Simulator") -> None:
        """Hook: all same-timestamp arrivals that need a first
        allocation, in event order. Learning policies prefetch them as
        one fused microbatched prediction (the agent arena); the
        default is a no-op and each arrival allocates individually."""
        pass

    def feedback(self, arrival: Arrival, meta: Dict, result: InvocationResult,
                 sim: "Simulator") -> None:
        pass

    def forget(self, arrival: Arrival) -> None:
        """Drop any per-invocation state cached by ``allocate``. Called
        instead of ``feedback`` when the invocation times out in the
        queue and will never run — without it, per-invocation caches
        (e.g. feature vectors) leak for the run's lifetime."""
        pass


@dataclasses.dataclass(slots=True)
class _Running:
    result: InvocationResult
    container: Container
    worker: Worker
    demand_vcpus: float
    net_gbps: float
    arrival: Optional[Arrival] = None
    meta: Optional[Dict] = None
    # uncontended exec seconds sampled at start — fed to the router's
    # estimator calibration (Router.observe_exec) at finish
    base_exec: float = 0.0
    # the invocation's feature vector + input MB (from the policy's aux
    # cache), carried to finish so calibration trains the per-input
    # regressor on the SAME vector the allocation saw
    features: Optional[object] = None
    input_mb: Optional[float] = None
    # dynamic-contention bookkeeping: seconds of uncontended work left,
    # the slowdown currently applied, when it was last re-evaluated, and
    # a generation counter that invalidates superseded finish events.
    base_remaining: float = 0.0
    slow: float = 1.0
    last_t: float = 0.0
    gen: int = 0


class Simulator:
    def __init__(
        self,
        *,
        policy: Policy,
        profiles: Dict[str, FunctionProfile],
        input_pool: Dict[str, List[Dict]],
        slo_table: Dict[Tuple[str, int], float],
        cfg: Optional[SimConfig] = None,
    ):
        self.cfg = cfg or SimConfig()
        self.policy = policy
        self.profiles = profiles
        self.input_pool = input_pool
        self.slo_table = slo_table
        self.rng = np.random.default_rng(self.cfg.seed)
        # resolve the fleet: an explicit FleetSpec wins; otherwise build
        # the uniform fleet the scalar flags describe, so every layer
        # below reads hardware from Worker.machine either way
        if self.cfg.fleet is not None:
            self.fleet = self.cfg.fleet
        else:
            self.fleet = FleetSpec.uniform(
                self.cfg.n_clusters, self.cfg.n_workers,
                MachineType(
                    physical_cores=self.cfg.physical_cores,
                    vcpus=self.cfg.vcpus_per_worker,
                    mem_mb=self.cfg.mem_mb_per_worker,
                    nic_gbps=NIC_GBPS,
                    cold_base_s=self.cfg.cold_base_s,
                    cold_per_gb_s=self.cfg.cold_per_gb_s,
                    vcpu_limit=self.cfg.vcpu_limit,
                ),
            )
        # transfer charging is skipped entirely on free topologies (the
        # default): no per-arrival home-cluster hash, no extra events —
        # the event stream is bit-identical to pre-fleet behavior
        self._charge_transfer = not self.fleet.topology.is_free()
        self.clusters = [
            Cluster(
                legacy_scans=self.cfg.legacy_scans,
                machines=spec.worker_machines(),
            )
            for spec in self.fleet.clusters
        ]
        # worker ids become globally unique across clusters: the
        # simulator keys per-worker state (_worker_running) by wid.
        # Schedulers index workers by list position, so single-cluster
        # behavior is unchanged (wid == position for cluster 0).
        n_total_workers = 0
        for cl in self.clusters:
            for w in cl.workers:
                w.wid = n_total_workers
                n_total_workers += 1
        from repro.core.router import Router
        from repro.core.scheduler import ShabariScheduler

        # locality-aware cold starts: resolve the image catalog and
        # attach one NodeImageCache per worker. The None default does
        # NOTHING here — one boolean, no cache objects, no per-arrival
        # work — so the disabled path stays byte-identical (goldens)
        # and full-speed (sim_bench scale tier).
        ic = self.cfg.image_cache
        self._image_cache_active = ic is not None
        self._images = None
        image_resolver = None
        if self._image_cache_active:
            if ic.images is not None:
                self._images = dict(ic.images)
            elif self.fleet.images:
                self._images = dict(self.fleet.images)
            else:
                self._images = default_images(sorted(self.profiles))
            pinned: Tuple[str, ...] = ()
            if ic.pin_base and self._images:
                # pin the universal base: layers present in EVERY image
                digsets = [set(im.digests) for im in self._images.values()]
                pinned = tuple(sorted(set.intersection(*digsets)))
            for cl in self.clusters:
                for w in cl.workers:
                    w.image_cache = NodeImageCache(
                        w.machine.image_store_mb,
                        w.machine.registry_gbps, pinned=pinned)
            if ic.affinity:
                # scheduler ranks cold placement by residual pull and
                # the router prices it; affinity=False leaves both
                # cache-blind while the runtime still charges pulls
                image_resolver = self._images.__getitem__
        placement = getattr(policy, "placement", "hashing")
        shabari_sched = getattr(policy, "uses_shabari_scheduler", True)
        self.schedulers = [
            ShabariScheduler(
                cl, placement=placement,
                keep_alive_s=self.cfg.keep_alive_s,
                route_larger=shabari_sched, background_launch=shabari_sched,
                image_resolver=image_resolver,
            )
            for cl in self.clusters
        ]
        self.router = Router(
            self.clusters, self.schedulers,
            routing=self.cfg.routing, seed=self.cfg.seed,
            admission=self.cfg.admission,
            admission_headroom=self.cfg.admission_headroom,
            estimate_features=self.cfg.estimate_features,
            estimate_horizon_s=self.cfg.estimate_horizon_s,
            sched_overhead_s=self.cfg.sched_overhead_s,
            # the router forecasts from the SAME per-worker MachineType
            # (cold curve, cores, NIC, exec factor) and Topology this
            # simulator charges — the §5 constants have one source now
            topology=self.fleet.topology,
            price_transfer=self.cfg.estimate_transfer,
            # clone aliases (fn::k) share estimator state: calibration
            # is keyed by base function, so cold-storm's clones learn
            # one model instead of each relearning from scratch
            pool_key=base_function,
            network_fed=lambda fn: base_function(fn) in NETWORK_FED,
            image_resolver=image_resolver,
        )
        # single-cluster aliases (the common case, and what most tests
        # and benchmarks reach for)
        self.cluster = self.clusters[0]
        self.scheduler = self.schedulers[0]
        self.store = MetadataStore()
        self.daemon = WorkerDaemon(self.store)
        self.results: List[InvocationResult] = []
        self.container_sizes: Dict[str, set] = {}
        self._events: List[Tuple[float, int, str, object]] = []
        self._seq = itertools.count()
        self._running: Dict[int, _Running] = {}
        # per-worker index of running invocations (dynamic-mode retiming
        # touches only the affected worker's co-runners)
        self._worker_running: List[Dict[int, _Running]] = [
            {} for _ in range(n_total_workers)
        ]
        self.dynamic = self.cfg.contention_mode == "dynamic"
        assert self.cfg.contention_mode in ("snapshot", "dynamic")
        self.events_processed = 0
        self.now = 0.0
        # array-backed loop state: the calendar queue replaces the
        # global heap while _run_fast is active (None = legacy heap);
        # the slim daemon path replays synth_trace's exact rng draws
        # without materializing utilization samples nobody reads
        self._queue: Optional[CalendarQueue] = None
        self._retry_q: Optional[deque] = None
        self._slim_daemon = not self.cfg.legacy_event_loop
        self._rng_advance = isinstance(self.rng.bit_generator,
                                       np.random.PCG64)
        self._zero_feat = np.zeros(1, np.float32)
        self._run_pool: List[_Running] = []
        # function chains (repro.serving.chains): None stays a single
        # is-None check on the hot paths — no runtime, no hooks' work
        self._chains = None
        self._chain_iid = None
        self._chain_alloc: Dict[str, Tuple[int, int]] = {}
        if self.cfg.chains:
            from repro.serving.chains import ChainRuntime
            self._chains = ChainRuntime(
                self.cfg.chains, self.input_pool,
                slack=self.cfg.chain_slack)

    # ------------------------------------------------------------ events
    def _push(self, t: float, kind: str, payload) -> None:
        ev = (t, next(self._seq), kind, payload)
        q = self._queue
        if q is not None:
            if kind == "arrival":
                # retry lane: every arrival re-push is scheduled at
                # now + retry_interval_s with now non-decreasing and
                # seq strictly increasing, so append order IS (t, seq)
                # order — a deque replaces a heap for the storm-hot
                # event class (_run_fast merges it back in)
                self._retry_q.append(ev)
            else:
                q.push(ev)
        else:
            heapq.heappush(self._events, ev)

    # ------------------------------------------------------------ helpers
    def cold_latency(self, vcpus: int, mem_mb: int,
                     machine: Optional[MachineType] = None) -> float:
        """Container-create latency on ``machine`` (the target worker's
        hardware; default-fleet machines mirror the SimConfig curve)."""
        m = machine if machine is not None else self.fleet.clusters[0].machines[0][0]
        jitter = float(self.rng.lognormal(0.0, COLD_JITTER_SIGMA))
        return m.cold_latency_s(mem_mb) * jitter

    def _cold_latency_at(self, w: Worker, function: str,
                         vcpus: int, mem_mb: int) -> float:
        """Cold latency for creating ``function``'s container on worker
        ``w``: the classic jittered create cost, overlapped with the
        registry pull of whatever image layers ``w`` is missing (the
        pull mutates the node's cache — this is the charging path, not
        a probe). With ``image_cache=None`` this is exactly the classic
        draw: same rng stream, no cache work."""
        lat = self.cold_latency(vcpus, mem_mb, w.machine)
        if self._image_cache_active:
            lat = max(lat, w.image_cache.pull(self._images[function]))
        return lat

    def _contention(self, w: Worker, fn: str, extra_demand: float,
                    extra_net: float) -> float:
        if self.cfg.legacy_scans:
            # pre-refactor loop, kept for A/B benchmarking (sim_bench)
            demand = extra_demand + sum(
                r.demand_vcpus for r in self._running.values() if r.worker is w
            )
            net = extra_net + sum(
                r.net_gbps for r in self._running.values() if r.worker is w
            )
        else:
            soa, i = w.soa, w.sidx
            demand = extra_demand + float(soa.active_demand_vcpus[i])
            net = extra_net + float(soa.active_net_gbps[i])
        cpu_slow = max(1.0, demand / w.machine.physical_cores)
        net_slow = (max(1.0, net / w.machine.nic_gbps)
                    if base_function(fn) in NETWORK_FED else 1.0)
        return max(cpu_slow, net_slow)

    def _net_demand(self, fn: str, meta: Dict, exec_s: float,
                    nic_gbps: float = NIC_GBPS) -> float:
        if base_function(fn) not in NETWORK_FED or exec_s <= 0:
            return 0.0
        bits = input_size_mb(fn, meta) * 8e6
        return min(bits / 1e9 / max(exec_s, 0.1), nic_gbps)

    def _aux_features(self, aux) -> Tuple[Optional[object], Optional[float]]:
        """The (feature vector, input MB) pair a policy caches in its
        retry aux (ShabariPolicy and subclasses; the static/offline
        baselines cache nothing) — the per-input signal threaded into
        Router.route/observe_exec. Returns (None, None) when the policy
        caches no features or SimConfig(estimate_features=False) turned
        the per-input estimator off."""
        if (self.cfg.estimate_features and isinstance(aux, tuple)
                and len(aux) == 2 and isinstance(aux[0], np.ndarray)):
            return aux[0], float(aux[1])
        return None, None

    # ------------------------------------------------------------ handlers
    def _record_terminal(self, arrival: Arrival, alloc, first_seen: float,
                         *, timed_out: bool = False,
                         shed: bool = False) -> None:
        """Record an invocation that will never run (queue timeout,
        front-door shed, cancelled cold start) and drop the policy's
        per-invocation state."""
        now = self.now
        res = InvocationResult(
            invocation_id=arrival.invocation_id, function=arrival.function,
            arrival_t=first_seen, start_t=now, finish_t=now,
            slo_s=self.slo_table[(arrival.function, arrival.input_idx)],
            alloc_vcpus=alloc.vcpus, alloc_mem_mb=alloc.mem_mb,
            queued_s=now - first_seen, timed_out=timed_out, shed=shed,
        )
        self.results.append(res)
        self.policy.forget(arrival)
        if self._chains is not None:
            # a chain stage that will never run fails its whole chain
            # (the join barriers below it can never be satisfied)
            self._chains.on_fail(arrival.invocation_id)

    def _on_arrival(self, arrival: Arrival, first_seen: float,
                    alloc=None, aux=None) -> None:
        # meta is resolved lazily: a front-door-held retry bounces off
        # the admission fast path below without ever reading its input
        now = self.now
        cfg = self.cfg
        meta = None
        if cfg.legacy_retry_alloc:
            # pre-fix retry path kept for A/B benchmarking (sim_bench):
            # re-predict on every retry, even when about to time out.
            # The featurized input + input size ride the retry payload
            # (aux), so only the PREDICT re-runs — not the Featurizer.
            meta = self.input_pool[arrival.function][arrival.input_idx]
            alloc, aux = self.policy.allocate_with_aux(
                arrival, meta, self, aux)
        if now - first_seen > cfg.queue_timeout_s:
            # the cached allocation from the first attempt is reported;
            # a timed-out invocation never touches the policy again
            if alloc is None:  # only reachable with queue_timeout_s <= 0
                meta = self.input_pool[arrival.function][arrival.input_idx]
                alloc, aux = self.policy.allocate_with_aux(
                    arrival, meta, self, aux)
            self._record_terminal(arrival, alloc, first_seen, timed_out=True)
            return
        if alloc is None:
            meta = self.input_pool[arrival.function][arrival.input_idx]
            alloc, aux = self.policy.allocate_with_aux(arrival, meta, self, aux)
        elif self.router.try_requeue():
            # retry of a front-door-held arrival while the fleet is
            # still past the queue-mode admission headroom: route()
            # would rebuild the same queued decision without touching
            # any scheduler, so skip straight to the re-push (shared by
            # both event loops — bit-identical to the long way around;
            # _push is inlined because retry storms make this the
            # hottest line of a saturated large-fleet simulation)
            ev = (now + cfg.retry_interval_s, next(self._seq), "arrival",
                  (arrival, first_seen, alloc, aux))
            if self._queue is not None:
                self._retry_q.append(ev)  # FIFO retry lane (see _push)
            else:
                heapq.heappush(self._events, ev)
            return
        if meta is None:
            meta = self.input_pool[arrival.function][arrival.input_idx]

        # per-input ECT + SLO-native admission: the router sees the
        # invocation's cached features and its REMAINING SLO budget
        # (queueing already spent counts against it on retries)
        feats, in_mb = self._aux_features(aux)
        slo_s = self.slo_table[(arrival.function, arrival.input_idx)]
        eff_slo = slo_s - (now - first_seen)
        budget_s = None
        if self._chains is not None:
            # chain stages route against the CHAIN's budget, not the
            # flat per-invocation SLO: slack-aware mode also hands the
            # remaining end-to-end allowance to estimate routing as
            # budget_s (None for non-chain traffic / uniform mode).
            # The last-seen allocation per function sizes Fifer
            # pre-warm launches (see _chain_prewarm).
            stage = self._chains.stage_budget(arrival, now, first_seen)
            if stage is not None:
                eff_slo, budget_s = stage
            self._chain_alloc[arrival.function] = (alloc.vcpus, alloc.mem_mb)
        route = self.router.route(arrival.function, alloc, now,
                                  features=feats, input_mb=in_mb,
                                  slo_s=eff_slo, budget_s=budget_s)
        decision = route.decision
        if route.shed:
            # admission control dropped it at the front door: no retry
            self._record_terminal(arrival, alloc, first_seen, shed=True)
            return
        if decision.queued:
            # carry the allocation AND the featurization cache: retries
            # must not re-run the policy or the Featurizer (front-door
            # admission queueing lands here too)
            self._push(now + self.cfg.retry_interval_s, "arrival",
                       (arrival, first_seen, alloc, aux))
            return

        # input-payload transfer (repro/core/fleet.py): the payload
        # lives in the function's HOME cluster's object store, so a
        # remote placement first moves it over the inter-cluster link.
        # The wait lands in queued_s. Free topologies (every default
        # fleet) skip this entirely — no per-arrival home hash, no
        # extra events — so pre-fleet event streams are bit-identical.
        xfer = 0.0
        if self._charge_transfer:
            xfer = self.fleet.topology.transfer_s(
                self.router.home_cluster(arrival.function),
                route.cluster_idx,
                input_size_mb(arrival.function, meta))

        if decision.pending is not None:
            # estimate routing bound this invocation to a still-warming
            # uncommitted container (a §5 case-2 background launch):
            # commit it — mark busy so no other arrival can take it,
            # reserve its capacity (acquire-on-placement, same as a
            # fresh cold start), and start when it turns warm. The
            # invocation pays only the residual warm-up (and, remotely,
            # whatever of the payload transfer the warm-up doesn't hide).
            c = decision.pending
            c.worker.cluster.mark_busy(c)
            if not self.cfg.legacy_acquire:
                c.worker.reserve(c.vcpus, c.mem_mb)
                c.reserved = True
            self._push(max(c.warm_at, now + xfer), "warm_start",
                       (arrival, meta, alloc, c, c.warm_at - now, first_seen,
                        aux))
            return

        cluster = self.clusters[route.cluster_idx]
        if decision.background_launch and decision.container is not None:
            # case 2: larger warm container used; exact size in background
            w, v, m = decision.background_launch
            c = cluster.new_container(
                w, arrival.function, v, m, now,
                warm_at=now + self._cold_latency_at(w, arrival.function, v, m),
            )
            self._note_size(arrival.function, v, m)

        if decision.container is not None:
            c = decision.container
            if xfer > 0.0:
                # warm container on a remote cluster: hold it while the
                # payload crosses the link, then start
                cluster.mark_busy(c)
                c.last_used = now
                self._push(now + xfer, "xfer_start",
                           (arrival, meta, alloc, c, first_seen, aux))
            else:
                self._start(arrival, meta, alloc, c,
                            cold=False, first_seen=first_seen, aux=aux)
        else:
            # cold start: create the container, start when warm (the
            # payload transfer overlaps the warm-up; only the excess
            # beyond the cold latency delays the start)
            w, v, m = decision.background_launch
            lat = self._cold_latency_at(w, arrival.function, v, m)
            c = cluster.new_container(w, arrival.function, v, m, now,
                                      warm_at=now + lat)
            cluster.mark_busy(c)
            if not self.cfg.legacy_acquire:
                # acquire-on-placement: hold the capacity for the whole
                # warm-up window (converted to a running acquisition in
                # _start, released in _cancel_cold_start)
                w.reserve(v, m)
                c.reserved = True
            self._note_size(arrival.function, v, m)
            self._push(now + max(lat, xfer), "warm_start",
                       (arrival, meta, alloc, c, lat, first_seen, aux))

    def _note_size(self, fn: str, v: int, m: int) -> None:
        self.container_sizes.setdefault(fn, set()).add((v, m))

    def _cancel_cold_start(self, arrival: Arrival, alloc, c: Container,
                           first_seen: float) -> None:
        """The cold start outlived the invocation's queue timeout:
        release the reservation and record the timeout. The container
        itself survives as an idle warm container — the capacity was
        spent warming it, so future invocations may as well reuse it."""
        c.reserved = False
        c.last_used = self.now
        c.worker.cancel_reservation(c.vcpus, c.mem_mb)
        c.worker.cluster.mark_idle(c)
        self._record_terminal(arrival, alloc, first_seen, timed_out=True)

    def _start(self, arrival, meta, alloc, container: Container, *, cold: bool,
               first_seen: float, cold_latency: float = 0.0,
               aux=None) -> None:
        now = self.now
        fn = arrival.function
        prof = self.profiles[fn]
        w = container.worker
        w.cluster.mark_busy(container)
        container.last_used = now
        if container.reserved:
            # acquire-on-placement: the capacity was reserved when the
            # cold start was placed; convert it instead of re-acquiring
            container.reserved = False
            w.commit_reservation(container.vcpus, container.mem_mb)
        else:
            w.acquire(container.vcpus, container.mem_mb)

        # the invocation runs with the CONTAINER's size (may exceed
        # request). base_exec is REFERENCE-machine uncontended seconds
        # (what profiles model and what calibrates the router's
        # estimator); the worker's exec-speed factor scales it to this
        # machine's uncontended time before contention applies.
        vcpus = container.vcpus
        base_exec, demand = prof.exec_and_demand(meta, vcpus, self.rng)
        eff_exec = base_exec * w.machine.exec_factor
        net = self._net_demand(fn, meta, eff_exec, w.machine.nic_gbps)
        slow = self._contention(w, fn, demand, net)
        exec_s = eff_exec * slow

        mem_used = prof.mem_used_mb(meta)
        oom = mem_used > container.mem_mb
        if oom:
            exec_s *= 0.6  # killed partway

        res = InvocationResult(
            invocation_id=arrival.invocation_id, function=fn,
            arrival_t=first_seen, start_t=now,
            slo_s=self.slo_table[(fn, arrival.input_idx)],
            alloc_vcpus=container.vcpus, alloc_mem_mb=container.mem_mb,
            used_vcpus=min(demand, vcpus),
            used_mem_mb=min(mem_used, container.mem_mb),
            cold_start=cold, cold_latency_s=cold_latency,
            queued_s=now - first_seen - (cold_latency if cold else 0.0),
            oom_killed=oom, exec_s=exec_s,
        )
        feats, in_mb = self._aux_features(aux)
        pool = self._run_pool
        if pool:
            # recycled record (churn cut): every field re-set here
            run = pool.pop()
            run.result = res
            run.container = container
            run.worker = w
            run.demand_vcpus = demand
            run.net_gbps = net
            run.arrival = arrival
            run.meta = meta
            run.base_exec = base_exec
            run.features = feats
            run.input_mb = in_mb
            run.base_remaining = 0.0
            run.slow = 1.0
            run.last_t = 0.0
            run.gen = 0
        else:
            run = _Running(
                result=res, container=container, worker=w,
                demand_vcpus=demand, net_gbps=net, arrival=arrival, meta=meta,
                base_exec=base_exec, features=feats, input_mb=in_mb,
            )
        self._running[arrival.invocation_id] = run
        self._worker_running[w.wid][arrival.invocation_id] = run
        w.add_active(demand, net)
        if self.dynamic:
            # track uncontended work (on THIS machine); the finish event
            # floats as co-runners come and go
            run.base_remaining = eff_exec * (0.6 if oom else 1.0)
            run.slow = slow
            run.last_t = now
            self._push(now + run.base_remaining * slow, "finish",
                       (arrival, meta, run.gen))
            self._retime_worker(w, exclude=arrival.invocation_id)
        else:
            self._push(now + exec_s, "finish", (arrival, meta, 0))
        if self._chains is not None:
            self._chain_prewarm(arrival.invocation_id)

    def _chain_prewarm(self, iid: int) -> None:
        """Fifer-style proactive scaling: a chain stage just STARTED, so
        its children's arrivals are now forecastable. For each child
        function whose running-parent count exceeds its idle
        warm+warming supply on its home cluster, launch ONE uncommitted
        warming container (exactly like a case-2 background launch: it
        enters ``idle_by_function`` with a future ``warm_at``, i.e. the
        warming-soon index estimate routing binds to), sized from the
        function's last-seen allocation. A child function never
        allocated yet is skipped — sizing it would mean running the
        policy out-of-band and perturbing its learning state."""
        counts = self._chains.note_start(iid)
        if not self.cfg.chain_prewarm:
            return
        for child_fn, inflight in counts:
            size = self._chain_alloc.get(child_fn)
            if size is None:
                continue
            ci = self.router.home_cluster(child_fn)
            cl = self.clusters[ci]
            supply = len(cl.idle_by_function.get(child_fn, ()))
            if supply >= inflight:
                continue
            v, m = size
            w = self.schedulers[ci].cold_candidate(child_fn, v, m)
            if w is None:
                continue
            cl.new_container(
                w, child_fn, v, m, self.now,
                warm_at=self.now + self._cold_latency_at(w, child_fn, v, m))
            self._note_size(child_fn, v, m)

    def _retime_worker(self, w: Worker, exclude: int = -1) -> None:
        """Dynamic mode: a co-runner started/finished on ``w`` — advance
        each running invocation's progress under its old slowdown, apply
        the new one, and re-queue its finish (the generation counter
        voids the stale event)."""
        now = self.now
        for iid, r in self._worker_running[w.wid].items():
            if iid == exclude:
                continue
            r.base_remaining = max(
                r.base_remaining - (now - r.last_t) / r.slow, 0.0)
            r.slow = self._contention(w, r.result.function, 0.0, 0.0)
            r.last_t = now
            r.gen += 1
            self._push(now + r.base_remaining * r.slow, "finish",
                       (r.arrival, r.meta, r.gen))

    def _on_finish(self, arrival: Arrival, meta: Dict, gen: int) -> None:
        now = self.now
        run = self._running.get(arrival.invocation_id)
        if run is None or gen != run.gen:
            return  # superseded by a dynamic-contention retime
        del self._running[arrival.invocation_id]
        res, c, w = run.result, run.container, run.worker
        del self._worker_running[w.wid][arrival.invocation_id]
        w.remove_active(run.demand_vcpus, run.net_gbps)
        res.finish_t = now
        if self.dynamic:
            res.exec_s = now - res.start_t
        w.release(c.vcpus, c.mem_mb)
        c.last_used = now
        w.cluster.mark_idle(c)
        self.results.append(res)

        if self._slim_daemon:
            # Array-backed loop's daemon path: nothing downstream reads
            # the UtilizationTrace SAMPLES — only its maxima, which
            # synth_trace forces to exactly (used_vcpus, used_mem_mb)
            # via the argmax write. So draw the identical rng stream
            # (two random(n) batches, same n) to keep the shared
            # generator bit-aligned with the legacy path, and build the
            # Observation/record directly with the interned zero
            # feature vector instead of allocating one per completion.
            n_smp = max(int(res.exec_s / SAMPLE_INTERVAL_S), 4)
            n_smp = min(n_smp, 4096)
            if self._rng_advance:
                # PCG64's random(n) consumes exactly n raw uint64s, so
                # jumping the state 2*n forward is bit-identical to the
                # two jitter batches synth_trace would have drawn —
                # O(log n) instead of generating values nothing reads
                self.rng.bit_generator.advance(2 * n_smp)
            else:
                self.rng.random(n_smp)
                self.rng.random(n_smp)
            obs = Observation(
                exec_time_s=now - res.arrival_t,  # end-to-end vs SLO
                slo_s=res.slo_s,
                alloc_vcpus=res.alloc_vcpus,
                max_vcpus_used=res.used_vcpus,
                alloc_mem_mb=res.alloc_mem_mb,
                max_mem_used_mb=res.used_mem_mb,
                cold_start=res.cold_start,
                oom_killed=res.oom_killed,
            )
            self.store.push(InvocationRecord(
                function=res.function, invocation_id=res.invocation_id,
                features=self._zero_feat, observation=obs,
                finish_time=now,
            ))
        else:
            trace = synth_trace(res.used_vcpus, res.used_mem_mb, res.exec_s,
                                self.rng)
            obs = self.daemon.report_completion(
                function=res.function, invocation_id=res.invocation_id,
                features=np.zeros(1, np.float32),  # policy recomputes if needed
                exec_time_s=now - res.arrival_t,  # end-to-end vs SLO
                slo_s=res.slo_s, alloc_vcpus=res.alloc_vcpus,
                alloc_mem_mb=res.alloc_mem_mb, trace=trace,
                finish_time=now, cold_start=res.cold_start,
                oom_killed=res.oom_killed,
            )
        self.policy.feedback(arrival, meta, res, self)
        # estimator calibration: report the UNCONTENDED exec time and
        # the NIC draw so estimate-mode scoring can apply each
        # candidate's own §5 slowdown without double counting (no-op
        # read path for every other routing policy, so default-mode
        # metrics are untouched). OOM kills ran only a fraction of
        # base_exec, so feeding the full figure would inflate the
        # estimator — skip them.
        if not res.oom_killed:
            self.router.observe_exec(res.function, run.base_exec,
                                     run.net_gbps,
                                     features=run.features,
                                     input_mb=run.input_mb)
        if self._chains is not None:
            ch = self._chains
            ch.note_end(arrival.invocation_id)
            if res.oom_killed:
                ch.on_fail(arrival.invocation_id)
            else:
                # spawn every stage whose LAST parent this completion
                # was: a fresh arrival at t == now, pushed as its own
                # scheduled-event kind so both event loops route it
                # through the calendar/heap (the fast loop's retry
                # deque is arrivals-at-now+interval ONLY — a same-t
                # arrival push would break its ordering invariant)
                for inst, stage, fn_c, idx_c in ch.on_complete(
                        arrival.invocation_id, now):
                    child = Arrival(next(self._chain_iid), now, fn_c, idx_c)
                    ch.bind(inst, stage, child.invocation_id, now)
                    self._push(now, "chain_arrival", child)
        if self.dynamic:
            self._retime_worker(w)  # departures speed co-runners up
        # recycle the bookkeeping record (the result object lives on in
        # self.results; only references are cleared, nothing is mutated)
        run.result = None
        run.container = None
        run.worker = None
        run.arrival = None
        run.meta = None
        run.features = None
        self._run_pool.append(run)

    # ------------------------------------------------------------ run
    def run(self, arrivals: List[Arrival]) -> List[InvocationResult]:
        if self._chains is not None:
            # spawned stage invocations get ids above the trace's
            # 0..n-1 block — unique, deterministic, loop-independent
            self._chain_iid = itertools.count(len(arrivals))
        if self.cfg.legacy_event_loop:
            return self._run_legacy(arrivals)
        return self._run_fast(arrivals)

    def chain_summary(self) -> Optional[Dict[str, float]]:
        """End-to-end chain metrics, None when ``cfg.chains`` is off."""
        return None if self._chains is None else self._chains.summary()

    def _process_arrival_cohort(self, t: float, payloads: list) -> None:
        """Handle one same-timestamp arrival cohort in event order —
        shared by both loops. Microbatching every CONSECUTIVE same-
        timestamp arrival is bit-identical to processing them one by
        one: nothing can be interleaved between them (an intervening
        finish/warm_start would break the cohort), and pending agent
        updates flush before any prediction for the same function."""
        if len(payloads) > 1 and not self.cfg.legacy_retry_alloc:
            fresh = [
                (a, self.input_pool[a.function][a.input_idx])
                for a, fs, alloc, _ in payloads
                if alloc is None
                and t - fs <= self.cfg.queue_timeout_s
            ]
            if len(fresh) > 1:
                self.policy.begin_arrival_batch(fresh, self)
        for arrival, first_seen, alloc, aux in payloads:
            self._on_arrival(arrival, first_seen, alloc, aux)

    def _handle_scheduled(self, t: float, kind: str, payload) -> None:
        """Dispatch one non-arrival, non-reap event (both loops)."""
        if kind == "warm_start":
            arrival, meta, alloc, c, lat, first_seen, aux = payload
            if c.reserved and t - first_seen > self.cfg.queue_timeout_s:
                # reservation outlived the queue timeout (only
                # possible when cold latency > remaining budget)
                self._cancel_cold_start(arrival, alloc, c, first_seen)
            else:
                # container finished cold-starting; run the
                # invocation (_start re-marks busy + commits the
                # reservation / acquires load)
                c.busy = False
                self._start(arrival, meta, alloc, c, cold=True,
                            first_seen=first_seen, cold_latency=lat,
                            aux=aux)
        elif kind == "xfer_start":
            # remote warm placement: the input payload finished
            # crossing the inter-cluster link; run on the warm
            # container that was held for it (_start re-marks busy)
            arrival, meta, alloc, c, first_seen, aux = payload
            c.busy = False
            self._start(arrival, meta, alloc, c, cold=False,
                        first_seen=first_seen, aux=aux)
        elif kind == "chain_arrival":
            # downstream chain stage spawned by an upstream completion
            # (repro.serving.chains): a fresh arrival first seen NOW —
            # it allocates, routes against the chain budget, and
            # retries like any other arrival from here on
            self._on_arrival(payload, t, None, None)
        else:  # finish
            arrival, meta, gen = payload
            self._on_finish(arrival, meta, gen)

    def _run_legacy(self, arrivals: List[Arrival]) -> List[InvocationResult]:
        """Pre-refactor hot loop (``legacy_event_loop=True``): one
        global heapq with every arrival pre-pushed."""
        for a in arrivals:
            self._push(a.t, "arrival", (a, a.t, None, None))
        reap_t = 60.0
        self._push(reap_t, "reap", None)
        while self._events:
            t, _, kind, payload = heapq.heappop(self._events)
            self.now = t
            self.events_processed += 1
            if kind == "arrival":
                payloads = [payload]
                while (self._events and self._events[0][0] == t
                       and self._events[0][2] == "arrival"):
                    payloads.append(heapq.heappop(self._events)[3])
                self.events_processed += len(payloads) - 1
                self._process_arrival_cohort(t, payloads)
            elif kind == "reap":
                for sched in self.schedulers:
                    sched.reap_idle(self.now)
                if self._events:
                    self._push(self.now + 60.0, "reap", None)
            else:
                self._handle_scheduled(t, kind, payload)
        return self.results

    def _run_fast(self, arrivals: List[Arrival]) -> List[InvocationResult]:
        """Array-backed hot loop (the default). The trace's arrivals
        never enter a priority queue: a stable argsort over their
        timestamps IS their pop order (ties keep list order, exactly
        the ``(t, seq)`` order the legacy heap gave them, since legacy
        seqs were assigned in list order). Scheduled events (finish /
        warm_start / xfer_start / reap) go through a bucketed
        :class:`CalendarQueue` whose pop order matches a global heap.
        Retries get a THIRD lane, a plain deque: every arrival re-push
        is scheduled at ``now + retry_interval_s`` with ``now``
        non-decreasing and seq strictly increasing, so append order is
        already ``(t, seq)`` order and no heap is needed for the event
        class that dominates a saturated run. The three streams merge
        on ``(t, seq)``: virtual arrival seqs are their list indices
        (all < n), and ``self._seq`` starts at n, so every scheduled
        event sorts after every same-timestamp fresh arrival — as it
        did under the single heap."""
        n = len(arrivals)
        self._seq = itertools.count(n)  # seqs 0..n-1 belong to arrivals
        self._queue = q = CalendarQueue()
        self._retry_q = rq = deque()
        try:
            if n:
                order = np.argsort(
                    np.array([a.t for a in arrivals], dtype=np.float64),
                    kind="stable",
                ).tolist()
            else:
                order = []
            self._push(60.0, "reap", None)  # seq n, as under the heap
            ai = 0
            while ai < n or q or rq:
                head = q.peek()
                # effective scheduled head = min over both lanes
                head_is_retry = False
                if rq:
                    r = rq[0]
                    if head is None or r[0] < head[0] or (
                            r[0] == head[0] and r[1] < head[1]):
                        head = r
                        head_is_retry = True
                if ai < n:
                    oi = order[ai]
                    a = arrivals[oi]
                    # oi < n <= any queued seq: fresh arrival wins ties
                    if head is None or a.t < head[0] or (
                            a.t == head[0] and oi < head[1]):
                        t = a.t
                        self.now = t
                        ai += 1
                        payloads = [(a, t, None, None)]
                        while ai < n:
                            b = arrivals[order[ai]]
                            if b.t != t:
                                break
                            payloads.append((b, t, None, None))
                            ai += 1
                        # retries at the same t (their seqs all exceed
                        # every fresh arrival's) extend the cohort
                        # while they are the globally next events — a
                        # calendar event at the same t with a smaller
                        # seq breaks the consecutive run, exactly as it
                        # broke the run the heap popped
                        if rq and rq[0][0] == t:
                            ch = q.peek()
                            while rq:
                                r = rq[0]
                                if r[0] != t or (ch is not None
                                                 and ch[0] == t
                                                 and ch[1] < r[1]):
                                    break
                                payloads.append(r[3])
                                rq.popleft()
                        self.events_processed += len(payloads)
                        self._process_arrival_cohort(t, payloads)
                        continue
                if head_is_retry:
                    t, _, _k, payload = rq.popleft()
                    self.now = t
                    self.events_processed += 1
                    nxt = rq[0] if rq else None
                    if nxt is None or nxt[0] != t:
                        # lone retry — the common case in a retry storm
                        # (retry timestamps inherit their arrival's
                        # fractional offset, so they rarely collide);
                        # identical to a single-payload cohort, minus
                        # the list build
                        a, fs, al, ax = payload
                        self._on_arrival(a, fs, al, ax)
                    else:
                        # retry-only cohort: drain same-t retries while
                        # no same-t calendar event with a smaller seq
                        # intervenes (heap-run parity, as above)
                        ch = q.peek()
                        payloads = [payload]
                        while rq:
                            r = rq[0]
                            if r[0] != t or (ch is not None
                                             and ch[0] == t
                                             and ch[1] < r[1]):
                                break
                            payloads.append(r[3])
                            rq.popleft()
                        self.events_processed += len(payloads) - 1
                        self._process_arrival_cohort(t, payloads)
                    continue
                t, _, kind, payload = q.pop()
                self.now = t
                self.events_processed += 1
                if kind == "reap":
                    for sched in self.schedulers:
                        sched.reap_idle(t)
                    if ai < n or q or rq:
                        self._push(t + 60.0, "reap", None)
                else:
                    self._handle_scheduled(t, kind, payload)
        finally:
            self._queue = None
            self._retry_q = None
        return self.results


# ---------------------------------------------------------------------------
# Metrics (the paper's three evaluation axes, §7.1)
# ---------------------------------------------------------------------------


def summarize(results: List[InvocationResult]) -> Dict[str, float]:
    if not results:
        return {}
    viol = [r for r in results if r.slo_violated]
    # waste/utilization are resource-consumption metrics: shed and
    # timed-out invocations never ran (used_*=0 with a real alloc_*
    # from _record_terminal), so including them reports phantom waste
    # for work that never consumed a cycle. They still count in the
    # SLO/shed/timeout rates below.
    ran = [r for r in results if not (r.shed or r.timed_out)]
    wasted_v = np.array([r.wasted_vcpus for r in ran])
    wasted_m = np.array([r.wasted_mem_mb for r in ran])
    util_v = np.array([
        r.used_vcpus / r.alloc_vcpus for r in ran if r.alloc_vcpus
    ])
    util_m = np.array([
        r.used_mem_mb / r.alloc_mem_mb for r in ran if r.alloc_mem_mb
    ])
    colds = [r for r in results if r.cold_start]
    return {
        "n": len(results),
        "slo_violation_pct": 100.0 * len(viol) / len(results),
        "wasted_vcpus_p50": float(np.percentile(wasted_v, 50)) if wasted_v.size else 0.0,
        "wasted_vcpus_p95": float(np.percentile(wasted_v, 95)) if wasted_v.size else 0.0,
        "wasted_mem_mb_p50": float(np.percentile(wasted_m, 50)) if wasted_m.size else 0.0,
        "wasted_mem_mb_p75": float(np.percentile(wasted_m, 75)) if wasted_m.size else 0.0,
        "wasted_mem_mb_p95": float(np.percentile(wasted_m, 95)) if wasted_m.size else 0.0,
        "cpu_util_p50": float(np.percentile(util_v, 50)) if util_v.size else 0.0,
        "mem_util_p50": float(np.percentile(util_m, 50)) if util_m.size else 0.0,
        "cold_start_pct": 100.0 * len(colds) / len(results),
        "cold_viol_pct": (
            100.0 * len([r for r in viol if r.cold_start]) / max(len(viol), 1)
        ),
        "oom_pct": 100.0 * len([r for r in results if r.oom_killed]) / len(results),
        "timeout_pct": 100.0 * len([r for r in results if r.timed_out]) / len(results),
        "shed_pct": 100.0 * len([r for r in results if r.shed]) / len(results),
    }
