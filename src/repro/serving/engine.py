"""Real JAX serving engine: batched prefill + greedy decode.

This is the execution layer the examples drive on CPU with reduced
configs (on TPU it is the per-slice executable Shabari's "containers"
wrap). Requests are token prompts; the engine pads them into a batch,
prefills the ring cache, then decodes step by step with the same
``forward_decode`` the dry-run lowers for the decode shapes.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models.model import forward_decode, forward_prefill, init_params


@dataclasses.dataclass
class GenerationResult:
    tokens: List[List[int]]
    prefill_s: float
    decode_s: float
    tokens_per_s: float


class ServingEngine:
    def __init__(self, cfg: ModelConfig, params=None, *, cache_window: int = 256,
                 seed: int = 0, use_pallas: bool = False):
        self.cfg = cfg
        self.params = params if params is not None else init_params(
            jax.random.PRNGKey(seed), cfg)
        self.cache_window = cache_window
        self.use_pallas = use_pallas

        def _prefill(params, tokens, **kw):
            return forward_prefill(params, cfg, tokens,
                                   cache_window=cache_window,
                                   use_pallas=use_pallas, **kw)

        def _decode(params, token, cache):
            return forward_decode(params, cfg, token, cache,
                                  use_pallas=use_pallas)

        self._prefill = jax.jit(_prefill)
        self._decode = jax.jit(_decode)

    def _pad_batch(self, prompts: Sequence[Sequence[int]]) -> Tuple[jnp.ndarray, np.ndarray]:
        # left-pad to align last positions (prefill logits are last-token)
        L = max(len(p) for p in prompts)
        if self.cfg.family in ("ssm", "hybrid"):
            L = int(np.ceil(L / self.cfg.ssm_chunk) * self.cfg.ssm_chunk)
        arr = np.zeros((len(prompts), L), np.int32)
        lens = np.array([len(p) for p in prompts])
        for i, p in enumerate(prompts):
            arr[i, L - len(p):] = np.asarray(p, np.int32)
        return jnp.asarray(arr), lens

    def generate(self, prompts: Sequence[Sequence[int]], *,
                 max_new_tokens: int = 32,
                 frame_embeds=None, patch_embeds=None) -> GenerationResult:
        cfg = self.cfg
        tokens, _ = self._pad_batch(prompts)
        kw = {}
        if cfg.is_encoder_decoder:
            B = tokens.shape[0]
            kw["frame_embeds"] = (
                frame_embeds if frame_embeds is not None
                else jnp.zeros((B, cfg.encoder_seq, cfg.d_model), cfg.dtype))
        if cfg.family == "vlm":
            B = tokens.shape[0]
            kw["patch_embeds"] = (
                patch_embeds if patch_embeds is not None
                else jnp.zeros((B, cfg.frontend_tokens, cfg.d_model), cfg.dtype))

        t0 = time.perf_counter()
        logits, cache = self._prefill(self.params, tokens, **kw)
        logits.block_until_ready()
        t_prefill = time.perf_counter() - t0

        out = [[] for _ in prompts]
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        t1 = time.perf_counter()
        for _ in range(max_new_tokens):
            for i, t in enumerate(np.asarray(tok)):
                out[i].append(int(t))
            logits, cache = self._decode(self.params, tok, cache)
            tok = jnp.argmax(logits, -1).astype(jnp.int32)
        jax.block_until_ready(logits)
        t_decode = time.perf_counter() - t1
        tps = len(prompts) * max_new_tokens / max(t_decode, 1e-9)
        return GenerationResult(out, t_prefill, t_decode, tps)
