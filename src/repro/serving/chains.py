"""Function chains / DAG workloads — the first structural change to
*what an invocation is* since the seed.

Production serverless traffic is dominated by multi-stage pipelines
(ML inference chains, ETL DAGs), and Shabari's delay-decisions-until-
input insight sharpens at stage boundaries: when stage N completes,
the router knows BOTH the payload stage N+1 will receive (the sum of
its parents' outputs) and the chain's remaining end-to-end budget —
neither of which exists for an independent invocation. Fifer (arXiv
2008.12819) shows what that knowledge buys: slack-aware per-stage
scheduling (a stage with slack tolerates a cold start or a queue hold;
a critical-path stage gets warm-priority placement) plus proactive
pre-warming of downstream containers from upstream admission counts.

This module supplies the spec and runtime state machine; the simulator
owns events and ids (``SimConfig.chains`` wires it in — ``None``, the
default, touches nothing):

* :class:`ChainSpec` — a DAG of named stages over the paper's 12
  profiled functions, per-edge payload sizes (MB), per-stage expected
  durations, and an end-to-end SLO expressed as ``slo_mult`` x the
  critical path;
* critical-path slack decomposition — ``stage_budget`` turns the
  remaining end-to-end budget into a per-stage allowance by reserving
  the longest expected path BELOW the stage (``chain_slack="aware"``),
  or splits the e2e SLO uniformly per stage for the slack-blind A/B
  arm (``"uniform"``, benchmarks/chain_bench);
* join barriers — a fan-in stage spawns only when its LAST parent
  completes; its input is the pool entry nearest the summed in-edge
  payloads, so exec models, NIC demand, transfer pricing, and the ECT
  regressor all see a consistent input size;
* Fifer-style pre-warm counts — ``note_start``/``note_end`` track how
  many running stage-N invocations will feed each stage-N+1 function,
  which the simulator compares against the idle warm/warming supply to
  decide proactive launches through the existing warming-soon index.

Every trace arrival of a spec's TRIGGER function (its root stage's
function) starts one chain instance; scenario generators keep trigger
functions out of their background traffic so the chain population is
exactly the trigger stream.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.serving.profiles import input_size_mb


@dataclasses.dataclass(frozen=True)
class ChainStage:
    """One DAG node: a unique stage name bound to a profiled function."""

    name: str
    function: str


@dataclasses.dataclass(frozen=True)
class ChainEdge:
    """``src`` stage's output feeds ``dst``; ``payload_mb`` is the size
    of that output (a fan-in stage's input is the sum over in-edges)."""

    src: str
    dst: str
    payload_mb: float


@dataclasses.dataclass(frozen=True)
class ChainSpec:
    """A DAG workload spec. ``expected_s`` carries author-time expected
    per-stage durations (uncontended seconds at a typical allocation) —
    they shape the slack DECOMPOSITION and the end-to-end SLO
    (``slo_mult`` x the critical path), not the simulated physics,
    which come from the real profiles as for any invocation."""

    name: str
    stages: Tuple[ChainStage, ...]
    edges: Tuple[ChainEdge, ...]
    expected_s: Tuple[Tuple[str, float], ...]
    slo_mult: float = 1.5


class _Compiled:
    """Derived DAG facts, computed once per spec."""

    __slots__ = ("spec", "root", "fn", "children", "n_parents",
                 "input_idx", "cp_after", "cp_total", "depth", "e2e_slo",
                 "n_stages")

    def __init__(self, spec: ChainSpec, input_pool: Dict[str, List[Dict]]):
        names = [s.name for s in spec.stages]
        assert len(set(names)) == len(names), f"duplicate stage in {spec.name}"
        self.spec = spec
        self.fn = {s.name: s.function for s in spec.stages}
        self.children: Dict[str, List[Tuple[str, float]]] = {
            n: [] for n in names}
        self.n_parents: Dict[str, int] = {n: 0 for n in names}
        in_mb: Dict[str, float] = {n: 0.0 for n in names}
        for e in spec.edges:
            assert e.src in self.fn and e.dst in self.fn, (spec.name, e)
            self.children[e.src].append((e.dst, e.payload_mb))
            self.n_parents[e.dst] += 1
            in_mb[e.dst] += e.payload_mb
        roots = [n for n in names if self.n_parents[n] == 0]
        assert len(roots) == 1, (
            f"chain {spec.name!r} must have exactly one root, got {roots}")
        self.root = roots[0]
        self.n_stages = len(names)

        # longest expected-duration path from each stage to a sink —
        # memoized DFS; the "in progress" sentinel catches cycles
        exp = dict(spec.expected_s)
        assert set(exp) == set(names), (
            f"chain {spec.name!r}: expected_s must cover every stage")
        cp_from: Dict[str, float] = {}
        depth_from: Dict[str, int] = {}

        def walk(n: str) -> float:
            got = cp_from.get(n)
            if got == -1.0:
                raise ValueError(f"chain {spec.name!r} has a cycle at {n!r}")
            if got is not None:
                return got
            cp_from[n] = -1.0
            best, deep = 0.0, 0
            for child, _ in self.children[n]:
                c = walk(child)
                best = max(best, c)
                deep = max(deep, depth_from[child])
            cp_from[n] = exp[n] + best
            depth_from[n] = 1 + deep
            return cp_from[n]

        self.cp_total = walk(self.root)
        assert len(cp_from) == len(names), (
            f"chain {spec.name!r}: stages unreachable from the root: "
            f"{sorted(set(names) - set(cp_from))}")
        # slack reserved BELOW each stage (the stage's own expected time
        # is part of ITS allowance, not its descendants')
        self.cp_after = {n: cp_from[n] - exp[n] for n in names}
        self.depth = depth_from[self.root]
        self.e2e_slo = spec.slo_mult * self.cp_total

        # fan-in input resolution: a spawned stage runs the pool entry
        # whose input size is nearest the summed in-edge payloads, so
        # the exec model, NIC demand, featurizer, and ECT regressor all
        # see one consistent input (deterministic: ties -> lower idx)
        self.input_idx: Dict[str, int] = {}
        for n in names:
            if n == self.root:
                continue
            pool = input_pool[self.fn[n]]
            sizes = [input_size_mb(self.fn[n], meta) for meta in pool]
            self.input_idx[n] = int(np.argmin(
                [abs(s - in_mb[n]) for s in sizes]))


@dataclasses.dataclass(slots=True)
class _Instance:
    """One live chain: join-barrier counters + stage timestamps."""

    comp: _Compiled
    root_t: float
    stage_t: Dict[str, float]
    waiting: Dict[str, int]
    done: int = 0
    failed: bool = False


class ChainRuntime:
    """The simulator-facing state machine. The simulator owns events,
    ids, and Arrival construction; this class owns instance state,
    join barriers, budgets, pre-warm counts, and end-to-end stats."""

    def __init__(self, specs, input_pool: Dict[str, List[Dict]],
                 *, slack: str = "aware"):
        assert slack in ("aware", "uniform"), slack
        self.slack = slack
        self._compiled: Dict[str, _Compiled] = {}
        for spec in specs:
            comp = _Compiled(spec, input_pool)
            trig = comp.fn[comp.root]
            assert trig not in self._compiled, (
                f"two chains share trigger function {trig!r}")
            self._compiled[trig] = comp
        self._by_iid: Dict[int, Tuple[_Instance, str]] = {}
        # Fifer pre-warm signal: running parent invocations per child
        # FUNCTION (stage-N admissions that will fan into stage N+1)
        self._inflight: Dict[str, int] = {}
        self.started = 0
        self.completed = 0
        self.failed = 0
        self.late = 0
        self.stage_spawned = 0
        self._e2e: List[float] = []

    def triggers(self) -> List[str]:
        return sorted(self._compiled)

    # ----------------------------------------------------------- budgets
    def stage_budget(self, arrival, now: float, first_seen: float
                     ) -> Optional[Tuple[float, Optional[float]]]:
        """Per-stage SLO allowance for a (possibly retried) arrival, as
        ``(slo_s, budget_s)`` — ``slo_s`` feeds admission, ``budget_s``
        feeds slack-aware estimate routing (None = slack-blind).
        Returns None for non-chain traffic. First sight of a trigger
        -function arrival registers a new chain instance (idempotent
        across retries: the id stays mapped).

        * ``aware``: remaining e2e budget minus the longest expected
          path below this stage — a critical-path stage gets exactly
          what the chain can still afford, an off-path stage inherits
          the join's slack;
        * ``uniform``: the slack-blind baseline — e2e SLO split evenly
          over the critical path's depth, measured from the STAGE's own
          arrival, with no routing budget."""
        ent = self._by_iid.get(arrival.invocation_id)
        if ent is None:
            comp = self._compiled.get(arrival.function)
            if comp is None:
                return None
            inst = _Instance(comp=comp, root_t=first_seen,
                             stage_t={comp.root: first_seen},
                             waiting=dict(comp.n_parents))
            self._by_iid[arrival.invocation_id] = ent = (inst, comp.root)
            self.started += 1
        inst, stage = ent
        comp = inst.comp
        if self.slack == "aware":
            b = comp.e2e_slo - (now - inst.root_t) - comp.cp_after[stage]
            return (b, b)
        return (comp.e2e_slo / comp.depth - (now - inst.stage_t[stage]),
                None)

    # ---------------------------------------------------------- pre-warm
    def note_start(self, iid: int) -> List[Tuple[str, int]]:
        """A stage invocation started running: bump the in-flight count
        of every child function it will feed. Returns ``[(child_fn,
        inflight)]`` so the simulator can compare demand against the
        idle warm/warming supply and pre-warm the shortfall."""
        ent = self._by_iid.get(iid)
        if ent is None:
            return []
        inst, stage = ent
        out = []
        for child, _mb in inst.comp.children[stage]:
            fn = inst.comp.fn[child]
            n = self._inflight[fn] = self._inflight.get(fn, 0) + 1
            out.append((fn, n))
        return out

    def note_end(self, iid: int) -> None:
        """Mirror of ``note_start`` at finish (normal or OOM)."""
        ent = self._by_iid.get(iid)
        if ent is None:
            return
        inst, stage = ent
        for child, _mb in inst.comp.children[stage]:
            fn = inst.comp.fn[child]
            self._inflight[fn] = self._inflight.get(fn, 1) - 1

    # ------------------------------------------------------- transitions
    def on_complete(self, iid: int, now: float
                    ) -> List[Tuple[_Instance, str, str, int]]:
        """A stage invocation finished successfully. Decrements child
        join barriers and returns the stages whose LAST parent this
        was, as ``(instance, stage_name, function, input_idx)`` — the
        simulator mints an invocation id, builds the Arrival, and calls
        :meth:`bind`. A failed instance spawns nothing (its joins can
        never be satisfied anyway); chain completion is recorded when
        every stage has finished."""
        ent = self._by_iid.get(iid)
        if ent is None:
            return []
        inst, stage = ent
        inst.done += 1
        ready: List[Tuple[_Instance, str, str, int]] = []
        comp = inst.comp
        if not inst.failed:
            for child, _mb in comp.children[stage]:
                inst.waiting[child] -= 1
                if inst.waiting[child] == 0:
                    ready.append((inst, child, comp.fn[child],
                                  comp.input_idx[child]))
            if inst.done == comp.n_stages:
                self.completed += 1
                e2e = now - inst.root_t
                self._e2e.append(e2e)
                if e2e > comp.e2e_slo + 1e-9:
                    self.late += 1
        return ready

    def bind(self, inst: _Instance, stage: str, iid: int,
             now: float) -> None:
        """Register a freshly-spawned downstream stage invocation."""
        self._by_iid[iid] = (inst, stage)
        inst.stage_t[stage] = now
        self.stage_spawned += 1

    def on_fail(self, iid: int) -> None:
        """A stage invocation will never complete (shed, queue timeout,
        or OOM kill): the whole chain instance fails, once."""
        ent = self._by_iid.get(iid)
        if ent is not None and not ent[0].failed:
            ent[0].failed = True
            self.failed += 1

    # ------------------------------------------------------------- stats
    def summary(self) -> Dict[str, float]:
        """End-to-end chain metrics (merged into chain-scenario goldens
        and the chain_bench rows). ``chain_e2e_viol_pct`` counts BOTH
        late completions and failed instances against starts — a shed
        or OOM-killed stage is an e2e miss, not a statistical dropout."""
        e2e = np.array(self._e2e) if self._e2e else np.empty(0)
        started = max(self.started, 1)
        return {
            "chain_started": float(self.started),
            "chain_completed": float(self.completed),
            "chain_failed": float(self.failed),
            "chain_stage_spawned": float(self.stage_spawned),
            "chain_e2e_viol_pct": 100.0 * (self.late + self.failed) / started,
            "chain_e2e_p50_s": float(np.percentile(e2e, 50)) if e2e.size else 0.0,
            "chain_e2e_p99_s": float(np.percentile(e2e, 99)) if e2e.size else 0.0,
        }


# ---------------------------------------------------------------------------
# Canonical specs (the chain-pipeline / fan-out-join scenarios)
# ---------------------------------------------------------------------------


def chain_trigger(spec: ChainSpec) -> str:
    """The spec's trigger function (root stage's function) without
    compiling against a pool."""
    dsts = {e.dst for e in spec.edges}
    roots = [s for s in spec.stages if s.name not in dsts]
    assert len(roots) == 1, spec.name
    return roots[0].function


def default_chains() -> Dict[str, ChainSpec]:
    """The two committed DAGs. ``expected_s`` values are the
    uncontended exec times of each stage's resolved input at a typical
    (8 vCPU) allocation, rounded — they set the slack decomposition
    ratios and the e2e SLO (``slo_mult`` x critical path), while the
    simulated physics come from the live profiles.

    * ``pipeline`` (media-etl) — a linear 4-stage media pipeline:
      image ingest -> mobilenet detect -> resnet50 classify -> archive
      compression. Every stage is on the critical path, so "aware"
      budgets equal remaining-e2e-minus-tail while "uniform" starves
      the expensive classify stage and over-serves ingest;
    * ``fanout`` (fan-out-join) — a cheap qr-decode trigger fans out to
      three parallel analyses (imageprocess / mobilenet / resnet50)
      whose outputs join in a sentiment digest. The thumb branch
      (~1 s) holds ~2.4 s of slack against the tag branch (~3.4 s) —
      exactly the asymmetry slack-aware budgets exploit."""
    pipeline = ChainSpec(
        name="media-etl",
        stages=(
            ChainStage("ingest", "imageprocess"),
            ChainStage("detect", "mobilenet"),
            ChainStage("classify", "resnet50"),
            ChainStage("archive", "compress"),
        ),
        edges=(
            ChainEdge("ingest", "detect", 1.2),
            ChainEdge("detect", "classify", 1.2),
            ChainEdge("classify", "archive", 0.5),
        ),
        expected_s=(
            ("ingest", 1.0),
            ("detect", 2.0),
            ("classify", 3.4),
            ("archive", 1.8),
        ),
        slo_mult=1.6,
    )
    fanout = ChainSpec(
        name="fanout-ml",
        stages=(
            ChainStage("validate", "qr"),
            ChainStage("thumb", "imageprocess"),
            ChainStage("detect", "mobilenet"),
            ChainStage("tag", "resnet50"),
            ChainStage("digest", "sentiment"),
        ),
        edges=(
            ChainEdge("validate", "thumb", 0.9),
            ChainEdge("validate", "detect", 0.9),
            ChainEdge("validate", "tag", 0.9),
            ChainEdge("thumb", "digest", 0.008),
            ChainEdge("detect", "digest", 0.006),
            ChainEdge("tag", "digest", 0.006),
        ),
        expected_s=(
            ("validate", 0.15),
            ("thumb", 1.0),
            ("detect", 2.0),
            ("tag", 3.4),
            ("digest", 2.1),
        ),
        slo_mult=1.6,
    )
    return {"pipeline": pipeline, "fanout": fanout}
