"""Bucketed calendar event queue for the array-backed simulator loop.

A classic calendar queue (Brown 1988) specialised for the simulator's
access pattern: events are pushed with a ``(t, seq)`` priority and
popped in exactly ``(t, seq)`` order, but the *time axis is coarsely
bucketed* so the structure never maintains one global million-entry
heap. Each bucket is a small binary heap covering ``bucket_s`` seconds
of simulated time; a second tiny heap orders the non-empty bucket ids.
Pops drain the current (earliest) bucket; pushes land in their bucket's
heap — O(log bucket-size), and bucket sizes stay bounded by the event
density per ``bucket_s`` window rather than by trace length.

Two properties the simulator depends on:

* **Total order parity with ``heapq``.** Within a bucket the heap
  orders ``(t, seq, ...)`` tuples exactly as the legacy global heap
  did, and buckets are drained in id order, so the pop sequence is
  byte-identical to a single ``heapq`` over the same pushes (``seq`` is
  a strictly increasing tiebreak, so priorities are unique).
* **Safe insert-into-draining-bucket.** Simulated time never goes
  backwards: every push carries ``t >= now`` (handlers schedule only
  into the future), so pushing into the *currently draining* bucket is
  an ordinary ``heappush`` into that bucket's heap — the event sorts
  after everything already popped and before later-``(t, seq)``
  residents. ``tests/test_event_loop.py`` pins this boundary case.

The queue stores whatever tuple the caller pushes as long as it starts
with ``(t, seq)``; it never inspects trailing fields.
"""

from __future__ import annotations

import heapq
from typing import List, Optional, Tuple


class CalendarQueue:
    """Min-priority queue over ``(t, seq, ...)`` tuples, bucketed by
    ``int(t / bucket_s)``. Pop order is identical to a single global
    ``heapq`` over the same pushes."""

    __slots__ = ("bucket_s", "_inv_bucket", "_buckets", "_bucket_ids",
                 "_size", "_head", "_head_bid")

    def __init__(self, bucket_s: float = 1.0):
        assert bucket_s > 0.0
        self.bucket_s = bucket_s
        # bucket id = int(t * 1/bucket_s): multiply beats divide on the
        # per-push hot path, and any monotone-in-t bucket map yields
        # the same pop order (order WITHIN the structure is always by
        # the full (t, seq) tuple; bucket ids only partition it)
        self._inv_bucket = 1.0 / bucket_s
        self._buckets: dict = {}          # bucket id -> heapified list
        self._bucket_ids: List[int] = []  # heap of non-empty bucket ids
        self._size = 0
        # cached earliest non-empty bucket: the hot loop peeks before
        # every pop (merge against the sorted arrival array) and again
        # per cohort member, so re-finding the head bucket each time
        # would double the per-event queue cost. Invalidated whenever
        # it might go stale: a push that OPENS a bucket earlier than
        # the cached one, or a pop that drains the cached bucket.
        self._head: Optional[list] = None
        self._head_bid = -1

    def __len__(self) -> int:
        return self._size

    def __bool__(self) -> bool:
        return self._size > 0

    def push(self, ev: Tuple) -> None:
        bid = int(ev[0] * self._inv_bucket)
        b = self._buckets.get(bid)
        if b is None:
            self._buckets[bid] = [ev]
            heapq.heappush(self._bucket_ids, bid)
            if self._head is not None and bid < self._head_bid:
                self._head = None  # new bucket sorts before cached head
        else:
            # an existing bucket is never earlier than the cached head
            # (the head is the earliest non-empty bucket), so the cache
            # stays valid — including pushes INTO the head bucket
            heapq.heappush(b, ev)
        self._size += 1

    def peek(self) -> Optional[Tuple]:
        """Earliest event without removing it (None when empty)."""
        b = self._head
        if b:
            return b[0]
        ids = self._bucket_ids
        buckets = self._buckets
        while ids:
            bid = ids[0]
            b = buckets.get(bid)
            if b:
                self._head = b
                self._head_bid = bid
                return b[0]
            # bucket drained earlier; drop the stale id
            heapq.heappop(ids)
            buckets.pop(bid, None)
        return None

    def pop(self) -> Tuple:
        b = self._head
        if not b:
            if self.peek() is None:
                raise IndexError("pop from empty CalendarQueue")
            b = self._head
        ev = heapq.heappop(b)
        if not b:
            heapq.heappop(self._bucket_ids)
            del self._buckets[self._head_bid]
            self._head = None
        self._size -= 1
        return ev
