"""Resource-management policies: Shabari + the paper's five baselines (§7.1).

* Static-Medium / Static-Large — fixed (12 vCPU, 3 GB) / (20 vCPU, 5 GB)
  per function, OpenWhisk-style memory-centric scheduling.
* Parrotfish — offline parametric regression on two representative
  inputs; picks the memory minimizing cost (GB-s) with PROPORTIONAL
  vCPUs (bound resource types), fixed thereafter.
* Aquatope — uncertainty-aware Bayesian optimization per function over
  the decoupled (vCPU, mem) space on the same two representative inputs;
  fixed thereafter; runs on Shabari's scheduler (fair comparison, §7.1).
* Cypress — input-SIZE-only linear regression of execution time;
  single-threaded assumption (<=2 vCPUs), batch-oriented memory sizing.
* Shabari — the paper's system: per-invocation online CSOAA prediction
  per resource type + cold-start-aware scheduling.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

from repro.core.allocator import Allocation, ResourceAllocator
from repro.core.cost_functions import Observation
from repro.core.featurizer import Featurizer
from repro.serving.profiles import FunctionProfile, input_size_mb
from repro.serving.simulator import InvocationResult, Policy, Simulator
from repro.serving.workload import Arrival

MEM_CLASS_MB = 128
VCPUS_PER_GB = 4.0  # platform binding for bound-resource-type baselines


# ---------------------------------------------------------------------------
def representative_inputs(pool: List[Dict]) -> Tuple[Dict, Dict]:
    """Medium and large representative inputs (Parrotfish/Aquatope, §7.1)."""
    return pool[len(pool) // 2], pool[-1]


class StaticPolicy(Policy):
    uses_shabari_scheduler = False
    placement = "hashing"

    def __init__(self, vcpus: int, mem_mb: int, name: str):
        self.vcpus = vcpus
        self.mem_mb = mem_mb
        self.name = name
        # one shared Allocation: the decision never varies and nothing
        # downstream mutates it, so per-invocation construction is churn
        self._alloc = Allocation(vcpus=vcpus, mem_mb=mem_mb)

    def allocate(self, arrival, meta, sim):
        return self._alloc


class ParrotfishPolicy(Policy):
    """Offline cost-optimal memory via parametric regression; vCPUs bound
    proportionally. ~25 min of profiling per function in the paper —
    we charge the same profiling invocations in benchmarks/overheads."""

    name = "parrotfish"
    uses_shabari_scheduler = False
    placement = "hashing"

    def __init__(self, profiles: Dict[str, FunctionProfile],
                 pool: Dict[str, List[Dict]], seed: int = 0):
        self.alloc_table: Dict[str, Allocation] = {}
        rng = np.random.default_rng(seed)
        mem_grid_mb = [512, 1024, 2048, 3072, 4096, 5120, 6144, 8192]
        for fn, prof in profiles.items():
            med, large = representative_inputs(pool[fn])
            best, best_cost = None, np.inf
            for mem in mem_grid_mb:
                vcpus = max(1, int(round(mem / 1024 * VCPUS_PER_GB)))
                # parametric regression fit == profile samples (5 each)
                times = []
                for m in (med, large):
                    times += [prof.exec_time(m, vcpus, rng) for _ in range(5)]
                t = float(np.mean(times))
                needed = max(prof.mem_used_mb(med), prof.mem_used_mb(large))
                if needed > mem:
                    continue  # OOM at this size
                cost = mem / 1024.0 * t  # GB-seconds
                if cost < best_cost:
                    best, best_cost = Allocation(vcpus, mem, True, True), cost
            if best is None:
                best = Allocation(20, 8192)
            self.alloc_table[fn] = best

    def allocate(self, arrival, meta, sim):
        return self.alloc_table[arrival.function]


class AquatopePolicy(Policy):
    """BO over decoupled (vCPU, mem) per function on two representative
    inputs: 30 uncertainty-aware trials of an EI-style acquisition on a
    noisy objective = SLO compliance with resource-cost regularizer.
    Decisions are per FUNCTION (input-agnostic) — the paper's critique."""

    name = "aquatope"
    uses_shabari_scheduler = True
    placement = "hashing"

    def __init__(self, profiles: Dict[str, FunctionProfile],
                 pool: Dict[str, List[Dict]],
                 slo_fn: Callable[[str, int], float],
                 trials: int = 30, seed: int = 0):
        self.alloc_table: Dict[str, Allocation] = {}
        rng = np.random.default_rng(seed)
        for fn, prof in profiles.items():
            med, large = representative_inputs(pool[fn])
            idx_med = pool[fn].index(med)
            idx_large = pool[fn].index(large)
            slo = min(slo_fn(fn, idx_med), slo_fn(fn, idx_large))
            samples: List[Tuple[int, int, float]] = []

            def objective(v, m):
                # noisy evaluation, as on a real cluster
                times = [prof.exec_time(x, v, rng) for x in (med, large)
                         for _ in range(2)]
                t = float(np.mean(times)) + 0.5 * float(np.std(times))
                mem_need = max(prof.mem_used_mb(med), prof.mem_used_mb(large))
                pen = 100.0 if m < mem_need else 0.0
                sl = 10.0 * max(t - slo, 0.0) / slo
                return sl + pen + 0.02 * v + 0.01 * m / 1024.0

            # BO-style: seeded random exploration then local refinement
            best, best_y = None, np.inf
            for i in range(trials):
                if best is None or i < trials // 2:
                    v = int(rng.integers(1, 33))
                    m = int(rng.integers(2, 65)) * MEM_CLASS_MB
                else:
                    bv, bm = best
                    v = int(np.clip(bv + rng.integers(-4, 5), 1, 32))
                    m = int(np.clip(bm + rng.integers(-8, 9) * MEM_CLASS_MB,
                                    256, 8192))
                y = objective(v, m)
                if y < best_y:
                    best, best_y = (v, m), y
            self.alloc_table[fn] = Allocation(best[0], best[1], True, True)

    def allocate(self, arrival, meta, sim):
        return self.alloc_table[arrival.function]


class CypressPolicy(Policy):
    """Input-size-aware batching system. Linear regression of exec time on
    input SIZE only; assumes single-threaded functions (<=2 vCPUs);
    memory sized for the predicted batch (multiples of a per-invocation
    share — poor utilization under sparse arrivals, §7.2)."""

    name = "cypress"
    uses_shabari_scheduler = False
    placement = "hashing"
    BATCH_TARGET = 4

    def __init__(self, profiles: Dict[str, FunctionProfile],
                 pool: Dict[str, List[Dict]], seed: int = 0):
        self.profiles = profiles
        # online LR state per function: sum stats for y = a*size + b
        self._lr: Dict[str, np.ndarray] = {}
        self._mem_obs: Dict[str, float] = {}
        self.pool = pool

    def _predict_exec(self, fn: str, size: float) -> float:
        st = self._lr.get(fn)
        if st is None or st[4] < 5:
            return 1.0
        n, sx, sy, sxy, _ = st[4], st[0], st[1], st[2], None
        sxx = st[3]
        denom = n * sxx - sx * sx
        if abs(denom) < 1e-9:
            return sy / n
        a = (n * sxy - sx * sy) / denom
        b = (sy - a * sx) / n
        return max(a * size + b, 0.05)

    def _update_lr(self, fn: str, size: float, t: float) -> None:
        st = self._lr.setdefault(fn, np.zeros(5))
        st[0] += size
        st[1] += t
        st[2] += size * t
        st[3] += size * size
        st[4] += 1

    def allocate(self, arrival, meta, sim):
        fn = arrival.function
        mem_share = self._mem_obs.get(fn, 512.0)
        # container sized for a batch of invocations (batch-oriented
        # provisioning) even when arrivals are sparse
        mem = int(math.ceil(self.BATCH_TARGET * mem_share / MEM_CLASS_MB)
                  ) * MEM_CLASS_MB
        return Allocation(vcpus=2, mem_mb=min(mem, 16 * 1024),
                          vcpu_predicted=True, mem_predicted=True)

    def feedback(self, arrival, meta, result, sim):
        fn = arrival.function
        self._update_lr(fn, input_size_mb(fn, meta), result.exec_s)
        prev = self._mem_obs.get(fn, 512.0)
        self._mem_obs[fn] = 0.8 * prev + 0.2 * max(result.used_mem_mb, 64.0)


class ShabariPolicy(Policy):
    """The paper's system: delayed per-invocation decisions.

    ``engine`` selects the allocator implementation: ``"arena"``
    (default, the batched agent arena — see ``repro.core.agent_arena``)
    or ``"legacy"`` (one jit'd dispatch per per-function agent per
    event). Allocations and metrics are bit-identical either way
    (asserted by the sim_bench engine A/B and the legacy-engine golden
    snapshot); only wall-clock differs."""

    name = "shabari"
    uses_shabari_scheduler = True
    placement = "hashing"

    def __init__(self, *, vcpu_cost_fn=None, vcpu_confidence: int = 10,
                 mem_confidence: Optional[int] = None,
                 default_vcpus: int = 10, n_vcpu_classes: int = 32,
                 engine: str = "arena"):
        from repro.core.cost_functions import absolute_vcpu_costs

        kwargs = dict(
            vcpu_confidence=vcpu_confidence,
            mem_confidence=(mem_confidence if mem_confidence is not None
                            else 2 * vcpu_confidence),
            default_vcpus=default_vcpus,
            n_vcpu_classes=n_vcpu_classes,
            vcpu_cost_fn=vcpu_cost_fn or absolute_vcpu_costs,
            engine=engine,
        )
        self.allocator = ResourceAllocator(**kwargs)
        self.featurizer = Featurizer()
        self._features: Dict[int, np.ndarray] = {}
        # same-timestamp arrivals prefetched by begin_arrival_batch:
        # invocation_id -> (Allocation, aux)
        self._prealloc: Dict[int, Tuple[Allocation, tuple]] = {}

    def _featurize(self, arrival, meta, sim):
        fn = arrival.function
        x = self.featurizer.extract(fn, sim.profiles[fn].input_type, meta)
        return x, input_size_mb(fn, meta)

    def allocate_with_aux(self, arrival, meta, sim, aux=None):
        pre = self._prealloc.pop(arrival.invocation_id, None)
        if pre is not None:
            alloc, aux = pre
            self._features[arrival.invocation_id] = aux[0]
            return alloc, aux
        if aux is None:
            # first sight of this invocation: featurize once; the tuple
            # rides the retry payload so re-allocations (the legacy
            # per-retry path) never re-run Featurizer / input_size_mb
            aux = self._featurize(arrival, meta, sim)
        x, size = aux
        self._features[arrival.invocation_id] = x
        return self.allocator.allocate(arrival.function, x, size), aux

    def allocate(self, arrival, meta, sim):
        return self.allocate_with_aux(arrival, meta, sim)[0]

    def begin_arrival_batch(self, items, sim):
        """Featurize in event order (the Featurizer's running stats are
        order-sensitive), then serve every first allocation of this
        timestamp with one fused arena predict."""
        batch = []
        for arrival, meta in items:
            aux = self._featurize(arrival, meta, sim)
            batch.append((arrival.invocation_id, arrival.function, aux))
        allocs = self.allocator.allocate_batch(
            [(fn, aux[0], aux[1]) for _, fn, aux in batch]
        )
        for (iid, fn, aux), alloc in zip(batch, allocs):
            self._prealloc[iid] = (alloc, aux)

    def forget(self, arrival):
        self._features.pop(arrival.invocation_id, None)
        self._prealloc.pop(arrival.invocation_id, None)

    def feedback(self, arrival, meta, result, sim):
        x = self._features.pop(arrival.invocation_id, None)
        if x is None:
            return
        obs = Observation(
            exec_time_s=result.finish_t - result.arrival_t,
            slo_s=result.slo_s,
            alloc_vcpus=result.alloc_vcpus,
            max_vcpus_used=result.used_vcpus,
            alloc_mem_mb=result.alloc_mem_mb,
            max_mem_used_mb=result.used_mem_mb,
            cold_start=result.cold_start,
            oom_killed=result.oom_killed,
        )
        self.allocator.feedback(arrival.function, x, obs)


class FormulationPolicy(ShabariPolicy):
    """Shabari with one of the §4.2 ML formulations (Figure 6)."""

    uses_shabari_scheduler = True

    def __init__(self, mode: str, profiles: Dict[str, FunctionProfile]):
        super().__init__()
        from repro.core.featurizer import FEATURE_SCHEMAS
        from repro.core.formulations import FormulationAllocator

        self.name = f"shabari-{mode}"
        fns = sorted(profiles.keys())
        dims = {f: len(FEATURE_SCHEMAS[profiles[f].input_type]) for f in fns}
        types = {f: profiles[f].input_type for f in fns}
        self.allocator = FormulationAllocator(mode, fns, dims, types)


# ---------------------------------------------------------------------------
# SLO table (§7.1: isolated profiling, 1.4x best-allocation median)
# ---------------------------------------------------------------------------


def build_slo_table(
    profiles: Dict[str, FunctionProfile],
    pool: Dict[str, List[Dict]],
    *,
    multiplier: float = 1.4,
    max_vcpus: int = 32,
    runs: int = 5,
    seed: int = 1234,
) -> Dict[Tuple[str, int], float]:
    rng = np.random.default_rng(seed)
    table: Dict[Tuple[str, int], float] = {}
    for fn, prof in profiles.items():
        for idx, meta in enumerate(pool[fn]):
            best = np.inf
            for v in (1, 2, 4, 8, 12, 16, 20, 24, 28, 32):
                if v > max_vcpus:
                    break
                times = [prof.exec_time(meta, v, rng) for _ in range(runs)]
                best = min(best, float(np.median(times)))
            table[(fn, idx)] = multiplier * best
    return table
