"""Workload generation: the Azure-trace shape (paper §7.1) plus a
registry of named load scenarios.

The paper samples a ten-minute window from the Azure Functions trace
[Shahrad et al. 2020], randomizes start times within each minute, and
subsamples to the target RPS. We reproduce the trace's load shape with
its published characteristics — heavy-tailed per-minute invocation
counts (most functions rare, a few hot) and bursty minutes — using a
seeded generator, then apply exactly the paper's per-minute
start-time randomization and RPS subsampling.

Because allocation quality flips under bursty versus steady load
(Fifer, arXiv 2008.12819; the Freedom/Opportunity study, arXiv
2105.14845), evaluation also needs the other load shapes a production
FaaS sees. ``SCENARIOS`` names them: ``azure`` (the trace shape above),
``poisson-steady``, ``flash-crowd``, ``diurnal``, ``heavy-tail-inputs``,
``cold-storm``, ``oversubscribe`` (the §7.5 study),
``multi-cluster`` (a hot-function surge for the front-door router,
``repro.core.router``), ``hetero-fleet`` (steady skewed load for
machine-type mixes, ``repro.core.fleet``), and ``wan-spill`` (the
hot-surge shape with heavy-tail inputs, where remote placements pay
real transfer time over modeled links). Each generator
is a pure seeded function of a :class:`ScenarioSpec`, so a (spec, seed)
pair always yields the identical ``Arrival`` list.
"""

from __future__ import annotations

import dataclasses
import itertools
from typing import Callable, Dict, List, Mapping, Optional, Sequence, Tuple

import numpy as np

_inv_ids = itertools.count()


@dataclasses.dataclass(slots=True)
class Arrival:
    invocation_id: int
    t: float
    function: str
    input_idx: int


def azure_minute_weights(n_minutes: int, rng: np.random.Generator) -> np.ndarray:
    """Per-minute relative load: lognormal bursts around a diurnal-ish
    baseline (the ten-minute windows in the trace show 2-4x swings)."""
    base = 1.0 + 0.3 * np.sin(np.linspace(0, 2 * np.pi, n_minutes))
    burst = rng.lognormal(mean=0.0, sigma=0.45, size=n_minutes)
    w = base * burst
    return w / w.sum()


def function_popularity(functions: Sequence[str], rng: np.random.Generator) -> np.ndarray:
    """Zipf-like popularity — the trace's hallmark (a few functions
    dominate invocations)."""
    ranks = np.arange(1, len(functions) + 1, dtype=np.float64)
    rng.shuffle(ranks)
    w = 1.0 / ranks ** 0.9
    return w / w.sum()


def generate_trace(
    *,
    rps: float,
    functions: Sequence[str],
    inputs_per_function: Dict[str, int],
    duration_s: float = 600.0,
    seed: int = 0,
    uniform_popularity: bool = False,
) -> List[Arrival]:
    rng = np.random.default_rng(seed)
    n_minutes = int(np.ceil(duration_s / 60.0))
    weights = azure_minute_weights(n_minutes, rng)
    total = int(round(rps * duration_s))
    per_minute = rng.multinomial(total, weights)
    if uniform_popularity:
        pop = np.full(len(functions), 1.0 / len(functions))
    else:
        pop = function_popularity(functions, rng)

    arrivals: List[Arrival] = []
    for minute, count in enumerate(per_minute):
        starts = rng.uniform(minute * 60.0, (minute + 1) * 60.0, size=count)
        starts.sort()
        fns = rng.choice(len(functions), size=count, p=pop)
        for t, fi in zip(starts, fns):
            fn = functions[fi]
            idx = int(rng.integers(inputs_per_function[fn]))
            arrivals.append(
                Arrival(next(_inv_ids), float(t), fn, idx)
            )
    arrivals.sort(key=lambda a: a.t)
    return arrivals


# ---------------------------------------------------------------------------
# Scenario matrix
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class ScenarioSpec:
    """A named, seeded, parameterized load scenario.

    ``params`` carries the scenario-specific knobs (spike multiplier,
    input-skew exponent, clone count, ...); every generator documents
    the keys it reads and their defaults, so an empty ``params`` always
    works.
    """

    scenario: str = "azure"
    rps: float = 4.0
    duration_s: float = 600.0
    seed: int = 0
    params: Dict[str, float] = dataclasses.field(default_factory=dict)

    def param(self, key: str, default: float) -> float:
        return float(self.params.get(key, default))


# generator signature: (spec, functions, inputs_per_function, rng) -> arrivals
ScenarioFn = Callable[
    [ScenarioSpec, List[str], Mapping[str, int], np.random.Generator],
    List["Arrival"],
]

SCENARIOS: Dict[str, ScenarioFn] = {}


def register_scenario(name: str) -> Callable[[ScenarioFn], ScenarioFn]:
    def deco(fn: ScenarioFn) -> ScenarioFn:
        SCENARIOS[name] = fn
        return fn
    return deco


def list_scenarios() -> List[str]:
    return sorted(SCENARIOS)


def generate_scenario(
    spec: ScenarioSpec,
    functions: Sequence[str],
    inputs_per_function: Mapping[str, int],
) -> List[Arrival]:
    """Generate the arrival trace for ``spec``.

    Invocation ids are renumbered 0..n-1 after the final time sort, so
    two calls with the same spec return *identical* Arrival lists
    (unlike the process-global counter ``generate_trace`` keeps for
    backward compatibility).
    """
    try:
        gen = SCENARIOS[spec.scenario]
    except KeyError:
        raise KeyError(
            f"unknown scenario {spec.scenario!r}; known: {list_scenarios()}"
        ) from None
    rng = np.random.default_rng(spec.seed)
    arrivals = gen(spec, list(functions), inputs_per_function, rng)
    arrivals.sort(key=lambda a: a.t)
    for i, a in enumerate(arrivals):
        a.invocation_id = i
    return arrivals


# ------------------------------------------------------------------ helpers
def _poisson_times(rate: float, duration_s: float,
                   rng: np.random.Generator) -> np.ndarray:
    """Homogeneous Poisson arrival times on [0, duration)."""
    if rate <= 0.0 or duration_s <= 0.0:
        return np.empty(0)
    n = int(rng.poisson(rate * duration_s))
    return np.sort(rng.uniform(0.0, duration_s, size=n))


def _thinned_times(rate_fn: Callable[[np.ndarray], np.ndarray],
                   peak_rate: float, duration_s: float,
                   rng: np.random.Generator) -> np.ndarray:
    """Inhomogeneous Poisson via thinning against ``peak_rate``."""
    cand = _poisson_times(peak_rate, duration_s, rng)
    if cand.size == 0:
        return cand
    accept = rate_fn(cand) / peak_rate
    # thinning is only correct when peak_rate bounds rate_fn; a silent
    # clamp here would generate a wrong (too-light) trace
    assert float(accept.max()) <= 1.0 + 1e-9, (
        "rate_fn exceeds peak_rate; thinning bound violated"
    )
    keep = rng.uniform(0.0, 1.0, size=cand.size) < accept
    return cand[keep]


def _assemble(times: np.ndarray, functions: List[str],
              pop: np.ndarray, inputs_per_function: Mapping[str, int],
              rng: np.random.Generator,
              input_weights: Optional[Callable[[int], np.ndarray]] = None,
              ) -> List[Arrival]:
    """Sample (function, input) per arrival time.

    ``input_weights(n)`` returns the idx-sampling distribution for a
    pool of n inputs; None means uniform. Pools are built smallest ->
    largest, so weights skewed toward high indices skew toward large
    inputs.
    """
    out: List[Arrival] = []
    if times.size == 0:
        return out
    fis = rng.choice(len(functions), size=times.size, p=pop)
    for t, fi in zip(times, fis):
        fn = functions[fi]
        n_inputs = inputs_per_function[fn]
        if input_weights is None:
            idx = int(rng.integers(n_inputs))
        else:
            idx = int(rng.choice(n_inputs, p=input_weights(n_inputs)))
        out.append(Arrival(next(_inv_ids), float(t), fn, idx))
    return out


# --------------------------------------------------------------- scenarios
@register_scenario("azure")
def _azure(spec: ScenarioSpec, functions, inputs_per_function, rng):
    """The seed generator: Azure-trace shape (bursty minutes + Zipf
    popularity). params: uniform_popularity (0/1, default 0)."""
    return generate_trace(
        rps=spec.rps, functions=functions,
        inputs_per_function=dict(inputs_per_function),
        duration_s=spec.duration_s, seed=spec.seed,
        uniform_popularity=bool(spec.param("uniform_popularity", 0)),
    )


@register_scenario("azure-24h")
def _azure_24h(spec: ScenarioSpec, functions, inputs_per_function, rng):
    """A full production day at Azure-trace scale, for the ``scale``
    benchmark tier (benchmarks/sim_bench): one diurnal cycle across the
    window (trough at the start, peak mid-window) times the trace's
    lognormal per-minute bursts, Zipf popularity. At the default
    ``peak_mult`` the peak minutes offer several times the fleet's
    serviceable rate, so the cell exercises admission control and
    front-door queueing the way a real overload day does. The whole
    trace is synthesized vectorized at build time — per-minute
    multinomial counts, one uniform draw per arrival — never per-event,
    so a ≥1M-invocation day builds in seconds. params: peak_mult
    (peak-to-trough ratio, default 6.0), burst_sigma (per-minute
    lognormal sigma, default 0.45)."""
    peak_mult = max(spec.param("peak_mult", 6.0), 1.0)
    burst_sigma = spec.param("burst_sigma", 0.45)
    n_minutes = int(np.ceil(spec.duration_s / 60.0))
    # sinusoid from trough 1.0 to peak ``peak_mult`` over one cycle
    phase = 2.0 * np.pi * np.arange(n_minutes) / n_minutes
    base = 1.0 + (peak_mult - 1.0) * 0.5 * (1.0 - np.cos(phase))
    burst = rng.lognormal(mean=0.0, sigma=burst_sigma, size=n_minutes)
    w = base * burst
    w = w / w.sum()
    total = int(round(spec.rps * spec.duration_s))
    per_minute = rng.multinomial(total, w)
    pop = function_popularity(functions, rng)

    m_idx = np.repeat(np.arange(n_minutes), per_minute)
    times = (m_idx + rng.random(total)) * 60.0
    times.sort(kind="stable")
    fis = rng.choice(len(functions), size=total, p=pop)
    n_inputs = np.array([inputs_per_function[f] for f in functions])
    idxs = rng.integers(0, n_inputs[fis])
    return [
        Arrival(next(_inv_ids), float(t), functions[fi], int(ix))
        for t, fi, ix in zip(times, fis, idxs)
    ]


@register_scenario("poisson-steady")
def _poisson_steady(spec: ScenarioSpec, functions, inputs_per_function, rng):
    """Memoryless steady load — the opposite pole from azure's bursty
    minutes. params: none."""
    pop = function_popularity(functions, rng)
    times = _poisson_times(spec.rps, spec.duration_s, rng)
    return _assemble(times, functions, pop, inputs_per_function, rng)


@register_scenario("flash-crowd")
def _flash_crowd(spec: ScenarioSpec, functions, inputs_per_function, rng):
    """Steady baseline with a spike window at ``spike_mult`` x baseline
    RPS (default 8x — Fifer's burst regime). params: spike_mult,
    spike_start_frac (default 0.4), spike_duration_s (default 60)."""
    mult = spec.param("spike_mult", 8.0)
    t0 = spec.param("spike_start_frac", 0.4) * spec.duration_s
    t1 = min(t0 + spec.param("spike_duration_s", 60.0), spec.duration_s)
    pop = function_popularity(functions, rng)

    def rate(t: np.ndarray) -> np.ndarray:
        return np.where((t >= t0) & (t < t1), spec.rps * mult, spec.rps)

    # spike_mult < 1 models a load DIP, so the baseline is the peak
    peak = spec.rps * max(mult, 1.0)
    times = _thinned_times(rate, peak, spec.duration_s, rng)
    return _assemble(times, functions, pop, inputs_per_function, rng)


@register_scenario("diurnal")
def _diurnal(spec: ScenarioSpec, functions, inputs_per_function, rng):
    """Sinusoidal day/night swing around the mean RPS. params: amp
    (default 0.6), cycles over the window (default 1)."""
    amp = min(max(spec.param("amp", 0.6), 0.0), 0.95)
    cycles = spec.param("cycles", 1.0)
    pop = function_popularity(functions, rng)

    def rate(t: np.ndarray) -> np.ndarray:
        phase = 2.0 * np.pi * cycles * t / spec.duration_s
        return spec.rps * (1.0 + amp * np.sin(phase - np.pi / 2.0))

    times = _thinned_times(rate, spec.rps * (1.0 + amp), spec.duration_s, rng)
    return _assemble(times, functions, pop, inputs_per_function, rng)


@register_scenario("heavy-tail-inputs")
def _heavy_tail_inputs(spec: ScenarioSpec, functions, inputs_per_function, rng):
    """Steady load whose input-size distribution is skewed to each
    profile's large end (pools are sorted smallest -> largest), probing
    the §2.1 non-linear size->time regime. params: skew (weight
    exponent, default 3.0)."""
    skew = spec.param("skew", 3.0)
    pop = function_popularity(functions, rng)
    times = _poisson_times(spec.rps, spec.duration_s, rng)

    def input_weights(n: int) -> np.ndarray:
        w = (np.arange(1, n + 1, dtype=np.float64)) ** skew
        return w / w.sum()

    return _assemble(times, functions, pop, inputs_per_function, rng,
                     input_weights=input_weights)


@register_scenario("cold-storm")
def _cold_storm(spec: ScenarioSpec, functions, inputs_per_function, rng):
    """Many unique, rarely-repeating functions — the keep-alive-defeating
    long tail of the Azure trace. Uniform popularity over the (cloned,
    see ``expand_function_clones``) function set so per-function arrival
    rate stays below warm-hit territory. params: clones (consumed by the
    experiment layer, default 6)."""
    pop = np.full(len(functions), 1.0 / len(functions))
    times = _poisson_times(spec.rps, spec.duration_s, rng)
    return _assemble(times, functions, pop, inputs_per_function, rng)


@register_scenario("registry-storm")
def _registry_storm(spec: ScenarioSpec, functions, inputs_per_function, rng):
    """Cold-storm over clone aliases that SHARE image base layers (a
    rolling deploy hammering the registry): uniform popularity over the
    cloned function set — every arrival is likely cold — plus a deploy
    -wave window at ``spike_mult`` x baseline, so concurrent pulls pile
    onto the per-node layer stores. The interesting physics lives in
    ``SimConfig(image_cache=...)``: siblings of a pulled clone miss only
    their tiny alias layer, so WHERE a cold start lands decides whether
    it pulls megabytes or gigabytes. params: clones (consumed by the
    experiment layer, default 6), spike_mult (default 4), spike_start
    _frac (default 0.3), spike_duration_s (default 45)."""
    mult = spec.param("spike_mult", 4.0)
    t0 = spec.param("spike_start_frac", 0.3) * spec.duration_s
    t1 = min(t0 + spec.param("spike_duration_s", 45.0), spec.duration_s)
    pop = np.full(len(functions), 1.0 / len(functions))

    def rate(t: np.ndarray) -> np.ndarray:
        return np.where((t >= t0) & (t < t1), spec.rps * mult, spec.rps)

    peak = spec.rps * max(mult, 1.0)
    times = _thinned_times(rate, peak, spec.duration_s, rng)
    return _assemble(times, functions, pop, inputs_per_function, rng)


@register_scenario("oversubscribe")
def _oversubscribe(spec: ScenarioSpec, functions, inputs_per_function, rng):
    """Offered load beyond cluster vCPUs (the §7.5 study): steady
    arrivals at ``load_mult`` x the nominal RPS, driving queueing,
    retries, and timeouts. params: load_mult (default 3.0)."""
    mult = spec.param("load_mult", 3.0)
    pop = function_popularity(functions, rng)
    times = _poisson_times(spec.rps * mult, spec.duration_s, rng)
    return _assemble(times, functions, pop, inputs_per_function, rng)


@register_scenario("multi-cluster")
def _multi_cluster(spec: ScenarioSpec, functions, inputs_per_function, rng):
    """Hot-spot shape for the front-door router: ``hot_frac`` of traffic
    concentrates on ``hot_fns`` randomly-chosen functions, plus a flash
    window at ``spike_mult`` x baseline. Hashed home clusters pin each
    hot function's warm pool to one cluster, so its cluster saturates
    while the others idle — the regime where spill-over routing (vs pure
    hashing) decides SLO compliance. params: hot_fns (default 2),
    hot_frac (default 0.7), spike_mult (default 4), spike_start_frac
    (default 0.4), spike_duration_s (default 60)."""
    n_hot = max(1, min(int(spec.param("hot_fns", 2)), len(functions)))
    hot_frac = min(max(spec.param("hot_frac", 0.7), 0.0), 1.0)
    hot = rng.choice(len(functions), size=n_hot, replace=False)
    pop = np.full(
        len(functions),
        (1.0 - hot_frac) / max(len(functions) - n_hot, 1),
    )
    pop[hot] = hot_frac / n_hot
    pop = pop / pop.sum()

    mult = spec.param("spike_mult", 4.0)
    t0 = spec.param("spike_start_frac", 0.4) * spec.duration_s
    t1 = min(t0 + spec.param("spike_duration_s", 60.0), spec.duration_s)

    def rate(t: np.ndarray) -> np.ndarray:
        return np.where((t >= t0) & (t < t1), spec.rps * mult, spec.rps)

    times = _thinned_times(rate, spec.rps * max(mult, 1.0), spec.duration_s,
                           rng)
    return _assemble(times, functions, pop, inputs_per_function, rng)


@register_scenario("hetero-fleet")
def _hetero_fleet(spec: ScenarioSpec, functions, inputs_per_function, rng):
    """Steady Zipf load with moderately size-skewed inputs — the probe
    shape for heterogeneous fleets (repro.core.fleet): no burst
    dynamics, so metric deltas isolate what per-machine cold curves,
    exec-speed factors, and §5 denominators change about placement.
    Run it under a FleetSpec mixing machine types (the golden pins a
    fast-tier + slow-tier mix). params: skew (input-weight exponent,
    default 2.0)."""
    skew = spec.param("skew", 2.0)
    pop = function_popularity(functions, rng)
    times = _poisson_times(spec.rps, spec.duration_s, rng)

    def input_weights(n: int) -> np.ndarray:
        w = (np.arange(1, n + 1, dtype=np.float64)) ** skew
        return w / w.sum()

    return _assemble(times, functions, pop, inputs_per_function, rng,
                     input_weights=input_weights)


@register_scenario("wan-spill")
def _wan_spill(spec: ScenarioSpec, functions, inputs_per_function, rng):
    """Hot-function surge (multi-cluster's shape) with HEAVY-TAIL input
    sizes: the hot functions' home cluster saturates, forcing spills,
    while large inputs make every remote placement pay real transfer
    time over the inter-cluster links (repro.core.fleet.Topology) —
    the regime where transfer-aware estimate routing separates from
    transfer-blind (benchmarks/fleet_bench). params: hot_fns (default
    2), hot_frac (default 0.7), skew (input-weight exponent, default
    3.0), spike_mult (default 4), spike_start_frac (default 0.4),
    spike_duration_s (default 60)."""
    n_hot = max(1, min(int(spec.param("hot_fns", 2)), len(functions)))
    hot_frac = min(max(spec.param("hot_frac", 0.7), 0.0), 1.0)
    hot = rng.choice(len(functions), size=n_hot, replace=False)
    pop = np.full(
        len(functions),
        (1.0 - hot_frac) / max(len(functions) - n_hot, 1),
    )
    pop[hot] = hot_frac / n_hot
    pop = pop / pop.sum()

    mult = spec.param("spike_mult", 4.0)
    t0 = spec.param("spike_start_frac", 0.4) * spec.duration_s
    t1 = min(t0 + spec.param("spike_duration_s", 60.0), spec.duration_s)
    skew = spec.param("skew", 3.0)

    def rate(t: np.ndarray) -> np.ndarray:
        return np.where((t >= t0) & (t < t1), spec.rps * mult, spec.rps)

    def input_weights(n: int) -> np.ndarray:
        w = (np.arange(1, n + 1, dtype=np.float64)) ** skew
        return w / w.sum()

    times = _thinned_times(rate, spec.rps * max(mult, 1.0), spec.duration_s,
                           rng)
    return _assemble(times, functions, pop, inputs_per_function, rng,
                     input_weights=input_weights)


def _chain_trace(chain_name: str, idx_cap_default: float,
                 spec: ScenarioSpec, functions, inputs_per_function,
                 rng: np.random.Generator) -> List[Arrival]:
    """Shared shape for the chain scenarios: a Poisson TRIGGER stream
    on the chain's root function plus background Zipf traffic over the
    remaining functions.

    The trace only carries the trigger arrivals — every downstream
    stage invocation is SPAWNED by the simulator when its parents
    complete (``SimConfig.chains``; the golden harness wires
    ``repro.serving.chains.default_chains()``). Background traffic
    excludes the trigger function so the chain count is exactly the
    trigger count, and it keeps the non-chain warm pools busy enough
    that slack decisions have real competition for capacity.

    params: trigger_frac (fraction of ``spec.rps`` that starts chains,
    default 0.4), trigger_idx_cap (exclusive upper bound on the trigger
    input idx — pools sort smallest -> largest and the root stage's
    expected_s is calibrated to a mid-pool input, so the cap keeps
    huge-input roots from swamping the critical-path math; per-scenario
    default).
    """
    from repro.serving.chains import chain_trigger, default_chains

    trig = chain_trigger(default_chains()[chain_name])
    frac = min(max(spec.param("trigger_frac", 0.4), 0.0), 1.0)
    cap = int(spec.param("trigger_idx_cap", idx_cap_default))

    out: List[Arrival] = []
    n_inputs = inputs_per_function[trig]
    hi = max(1, min(cap, n_inputs))
    for t in _poisson_times(frac * spec.rps, spec.duration_s, rng):
        idx = int(rng.integers(hi))
        out.append(Arrival(next(_inv_ids), float(t), trig, idx))

    bg = [f for f in functions if f != trig]
    if bg:
        pop = function_popularity(bg, rng)
        times = _poisson_times((1.0 - frac) * spec.rps, spec.duration_s,
                               rng)
        out.extend(_assemble(times, bg, pop, inputs_per_function, rng))
    return out


@register_scenario("chain-pipeline")
def _chain_pipeline(spec: ScenarioSpec, functions, inputs_per_function, rng):
    """Linear 4-stage media-ETL chain (``default_chains()["pipeline"]``:
    imageprocess -> mobilenet -> resnet50 -> compress) under background
    Zipf load. The root is imageprocess, whose input pool spans ~0.1s
    to ~9s of exec — the default idx cap (11 of 14) trims the extreme
    tail so the e2e SLO (slo_mult x critical path) stays meaningful.
    params: see ``_chain_trace``."""
    return _chain_trace("pipeline", 11.0, spec, functions,
                        inputs_per_function, rng)


@register_scenario("fan-out-join")
def _fan_out_join(spec: ScenarioSpec, functions, inputs_per_function, rng):
    """Fan-out/fan-in chain (``default_chains()["fanout"]``: qr
    validates, then thumb/detect/tag run in parallel, and a sentiment
    digest joins all three) under background Zipf load. The join
    barrier makes the digest's arrival time the max of three sibling
    completions, so one slow sibling decides e2e latency — the shape
    where per-stage slack differs most from a uniform SLO split. qr's
    pool is uniformly cheap, so no idx cap by default. params: see
    ``_chain_trace``."""
    return _chain_trace("fanout", 1e9, spec, functions,
                        inputs_per_function, rng)
