"""Azure-trace-style workload generation (paper §7.1).

The paper samples a ten-minute window from the Azure Functions trace
[Shahrad et al. 2020], randomizes start times within each minute, and
subsamples to the target RPS. We reproduce the trace's load shape with
its published characteristics — heavy-tailed per-minute invocation
counts (most functions rare, a few hot) and bursty minutes — using a
seeded generator, then apply exactly the paper's per-minute
start-time randomization and RPS subsampling.
"""

from __future__ import annotations

import dataclasses
import itertools
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

_inv_ids = itertools.count()


@dataclasses.dataclass
class Arrival:
    invocation_id: int
    t: float
    function: str
    input_idx: int


def azure_minute_weights(n_minutes: int, rng: np.random.Generator) -> np.ndarray:
    """Per-minute relative load: lognormal bursts around a diurnal-ish
    baseline (the ten-minute windows in the trace show 2-4x swings)."""
    base = 1.0 + 0.3 * np.sin(np.linspace(0, 2 * np.pi, n_minutes))
    burst = rng.lognormal(mean=0.0, sigma=0.45, size=n_minutes)
    w = base * burst
    return w / w.sum()


def function_popularity(functions: Sequence[str], rng: np.random.Generator) -> np.ndarray:
    """Zipf-like popularity — the trace's hallmark (a few functions
    dominate invocations)."""
    ranks = np.arange(1, len(functions) + 1, dtype=np.float64)
    rng.shuffle(ranks)
    w = 1.0 / ranks ** 0.9
    return w / w.sum()


def generate_trace(
    *,
    rps: float,
    functions: Sequence[str],
    inputs_per_function: Dict[str, int],
    duration_s: float = 600.0,
    seed: int = 0,
    uniform_popularity: bool = False,
) -> List[Arrival]:
    rng = np.random.default_rng(seed)
    n_minutes = int(np.ceil(duration_s / 60.0))
    weights = azure_minute_weights(n_minutes, rng)
    total = int(round(rps * duration_s))
    per_minute = rng.multinomial(total, weights)
    if uniform_popularity:
        pop = np.full(len(functions), 1.0 / len(functions))
    else:
        pop = function_popularity(functions, rng)

    arrivals: List[Arrival] = []
    for minute, count in enumerate(per_minute):
        starts = rng.uniform(minute * 60.0, (minute + 1) * 60.0, size=count)
        starts.sort()
        fns = rng.choice(len(functions), size=count, p=pop)
        for t, fi in zip(starts, fns):
            fn = functions[fi]
            idx = int(rng.integers(inputs_per_function[fn]))
            arrivals.append(
                Arrival(next(_inv_ids), float(t), fn, idx)
            )
    arrivals.sort(key=lambda a: a.t)
    return arrivals
