"""Serving runtime: engine, cluster simulator, workload, profiles, baselines."""
