"""Optimizers in pure JAX: AdamW with optional bf16 moments, grad clip, schedules.

No optax dependency — the optimizer state mirrors the parameter pytree
(sharded identically), which is what the FSDP sharding rules rely on.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    # bf16 moments halve optimizer memory — used for the >=100B archs.
    moment_dtype: str = "float32"
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1


def lr_schedule(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    """Linear warmup + cosine decay."""
    step = step.astype(jnp.float32)
    warm = step / jnp.maximum(cfg.warmup_steps, 1)
    prog = (step - cfg.warmup_steps) / jnp.maximum(
        cfg.total_steps - cfg.warmup_steps, 1
    )
    prog = jnp.clip(prog, 0.0, 1.0)
    cos = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * jnp.where(step < cfg.warmup_steps, warm, cos)


def init_opt_state(cfg: AdamWConfig, params) -> Dict[str, Any]:
    mdt = jnp.dtype(cfg.moment_dtype)
    zeros = lambda p: jnp.zeros(p.shape, mdt)
    return {
        "step": jnp.zeros((), jnp.int32),
        "m": jax.tree_util.tree_map(zeros, params),
        "v": jax.tree_util.tree_map(zeros, params),
    }


def global_norm(tree) -> jax.Array:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in leaves)
    )


def _is_matrix(p: jax.Array) -> bool:
    # weight decay only on >=2D tensors (skips norms, biases, scalars)
    return p.ndim >= 2


def adamw_update(
    cfg: AdamWConfig, params, grads, opt_state
) -> Tuple[Any, Dict[str, Any], Dict[str, jax.Array]]:
    """One AdamW step. Returns (new_params, new_opt_state, metrics)."""
    step = opt_state["step"] + 1
    gnorm = global_norm(grads)
    clip = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9))
    lr = lr_schedule(cfg, step)
    b1, b2 = cfg.b1, cfg.b2
    mdt = jnp.dtype(cfg.moment_dtype)
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * clip
        m32 = m.astype(jnp.float32) * b1 + (1 - b1) * g
        v32 = v.astype(jnp.float32) * b2 + (1 - b2) * jnp.square(g)
        mhat = m32 / bc1
        vhat = v32 / bc2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if _is_matrix(p):
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        newp = p.astype(jnp.float32) - lr * delta
        return newp.astype(p.dtype), m32.astype(mdt), v32.astype(mdt)

    flat_p, treedef = jax.tree_util.tree_flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(opt_state["m"])
    flat_v = treedef.flatten_up_to(opt_state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree_util.tree_unflatten(treedef, [o[0] for o in out])
    new_m = jax.tree_util.tree_unflatten(treedef, [o[1] for o in out])
    new_v = jax.tree_util.tree_unflatten(treedef, [o[2] for o in out])
    new_state = {"step": step, "m": new_m, "v": new_v}
    return new_p, new_state, {"grad_norm": gnorm, "lr": lr}
