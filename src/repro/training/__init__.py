"""Training substrate: optimizer, data pipeline, checkpointing, train loop."""
