"""Checkpointing: msgpack-serialized pytrees with shape/dtype manifest.

No orbax dependency — arrays are flattened by tree path, each leaf
stored as raw bytes + (shape, dtype), with an atomic rename commit so a
killed run never leaves a half-written checkpoint. Works for params,
optimizer state, and data-pipeline step in one bundle.
"""

from __future__ import annotations

import json
import os
import tempfile
from pathlib import Path
from typing import Any, Dict, Tuple

import jax
import msgpack
import numpy as np


def _flatten(tree) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(
            str(getattr(p, "key", getattr(p, "idx", p))) for p in path
        )
        flat[key] = np.asarray(leaf)
    return flat


def save_checkpoint(path: str, *, step: int, params, opt_state=None,
                    extra: Dict[str, Any] = None) -> str:
    """Write an atomic checkpoint bundle; returns the final path."""
    out = Path(path)
    out.parent.mkdir(parents=True, exist_ok=True)
    bundles = {"params": _flatten(params)}
    if opt_state is not None:
        bundles["opt_state"] = _flatten(opt_state)
    manifest = {"step": step, "extra": extra or {}, "bundles": {}}
    payload: Dict[str, bytes] = {}
    for bname, flat in bundles.items():
        man = {}
        for key, arr in flat.items():
            bkey = f"{bname}:{key}"
            payload[bkey] = arr.tobytes()
            man[key] = {"shape": list(arr.shape), "dtype": str(arr.dtype)}
        manifest["bundles"][bname] = man
    blob = msgpack.packb(
        {"manifest": json.dumps(manifest), "data": payload},
        use_bin_type=True,
    )
    with tempfile.NamedTemporaryFile(
        dir=out.parent, delete=False, suffix=".tmp"
    ) as f:
        f.write(blob)
        tmp = f.name
    os.replace(tmp, out)  # atomic commit
    return str(out)


def load_checkpoint(path: str) -> Dict[str, Any]:
    """Returns {step, extra, params, opt_state?} with numpy leaves keyed
    by tree path (use ``restore_into`` to rebuild a pytree)."""
    blob = msgpack.unpackb(Path(path).read_bytes(), raw=False)
    manifest = json.loads(blob["manifest"])
    out: Dict[str, Any] = {"step": manifest["step"], "extra": manifest["extra"]}
    for bname, man in manifest["bundles"].items():
        flat = {}
        for key, info in man.items():
            arr = np.frombuffer(
                blob["data"][f"{bname}:{key}"], dtype=np.dtype(info["dtype"])
            ).reshape(info["shape"])
            flat[key] = arr
        out[bname] = flat
    return out


def restore_into(template, flat: Dict[str, np.ndarray]):
    """Rebuild a pytree with ``template``'s structure from flat arrays."""
    paths = jax.tree_util.tree_flatten_with_path(template)[0]
    treedef = jax.tree_util.tree_structure(template)
    leaves = []
    for path, leaf in paths:
        key = "/".join(
            str(getattr(p, "key", getattr(p, "idx", p))) for p in path
        )
        arr = flat[key]
        assert tuple(arr.shape) == tuple(leaf.shape), (key, arr.shape, leaf.shape)
        leaves.append(jax.numpy.asarray(arr, dtype=leaf.dtype))
    return jax.tree_util.tree_unflatten(treedef, leaves)
