"""Training loop: jit'd step, metrics, periodic checkpointing, resume."""

from __future__ import annotations

import dataclasses
import time
from pathlib import Path
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models.model import init_params
from repro.training.checkpoint import load_checkpoint, restore_into, save_checkpoint
from repro.training.data import DataConfig, SyntheticTokenPipeline
from repro.training.optimizer import AdamWConfig, adamw_update, init_opt_state


@dataclasses.dataclass
class TrainLoopConfig:
    steps: int = 200
    log_every: int = 10
    ckpt_every: int = 100
    ckpt_dir: Optional[str] = None
    seed: int = 0
    remat: bool = False  # small models on CPU don't need it


def train(
    cfg: ModelConfig,
    *,
    data_cfg: DataConfig,
    opt_cfg: Optional[AdamWConfig] = None,
    loop: Optional[TrainLoopConfig] = None,
    resume_from: Optional[str] = None,
    extra_batch_fn: Optional[Callable[[int], Dict]] = None,
) -> Dict[str, List[float]]:
    """Train; returns the metric history. CPU-friendly for the examples
    (reduced configs, ~100M params, a few hundred steps)."""
    from repro.models.model import forward_train

    loop = loop or TrainLoopConfig()
    opt_cfg = opt_cfg or AdamWConfig(total_steps=loop.steps)
    pipe = SyntheticTokenPipeline(data_cfg)

    key = jax.random.PRNGKey(loop.seed)
    params = init_params(key, cfg)
    opt_state = init_opt_state(opt_cfg, params)
    start_step = 0
    if resume_from:
        bundle = load_checkpoint(resume_from)
        params = restore_into(params, bundle["params"])
        opt_state = restore_into(opt_state, bundle["opt_state"])
        start_step = bundle["step"]

    @jax.jit
    def step_fn(params, opt_state, batch):
        def loss_fn(p):
            return forward_train(
                p, cfg, batch["tokens"], batch["labels"],
                patch_embeds=batch.get("patch_embeds"),
                frame_embeds=batch.get("frame_embeds"),
                remat=loop.remat,
            )

        (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(params)
        params, opt_state, opt_metrics = adamw_update(
            opt_cfg, params, grads, opt_state
        )
        return params, opt_state, dict(metrics, loss=loss, **opt_metrics)

    history: Dict[str, List[float]] = {"step": [], "loss": [], "grad_norm": [],
                                       "tokens_per_s": []}
    t_last = time.time()
    for step in range(start_step, loop.steps):
        batch = {k: jnp.asarray(v) for k, v in pipe.batch_at(step).items()}
        if extra_batch_fn is not None:
            batch.update(extra_batch_fn(step))
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        if (step + 1) % loop.log_every == 0 or step == start_step:
            loss = float(metrics["loss"])
            gn = float(metrics["grad_norm"])
            dt = time.time() - t_last
            tps = data_cfg.batch_size * data_cfg.seq_len * loop.log_every / max(dt, 1e-9)
            t_last = time.time()
            history["step"].append(step + 1)
            history["loss"].append(loss)
            history["grad_norm"].append(gn)
            history["tokens_per_s"].append(tps)
            print(f"step {step+1:5d} loss={loss:.4f} grad_norm={gn:.3f} tok/s={tps:,.0f}")
        if loop.ckpt_dir and (step + 1) % loop.ckpt_every == 0:
            path = Path(loop.ckpt_dir) / f"ckpt_{step+1:06d}.msgpack"
            save_checkpoint(str(path), step=step + 1, params=params,
                            opt_state=opt_state)
    history["final_params"] = params  # type: ignore[assignment]
    return history
