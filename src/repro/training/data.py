"""Synthetic token data pipeline.

A deterministic, seekable stream of language-model batches: documents
are sampled from a Zipfian unigram-with-bigram-structure generator (so
the loss actually decreases during the example training runs — a model
can learn the bigram statistics), packed to fixed-length sequences, and
served as (tokens, labels) with next-token labels. Restartable from a
step index for checkpoint resume.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional, Tuple

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    batch_size: int
    seed: int = 0
    # bigram structure strength: 0 = iid tokens, 1 = fully deterministic
    bigram_strength: float = 0.7
    n_bigram_states: int = 64


class SyntheticTokenPipeline:
    """Deterministic batch source; ``batch_at(step)`` is random-access."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        root = np.random.default_rng(cfg.seed)
        V = cfg.vocab_size
        # zipfian unigram distribution
        ranks = np.arange(1, V + 1, dtype=np.float64)
        self._unigram = (1.0 / ranks**1.1)
        self._unigram /= self._unigram.sum()
        # latent bigram chain: each state prefers a band of tokens
        S = cfg.n_bigram_states
        self._state_of_token = root.integers(0, S, size=V)
        self._next_state = root.integers(0, S, size=S)
        self._band = root.integers(0, V - 16, size=S)

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        cfg = self.cfg
        rng = np.random.default_rng((cfg.seed, step))
        B, L, V = cfg.batch_size, cfg.seq_len, cfg.vocab_size
        toks = np.empty((B, L + 1), np.int32)
        toks[:, 0] = rng.choice(V, size=B, p=self._unigram)
        for t in range(1, L + 1):
            prev_state = self._state_of_token[toks[:, t - 1]]
            nxt = self._next_state[prev_state]
            band_tok = self._band[nxt] + rng.integers(0, 16, size=B)
            iid_tok = rng.choice(V, size=B, p=self._unigram)
            use_band = rng.random(B) < cfg.bigram_strength
            toks[:, t] = np.where(use_band, band_tok, iid_tok)
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1
