"""Mixtral-8x7B — sparse MoE decoder, 8 experts top-2, sliding-window attn.

32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=32000. [arXiv:2401.04088]
"""

from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="mixtral-8x7b",
        family="moe",
        source="arXiv:2401.04088 (Mixtral of Experts)",
        num_layers=32,
        d_model=4096,
        num_heads=32,
        num_kv_heads=8,
        head_dim=128,
        d_ff=14336,
        vocab_size=32000,
        mlp_type="swiglu",
        num_experts=8,
        experts_per_token=2,
        sliding_window=4096,
        rope_theta=1_000_000.0,
    )


def reduced_config() -> ModelConfig:
    return ModelConfig(
        name="mixtral-8x7b-reduced",
        family="moe",
        source="reduced smoke variant",
        num_layers=2,
        d_model=256,
        num_heads=4,
        num_kv_heads=2,
        head_dim=64,
        d_ff=512,
        vocab_size=1024,
        mlp_type="swiglu",
        num_experts=4,
        experts_per_token=2,
        sliding_window=128,
        rope_theta=1_000_000.0,
    )
