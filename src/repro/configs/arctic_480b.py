"""Snowflake Arctic-480B — 128-expert top-2 MoE with dense residual path.

35L d_model=7168 56H (GQA kv=8) d_ff=4864 vocab=32000, MoE 128e top-2.
[hf:Snowflake/snowflake-arctic-base]
"""

from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="arctic-480b",
        family="moe",
        source="hf:Snowflake/snowflake-arctic-base",
        num_layers=35,
        d_model=7168,
        num_heads=56,
        num_kv_heads=8,
        head_dim=128,
        d_ff=4864,
        vocab_size=32000,
        mlp_type="swiglu",
        num_experts=128,
        experts_per_token=2,
        dense_residual=True,  # dense FFN residual in parallel with MoE
        rope_theta=10_000.0,
    )


def reduced_config() -> ModelConfig:
    return ModelConfig(
        name="arctic-480b-reduced",
        family="moe",
        source="reduced smoke variant",
        num_layers=2,
        d_model=256,
        num_heads=4,
        num_kv_heads=2,
        head_dim=64,
        d_ff=256,
        vocab_size=1024,
        mlp_type="swiglu",
        num_experts=4,
        experts_per_token=2,
        dense_residual=True,
        rope_theta=10_000.0,
    )
