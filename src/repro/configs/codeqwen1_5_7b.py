"""CodeQwen1.5-7B — dense MHA-style decoder (kv=32), qwen1.5 architecture.

32L d_model=4096 32H (GQA kv=32) d_ff=13440 vocab=92416.
[hf:Qwen/CodeQwen1.5-7B]
"""

from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="codeqwen1.5-7b",
        family="dense",
        source="hf:Qwen/CodeQwen1.5-7B",
        num_layers=32,
        d_model=4096,
        num_heads=32,
        num_kv_heads=32,
        head_dim=128,
        d_ff=13440,
        vocab_size=92416,
        mlp_type="swiglu",
        qkv_bias=True,
        rope_theta=1_000_000.0,
    )


def reduced_config() -> ModelConfig:
    return ModelConfig(
        name="codeqwen1.5-7b-reduced",
        family="dense",
        source="reduced smoke variant",
        num_layers=2,
        d_model=256,
        num_heads=4,
        num_kv_heads=4,
        head_dim=64,
        d_ff=512,
        vocab_size=1024,
        mlp_type="swiglu",
        qkv_bias=True,
        rope_theta=1_000_000.0,
    )
