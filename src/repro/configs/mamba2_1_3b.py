"""Mamba2-1.3B — attention-free SSM with SSD (state-space duality).

48L d_model=2048 (attn-free) vocab=50280, ssm_state=128. [arXiv:2405.21060]
"""

from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="mamba2-1.3b",
        family="ssm",
        source="arXiv:2405.21060 (Mamba-2 / SSD)",
        num_layers=48,
        d_model=2048,
        vocab_size=50280,
        ssm_state=128,
        ssm_expand=2,
        ssm_head_dim=64,   # 64 heads at d_inner=4096
        ssm_chunk=256,
        conv_width=4,
    )


def reduced_config() -> ModelConfig:
    return ModelConfig(
        name="mamba2-1.3b-reduced",
        family="ssm",
        source="reduced smoke variant",
        num_layers=2,
        d_model=256,
        vocab_size=1024,
        ssm_state=32,
        ssm_expand=2,
        ssm_head_dim=64,
        ssm_chunk=64,
        conv_width=4,
    )
