"""Nemotron-4-15B — dense GQA decoder with squared-ReLU MLP.

32L d_model=6144 48H (GQA kv=8) d_ff=24576 vocab=256000. [arXiv:2402.16819]
"""

from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="nemotron-4-15b",
        family="dense",
        source="arXiv:2402.16819 (Nemotron-4 15B)",
        num_layers=32,
        d_model=6144,
        num_heads=48,
        num_kv_heads=8,
        head_dim=128,
        d_ff=24576,
        vocab_size=256000,
        mlp_type="squared_relu",
        rope_theta=10_000.0,
    )


def reduced_config() -> ModelConfig:
    return ModelConfig(
        name="nemotron-4-15b-reduced",
        family="dense",
        source="reduced smoke variant",
        num_layers=2,
        d_model=256,
        num_heads=4,
        num_kv_heads=2,
        head_dim=64,
        d_ff=1024,
        vocab_size=1024,
        mlp_type="squared_relu",
        rope_theta=10_000.0,
    )
