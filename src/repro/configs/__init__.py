"""Config registry: one module per assigned architecture.

``get_config(arch_id)`` returns the full production config;
``get_reduced_config(arch_id)`` returns the CPU-smoke-testable variant of
the same family (<=2 layers, d_model<=512, <=4 experts).
"""

from __future__ import annotations

import importlib
from typing import Dict, List

from repro.configs.base import (
    DECODE_32K,
    LONG_500K,
    PREFILL_32K,
    SHAPES,
    TRAIN_4K,
    ModelConfig,
    ShapeConfig,
    cache_specs,
    decoder_seq_len,
    effective_decode_window,
    input_specs,
    shape_applicable,
)

ARCH_IDS: List[str] = [
    "qwen2_5_3b",
    "mixtral_8x7b",
    "nemotron_4_15b",
    "internvl2_76b",
    "mamba2_1_3b",
    "arctic_480b",
    "codeqwen1_5_7b",
    "whisper_tiny",
    "zamba2_7b",
    "phi3_mini_3_8b",
]

# CLI ids with dashes/dots map onto module names.
_ALIASES = {
    "qwen2.5-3b": "qwen2_5_3b",
    "mixtral-8x7b": "mixtral_8x7b",
    "nemotron-4-15b": "nemotron_4_15b",
    "internvl2-76b": "internvl2_76b",
    "mamba2-1.3b": "mamba2_1_3b",
    "arctic-480b": "arctic_480b",
    "codeqwen1.5-7b": "codeqwen1_5_7b",
    "whisper-tiny": "whisper_tiny",
    "zamba2-7b": "zamba2_7b",
    "phi3-mini-3.8b": "phi3_mini_3_8b",
}


def canonical_id(arch: str) -> str:
    return _ALIASES.get(arch, arch)


def get_config(arch: str) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{canonical_id(arch)}")
    cfg: ModelConfig = mod.config()
    cfg.validate()
    return cfg


def get_reduced_config(arch: str) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{canonical_id(arch)}")
    cfg: ModelConfig = mod.reduced_config()
    cfg.validate()
    assert cfg.num_layers <= 2 and cfg.d_model <= 512
    assert cfg.num_experts <= 4
    return cfg


def all_configs() -> Dict[str, ModelConfig]:
    return {a: get_config(a) for a in ARCH_IDS}


__all__ = [
    "ARCH_IDS",
    "ModelConfig",
    "ShapeConfig",
    "SHAPES",
    "TRAIN_4K",
    "PREFILL_32K",
    "DECODE_32K",
    "LONG_500K",
    "get_config",
    "get_reduced_config",
    "all_configs",
    "canonical_id",
    "input_specs",
    "cache_specs",
    "shape_applicable",
    "effective_decode_window",
    "decoder_seq_len",
]
