"""Configuration system for the repro framework.

Two families of config live here:

* :class:`ModelConfig` — a full architectural description of one of the
  assigned architectures (or a reduced smoke variant of the same family).
* :class:`ShapeConfig` — one of the four assigned input shapes
  (train_4k / prefill_32k / decode_32k / long_500k).

``input_specs(model_cfg, shape_cfg)`` produces ``jax.ShapeDtypeStruct``
stand-ins for every input of the step function that the shape lowers
(``train_step`` for training shapes, ``serve_step`` for decode shapes),
so the multi-pod dry-run can ``.lower().compile()`` without allocating.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

# ---------------------------------------------------------------------------
# Model configuration
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Architecture description. One instance per assigned architecture."""

    name: str
    family: str  # dense | moe | ssm | hybrid | vlm | audio
    source: str  # citation for the config (paper / model card)

    num_layers: int = 0
    d_model: int = 0
    num_heads: int = 0
    num_kv_heads: int = 0
    head_dim: int = 0
    d_ff: int = 0
    vocab_size: int = 0

    # --- MLP / activation ---------------------------------------------------
    mlp_type: str = "swiglu"  # swiglu | squared_relu
    # --- attention ----------------------------------------------------------
    qkv_bias: bool = False
    rope_theta: float = 10_000.0
    sliding_window: Optional[int] = None  # native SWA (mixtral)
    # Window used only for the long_500k sub-quadratic dense variant.
    long_context_window: int = 8192
    supports_long_context: bool = True  # whisper sets False
    # --- MoE ----------------------------------------------------------------
    num_experts: int = 0
    experts_per_token: int = 0
    dense_residual: bool = False  # arctic: dense FFN in parallel with MoE
    router_aux_weight: float = 0.01
    # --- SSM (mamba2 / zamba2) ----------------------------------------------
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_chunk: int = 256
    conv_width: int = 4
    # --- hybrid (zamba2): one shared attention block applied every N layers --
    attn_every: int = 0
    # --- encoder-decoder (whisper) --------------------------------------------
    is_encoder_decoder: bool = False
    encoder_layers: int = 0
    encoder_seq: int = 0  # audio frames after the (stubbed) conv frontend
    max_target_positions: int = 0  # whisper decoder positional cap
    # --- vlm frontend stub ----------------------------------------------------
    frontend_tokens: int = 0  # precomputed patch embeddings prepended to text
    # --- numerics -------------------------------------------------------------
    dtype: str = "bfloat16"
    norm_eps: float = 1e-5

    # ----------------------------------------------------------------- helpers
    @property
    def q_dim(self) -> int:
        return self.num_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.num_kv_heads * self.head_dim

    @property
    def d_inner(self) -> int:
        """Mamba2 inner width."""
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim if self.ssm_head_dim else 0

    @property
    def uses_attention(self) -> bool:
        return self.family != "ssm"

    @property
    def uses_ssm(self) -> bool:
        return self.family in ("ssm", "hybrid")

    def param_count(self) -> int:
        """Analytic parameter count (exact for our implementation)."""
        from repro.models.model import count_params_analytic

        return count_params_analytic(self)

    def active_param_count(self) -> int:
        from repro.models.model import count_params_analytic

        return count_params_analytic(self, active_only=True)

    def validate(self) -> None:
        if self.family in ("dense", "moe", "vlm", "audio", "hybrid"):
            assert self.num_heads > 0 and self.head_dim > 0
            assert self.num_heads % self.num_kv_heads == 0, self.name
        if self.family in ("moe",):
            assert self.num_experts > 1 and self.experts_per_token >= 1
        if self.family in ("ssm", "hybrid"):
            assert self.ssm_state > 0
            assert self.d_inner % self.ssm_head_dim == 0
        if self.family == "audio":
            assert self.is_encoder_decoder


# ---------------------------------------------------------------------------
# Input shapes
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One of the four assigned (seq_len, global_batch) input shapes."""

    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


TRAIN_4K = ShapeConfig("train_4k", 4_096, 256, "train")
PREFILL_32K = ShapeConfig("prefill_32k", 32_768, 32, "prefill")
DECODE_32K = ShapeConfig("decode_32k", 32_768, 128, "decode")
LONG_500K = ShapeConfig("long_500k", 524_288, 1, "decode")

SHAPES: Dict[str, ShapeConfig] = {
    s.name: s for s in (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)
}


def shape_applicable(cfg: ModelConfig, shape: ShapeConfig) -> bool:
    """Whether (arch, shape) is runnable (see DESIGN.md §4 for skips)."""
    if shape.name == "long_500k":
        # Needs sub-quadratic attention. SSM/hybrid are native; SWA archs
        # are native; pure-dense archs use the explicit sliding-window
        # variant (supports_long_context). whisper opts out (448-pos cap).
        return cfg.supports_long_context
    return True


def effective_decode_window(cfg: ModelConfig, shape: ShapeConfig) -> int:
    """KV entries actually cached at decode time for (arch, shape).

    Full-attention archs cache the whole context for decode_32k; for
    long_500k every attention arch runs windowed (native SWA window or the
    long-context variant window). SSM layers never appear here.
    """
    if not cfg.uses_attention:
        return 0
    if cfg.sliding_window is not None:
        return min(cfg.sliding_window, shape.seq_len)
    if shape.name == "long_500k":
        return min(cfg.long_context_window, shape.seq_len)
    if cfg.is_encoder_decoder and cfg.max_target_positions:
        return min(cfg.max_target_positions, shape.seq_len)
    return shape.seq_len


def decoder_seq_len(cfg: ModelConfig, shape: ShapeConfig) -> int:
    """Sequence length seen by the decoder (whisper caps at 448)."""
    if cfg.is_encoder_decoder and cfg.max_target_positions:
        return min(cfg.max_target_positions, shape.seq_len)
    return shape.seq_len


# ---------------------------------------------------------------------------
# ShapeDtypeStruct input specs for the dry-run
# ---------------------------------------------------------------------------


def _sds(shape, dtype) -> jax.ShapeDtypeStruct:
    return jax.ShapeDtypeStruct(tuple(int(x) for x in shape), dtype)


def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, Any]:
    """ShapeDtypeStruct stand-ins for every model input of the step the
    shape lowers. Keys match the keyword arguments of the step functions in
    ``repro.launch``. No device allocation happens here.
    """
    B = shape.global_batch
    S = decoder_seq_len(cfg, shape)
    dt = jnp.dtype(cfg.dtype)
    i32 = jnp.int32

    specs: Dict[str, Any] = {}
    if shape.kind == "train":
        text = S - cfg.frontend_tokens if cfg.family == "vlm" else S
        specs["tokens"] = _sds((B, text), i32)
        specs["labels"] = _sds((B, text), i32)
        if cfg.family == "vlm":
            specs["patch_embeds"] = _sds((B, cfg.frontend_tokens, cfg.d_model), dt)
        if cfg.is_encoder_decoder:
            specs["frame_embeds"] = _sds((B, cfg.encoder_seq, cfg.d_model), dt)
    elif shape.kind == "prefill":
        text = S - cfg.frontend_tokens if cfg.family == "vlm" else S
        specs["tokens"] = _sds((B, text), i32)
        if cfg.family == "vlm":
            specs["patch_embeds"] = _sds((B, cfg.frontend_tokens, cfg.d_model), dt)
        if cfg.is_encoder_decoder:
            specs["frame_embeds"] = _sds((B, cfg.encoder_seq, cfg.d_model), dt)
    elif shape.kind == "decode":
        specs["token"] = _sds((B,), i32)
        specs["cache"] = cache_specs(cfg, shape)
        if cfg.is_encoder_decoder:
            # Cross-attention reads encoder output kept in the cache specs.
            pass
    else:  # pragma: no cover
        raise ValueError(shape.kind)
    return specs


def cache_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, Any]:
    """ShapeDtypeStructs for the decode cache pytree (KV and/or SSM state)."""
    from repro.models.kv_cache import cache_shapes

    shapes = cache_shapes(cfg, shape)
    return jax.tree_util.tree_map(
        lambda sd: _sds(sd[0], sd[1]), shapes, is_leaf=lambda x: isinstance(x, tuple)
    )
