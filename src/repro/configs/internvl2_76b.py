"""InternVL2-76B — VLM: InternViT frontend (stub) + Llama3-70B-class backbone.

80L d_model=8192 64H (GQA kv=8) d_ff=28672 vocab=128256. [arXiv:2404.16821]

The vision encoder + projector are a STUB per the assignment: ``input_specs``
provides precomputed patch embeddings (frontend_tokens x d_model) that the
language transformer consumes alongside text tokens.
"""

from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="internvl2-76b",
        family="vlm",
        source="arXiv:2404.16821 (InternVL2; InternViT + LLM backbone)",
        num_layers=80,
        d_model=8192,
        num_heads=64,
        num_kv_heads=8,
        head_dim=128,
        d_ff=28672,
        vocab_size=128256,
        mlp_type="swiglu",
        rope_theta=500_000.0,
        frontend_tokens=256,  # one image tile -> 256 visual tokens
    )


def reduced_config() -> ModelConfig:
    return ModelConfig(
        name="internvl2-76b-reduced",
        family="vlm",
        source="reduced smoke variant",
        num_layers=2,
        d_model=256,
        num_heads=4,
        num_kv_heads=2,
        head_dim=64,
        d_ff=512,
        vocab_size=1024,
        mlp_type="swiglu",
        rope_theta=500_000.0,
        frontend_tokens=16,
    )
