"""Phi-3-mini-3.8B — dense decoder, RoPE + SwiGLU + GQA(kv=32 ~ MHA).

32L d_model=3072 32H (GQA kv=32) d_ff=8192 vocab=32064. [arXiv:2404.14219]
"""

from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="phi3-mini-3.8b",
        family="dense",
        source="arXiv:2404.14219 (Phi-3)",
        num_layers=32,
        d_model=3072,
        num_heads=32,
        num_kv_heads=32,
        head_dim=96,
        d_ff=8192,
        vocab_size=32064,
        mlp_type="swiglu",
        rope_theta=10_000.0,
    )


def reduced_config() -> ModelConfig:
    return ModelConfig(
        name="phi3-mini-3.8b-reduced",
        family="dense",
        source="reduced smoke variant",
        num_layers=2,
        d_model=256,
        num_heads=4,
        num_kv_heads=4,
        head_dim=64,
        d_ff=512,
        vocab_size=1024,
        mlp_type="swiglu",
        rope_theta=10_000.0,
    )
