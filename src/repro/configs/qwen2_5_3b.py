"""Qwen2.5-3B — dense GQA decoder with QKV bias.

36L d_model=2048 16H (GQA kv=2) d_ff=11008 vocab=151936.
[hf:Qwen/Qwen2.5-0.5B family config, 3B scale point]
"""

from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen2.5-3b",
        family="dense",
        source="hf:Qwen/Qwen2.5-0.5B (family); Qwen2.5 tech report",
        num_layers=36,
        d_model=2048,
        num_heads=16,
        num_kv_heads=2,
        head_dim=128,
        d_ff=11008,
        vocab_size=151936,
        mlp_type="swiglu",
        qkv_bias=True,
        rope_theta=1_000_000.0,
    )


def reduced_config() -> ModelConfig:
    return ModelConfig(
        name="qwen2.5-3b-reduced",
        family="dense",
        source="reduced smoke variant",
        num_layers=2,
        d_model=256,
        num_heads=4,
        num_kv_heads=2,
        head_dim=64,
        d_ff=512,
        vocab_size=1024,
        mlp_type="swiglu",
        qkv_bias=True,
        rope_theta=1_000_000.0,
    )
