"""Whisper-tiny — encoder-decoder audio transformer, conv frontend stubbed.

4L d_model=384 6H (kv=6) d_ff=1536 vocab=51865. [arXiv:2212.04356]

The mel-spectrogram + conv feature extractor is a STUB per the assignment:
``input_specs`` provides precomputed frame embeddings (1500 x d_model).
The decoder positional embedding caps the target length at 448 tokens, so
long_500k is skipped for this arch (DESIGN.md §4).
"""

from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="whisper-tiny",
        family="audio",
        source="arXiv:2212.04356 (Whisper)",
        num_layers=4,          # decoder layers
        d_model=384,
        num_heads=6,
        num_kv_heads=6,
        head_dim=64,
        d_ff=1536,
        vocab_size=51865,
        mlp_type="swiglu",
        is_encoder_decoder=True,
        encoder_layers=4,
        encoder_seq=1500,      # 30 s audio -> 1500 frames post-conv
        max_target_positions=448,
        supports_long_context=False,
        rope_theta=10_000.0,
    )


def reduced_config() -> ModelConfig:
    return ModelConfig(
        name="whisper-tiny-reduced",
        family="audio",
        source="reduced smoke variant",
        num_layers=2,
        d_model=128,
        num_heads=2,
        num_kv_heads=2,
        head_dim=64,
        d_ff=256,
        vocab_size=1024,
        mlp_type="swiglu",
        is_encoder_decoder=True,
        encoder_layers=2,
        encoder_seq=64,
        max_target_positions=448,
        supports_long_context=False,
        rope_theta=10_000.0,
    )
