"""Zamba2-7B — hybrid: Mamba2 backbone + shared attention blocks.

81L d_model=3584 32H (GQA kv=32) d_ff=14336 vocab=32000, ssm_state=64.
[arXiv:2411.15242]

Structure follows the Zamba2 pattern: the backbone is Mamba2 blocks; a
single SHARED attention+MLP block (one parameter set) is applied every
``attn_every`` layers, consuming the concatenated residual stream.
"""

from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="zamba2-7b",
        family="hybrid",
        source="arXiv:2411.15242 (Zamba2)",
        num_layers=81,
        d_model=3584,
        num_heads=32,
        num_kv_heads=32,
        head_dim=112,
        d_ff=14336,
        vocab_size=32000,
        mlp_type="swiglu",
        ssm_state=64,
        ssm_expand=2,
        ssm_head_dim=64,
        ssm_chunk=256,
        conv_width=4,
        attn_every=6,  # shared attention block applied every 6 mamba layers
        rope_theta=10_000.0,
    )


def reduced_config() -> ModelConfig:
    return ModelConfig(
        name="zamba2-7b-reduced",
        family="hybrid",
        source="reduced smoke variant",
        num_layers=2,
        d_model=256,
        num_heads=4,
        num_kv_heads=4,
        head_dim=64,
        d_ff=512,
        vocab_size=1024,
        mlp_type="swiglu",
        ssm_state=32,
        ssm_expand=2,
        ssm_head_dim=64,
        ssm_chunk=64,
        conv_width=4,
        attn_every=2,
        rope_theta=10_000.0,
    )
