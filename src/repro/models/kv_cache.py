"""Decode-time caches: ring-buffer KV caches and SSM states.

Layers are SCANNED (params stacked on a leading layer axis — see
``repro.models.model``), so caches are stacked too:

* ``k``, ``v``     : (L_attn, B, W, Hkv, hd)   — self-attention KV
* ``conv``         : (L_ssm, B, K-1, C)        — mamba conv window
* ``ssd``          : (L_ssm, B, H, P, N) f32   — mamba SSD state
* ``xk``, ``xv``   : (L_dec, B, S_enc, Hkv, hd) — whisper cross-attn KV
* ``pos``          : (B,) int32                — tokens generated so far

W is the *effective* window (full context for decode_32k full-attention
archs; the SWA / long-context window otherwise). Keys are RoPE'd at their
absolute position before caching, so ring-buffer slots stay consistent.
"""

from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import (
    ModelConfig,
    ShapeConfig,
    effective_decode_window,
)

CacheShapes = Dict[str, Tuple[Tuple[int, ...], Any]]


def conv_dim(cfg: ModelConfig) -> int:
    """Channels entering the causal conv: x plus B and C (n_groups = 1)."""
    return cfg.d_inner + 2 * cfg.ssm_state


def num_attn_layers(cfg: ModelConfig) -> int:
    if cfg.family in ("dense", "moe", "vlm", "audio"):
        return cfg.num_layers
    if cfg.family == "hybrid":
        # one shared block applied every attn_every mamba layers
        return (cfg.num_layers + cfg.attn_every - 1) // cfg.attn_every
    return 0


def num_ssm_layers(cfg: ModelConfig) -> int:
    return cfg.num_layers if cfg.family in ("ssm", "hybrid") else 0


def cache_shapes(cfg: ModelConfig, shape: ShapeConfig) -> CacheShapes:
    B = shape.global_batch
    W = effective_decode_window(cfg, shape)
    dt = jnp.dtype(cfg.dtype)
    out: CacheShapes = {"pos": ((B,), jnp.int32)}
    La, Ls = num_attn_layers(cfg), num_ssm_layers(cfg)
    if La:
        out["k"] = ((La, B, W, cfg.num_kv_heads, cfg.head_dim), dt)
        out["v"] = ((La, B, W, cfg.num_kv_heads, cfg.head_dim), dt)
    if Ls:
        out["conv"] = ((Ls, B, cfg.conv_width - 1, conv_dim(cfg)), dt)
        out["ssd"] = ((Ls, B, cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state), jnp.float32)
    if cfg.is_encoder_decoder:
        out["xk"] = ((cfg.num_layers, B, cfg.encoder_seq, cfg.num_kv_heads, cfg.head_dim), dt)
        out["xv"] = ((cfg.num_layers, B, cfg.encoder_seq, cfg.num_kv_heads, cfg.head_dim), dt)
    return out


def init_cache(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, jax.Array]:
    return {
        name: jnp.zeros(shp, dtype)
        for name, (shp, dtype) in cache_shapes(cfg, shape).items()
    }


# ---------------------------------------------------------------------------
# Ring-buffer helpers (single layer views — scan bodies see one layer)
# ---------------------------------------------------------------------------


# Ring-write formulation. "onehot" (baseline) = masked multiply-add:
# reads+writes the whole buffer and, on a W-sharded cache, makes GSPMD
# re-materialize it in fp32 every step (the dominant decode collective,
# §Perf). "scatter" = one-row dynamic scatter per batch element, which
# stays shard-local.
_RING_MODE = "onehot"


def set_ring_mode(name: str) -> None:
    global _RING_MODE
    assert name in ("onehot", "scatter")
    _RING_MODE = name


def ring_write(buf: jax.Array, new: jax.Array, pos: jax.Array) -> jax.Array:
    """Write one entry per batch row at slot pos % W.

    buf: (B, W, ...); new: (B, ...) (no window axis); pos: (B,) int32.
    """
    W = buf.shape[1]
    slot = (pos % W).astype(jnp.int32)
    if _RING_MODE == "scatter":
        return jax.vmap(lambda b, n, s: b.at[s].set(n))(buf, new, slot)
    onehot = jax.nn.one_hot(slot, W, dtype=buf.dtype)  # (B, W)
    onehot = onehot.reshape(onehot.shape + (1,) * (buf.ndim - 2))
    return buf * (1 - onehot) + new[:, None] * onehot


def ring_positions(pos: jax.Array, W: int) -> jax.Array:
    """Absolute position held by each ring slot *after* ``pos`` writes.

    pos: (B,) -> (B, W) int32; slots never written hold negative values.
    Slot s holds the largest p < pos with p % W == s.
    """
    slots = jnp.arange(W, dtype=jnp.int32)[None, :]
    p = pos[:, None]
    base = (p - 1 - slots) // W * W + slots
    over = base > p - 1
    return jnp.where(over, base - W, base)


def ring_valid(pos: jax.Array, W: int) -> jax.Array:
    """Which ring slots contain live history. pos: (B,) -> (B, W) bool."""
    return ring_positions(pos, W) >= 0


def write_prefill(buf: jax.Array, new: jax.Array) -> jax.Array:
    """Fill one layer's cache with the last W entries of a prefill segment.

    buf: (B, W, ...); new: (B, S, ...). Resulting slot layout matches what
    ring_write would produce after S sequential writes.
    """
    B, W = buf.shape[:2]
    S = new.shape[1]
    if S <= W:
        return buf.at[:, :S].set(new)
    tail = new[:, S - W :]
    abs_pos = jnp.arange(S - W, S, dtype=jnp.int32)
    return buf.at[:, abs_pos % W].set(tail)
