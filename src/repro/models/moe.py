"""Mixture-of-Experts block: top-k router + capacity-based scatter dispatch.

Dispatch is the static-shape scatter/gather formulation (no (T,E,C)
one-hot dispatch tensors): each (token, choice) computes its expert and
slot via a cumulative count, tokens are scattered into per-expert buffers
(E, C, D), experts run as batched matmuls (sharded over the model axis —
XLA inserts the all-to-all-style resharding), and results gather back
weighted by router gates. Top-1 choices are ranked before top-2 so they
are never dropped first. Matches Switch/GShard capacity semantics with
capacity_factor 1.25.

Arctic additionally runs a dense SwiGLU residual path in parallel.
"""

from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import dense_init

Params = Dict[str, jax.Array]

CAPACITY_FACTOR = 1.25

# Sequence chunking for dispatch (§Perf hillclimb): the (E, C, D) expert
# buffers scale with the TOKEN count; at prefill_32k/train_4k scale they
# dominate peak temp memory (arctic: ~1.9 TB/device unchunked). Splitting
# the token axis into N chunks scans the dispatch+compute, dividing peak
# buffer memory by N at identical total FLOPs. 1 = off (baseline).
_SEQ_CHUNKS = 1


def set_moe_seq_chunks(n: int) -> None:
    global _SEQ_CHUNKS
    _SEQ_CHUNKS = max(1, int(n))


def init_moe(key, cfg: ModelConfig) -> Params:
    dt = jnp.dtype(cfg.dtype)
    E, D, F = cfg.num_experts, cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 4)
    scale = D ** -0.5
    p = {
        "router": dense_init(ks[0], D, E, jnp.float32),
        "wg": (jax.random.normal(ks[1], (E, D, F), jnp.float32) * scale).astype(dt),
        "wu": (jax.random.normal(ks[2], (E, D, F), jnp.float32) * scale).astype(dt),
        "wd": (jax.random.normal(ks[3], (E, F, D), jnp.float32) * (F ** -0.5)).astype(dt),
    }
    return p


def moe_capacity(cfg: ModelConfig, num_tokens: int) -> int:
    cap = int(CAPACITY_FACTOR * num_tokens * cfg.experts_per_token / cfg.num_experts)
    return max(8, -(-cap // 8) * 8)  # round up to multiple of 8


def moe_block(
    p: Params,
    cfg: ModelConfig,
    x: jax.Array,  # (B, S, D)
    capacity: Optional[int] = None,
    constrain=None,
) -> Tuple[jax.Array, jax.Array]:
    """Returns (out: (B,S,D), aux_loss scalar fp32)."""
    Bsz, S, D = x.shape
    T = Bsz * S
    nc = _SEQ_CHUNKS
    if nc > 1 and T % nc == 0 and T // nc >= 8:
        # honor the dry-run's full-unroll mode so XLA cost analysis sees
        # every chunk (a while-loop body is counted once)
        from repro.models import model as _model

        unroll = nc if _model._SCAN_UNROLL > 1 else 1
        xt = x.reshape(nc, T // nc, D)

        def body(_, xc):
            return None, _moe_tokens(p, cfg, xc, capacity, constrain)

        _, (ys, auxs) = jax.lax.scan(body, None, xt, unroll=unroll)
        return ys.reshape(Bsz, S, D), jnp.mean(auxs)
    y, aux = _moe_tokens(p, cfg, x.reshape(T, D), capacity, constrain)
    return y.reshape(Bsz, S, D), aux


def _moe_tokens(
    p: Params,
    cfg: ModelConfig,
    xt: jax.Array,  # (T, D)
    capacity: Optional[int] = None,
    constrain=None,
) -> Tuple[jax.Array, jax.Array]:
    if constrain is None:
        constrain = lambda name, v: v
    T, D = xt.shape
    E, K = cfg.num_experts, cfg.experts_per_token
    C = capacity if capacity is not None else moe_capacity(cfg, T)
    logits = (xt.astype(jnp.float32) @ p["router"]).astype(jnp.float32)  # (T,E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_ids = jax.lax.top_k(probs, K)  # (T,K)
    gate_vals = gate_vals / jnp.sum(gate_vals, axis=-1, keepdims=True)

    # --- slot assignment: rank assignments (choice-major so top-1 wins) ----
    flat_expert = expert_ids.T.reshape(T * K)  # choice-major: (K,T) flattened
    onehot = jax.nn.one_hot(flat_expert, E, dtype=jnp.int32)  # (KT, E)
    ranks = jnp.cumsum(onehot, axis=0) - onehot  # rank among same-expert assigns
    slot = jnp.sum(ranks * onehot, axis=-1)  # (KT,)
    keep = slot < C

    # --- scatter tokens into expert buffers --------------------------------
    token_ids = jnp.tile(jnp.arange(T, dtype=jnp.int32), K)
    src = xt[token_ids] * keep[:, None].astype(xt.dtype)
    # Dropped assignments write to a sacrificial slot C (buffer has C+1).
    write_slot = jnp.where(keep, slot, C).astype(jnp.int32)
    buf = jnp.zeros((E, C + 1, D), xt.dtype)
    buf = buf.at[flat_expert, write_slot].add(src)
    buf = constrain("moe_buf", buf[:, :C])

    # --- expert compute (batched over E; sharded over model axis when E
    # divides it, else the capacity dim carries the data axes — without
    # the constraint GSPMD replicates the (E,C,D) buffers and all-reduces
    # them whole (§Perf hillclimb, mixtral prefill) ----------------------
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, p["wg"])) * jnp.einsum(
        "ecd,edf->ecf", buf, p["wu"]
    )
    h = constrain("moe_h", h)
    out_buf = constrain("moe_buf", jnp.einsum("ecf,efd->ecd", h, p["wd"]))

    # --- gather back, weighted by gates -------------------------------------
    out_flat = constrain("moe_tokens", out_buf[flat_expert, write_slot])
    gates_flat = gate_vals.T.reshape(T * K)
    out_flat = out_flat * (gates_flat * keep).astype(out_flat.dtype)[:, None]
    y = jnp.zeros((T, D), out_flat.dtype).at[token_ids].add(out_flat)

    # --- load-balance aux loss (Switch): E * sum_e f_e * P_e ---------------
    me = jnp.mean(probs, axis=0)  # (E,)
    assigned = jax.nn.one_hot(expert_ids[:, 0], E, dtype=jnp.float32)
    ce = jnp.mean(assigned, axis=0)
    aux = E * jnp.sum(me * ce)

    return y, aux


def moe_decode(
    p: Params,
    cfg: ModelConfig,
    x: jax.Array,  # (B, D) — single token per sequence
) -> jax.Array:
    """Decode-time MoE: the same dispatch path with a one-token sequence.

    At decode the per-expert buffers are tiny (capacity ~= B*K/E), so the
    expert matmuls are weight-bandwidth-bound — every expert's weights are
    still read. The roofline analysis flags exactly this regime for MoE
    decode shapes.
    """
    y, _ = moe_block(p, cfg, x[:, None, :])
    return y[:, 0, :]


def moe_param_count(cfg: ModelConfig, active_only: bool = False) -> int:
    E = cfg.experts_per_token if active_only else cfg.num_experts
    n = cfg.d_model * cfg.num_experts  # router
    n += E * 3 * cfg.d_model * cfg.d_ff
    return n
