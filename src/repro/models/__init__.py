"""Pure-JAX model zoo: dense / MoE / SSM / hybrid / enc-dec / VLM families."""

from repro.models.model import (
    count_params,
    count_params_analytic,
    forward_decode,
    forward_prefill,
    forward_train,
    init_params,
)

__all__ = [
    "init_params",
    "forward_train",
    "forward_prefill",
    "forward_decode",
    "count_params",
    "count_params_analytic",
]
