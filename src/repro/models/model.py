"""Model assembly: init / train / prefill / decode for all six families.

Layers are homogeneous within a family, so per-layer parameters are
STACKED on a leading axis and applied with ``jax.lax.scan`` — this keeps
the HLO small (one layer body) and compile times tractable for the 80
multi-pod dry-run compiles. Family quirks:

* hybrid (zamba2): a single SHARED attention block (one parameter set,
  closed over by the scan body) fires every ``attn_every`` mamba layers,
  selected with ``lax.cond`` on the layer index; each firing has its own
  KV-cache slice.
* audio (whisper): a bidirectional encoder scan feeds per-decoder-layer
  cross-attention KV, cached at prefill; learned absolute positions, no
  RoPE.
* vlm (internvl2): stubbed patch embeddings are prepended to the text
  embeddings; loss masks the frontend positions.
"""

from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models import moe as MOE
from repro.models import ssm as SSM
from repro.models.kv_cache import (
    conv_dim,
    num_attn_layers,
    ring_positions,
    ring_valid,
    ring_write,
    write_prefill,
)

Params = Dict[str, Any]

# Scan unroll factor for the layer stack. 1 (default) keeps the HLO small
# for fast test iteration; the dry-run sets it to the layer count because
# XLA's cost analysis counts a while-loop body ONCE — full unroll is the
# only way compiled FLOPs/bytes reflect the whole model (calibrated in
# EXPERIMENTS.md §Dry-run).
_SCAN_UNROLL = 1


def set_scan_unroll(n: int) -> None:
    global _SCAN_UNROLL
    _SCAN_UNROLL = max(1, int(n))


def _scan(body, init, xs, length=None):
    return jax.lax.scan(body, init, xs, unroll=_SCAN_UNROLL)


# Remat policy for the layer-scan body during training. "nothing" =
# recompute everything (min memory); "dots" = save matmul outputs (less
# recompute, more memory). §Perf hillclimbs flip this per arch.
_REMAT_POLICY = "nothing"


def set_remat_policy(name: str) -> None:
    global _REMAT_POLICY
    assert name in ("nothing", "dots")
    _REMAT_POLICY = name


def _checkpoint(body):
    policy = (
        jax.checkpoint_policies.nothing_saveable
        if _REMAT_POLICY == "nothing"
        else jax.checkpoint_policies.dots_with_no_batch_dims_saveable
    )
    return jax.checkpoint(body, policy=policy)


# Decode attention mode. "concat" (baseline) appends the current token's
# K/V to the cache window before attending — on a W-sharded cache the
# (W+1)-long concat forces GSPMD to re-materialize the whole cache every
# step. "split" attends to the cache and the current token separately and
# merges the two partial softmaxes (flash-decode style), touching only
# (B,H,1)-scale tensors outside the sharded cache. §Perf hillclimb knob.
_DECODE_MODE = "concat"


def set_decode_mode(name: str) -> None:
    global _DECODE_MODE
    assert name in ("concat", "split")
    _DECODE_MODE = name


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------


def _init_attn_mlp_block(key, cfg: ModelConfig, cross: bool = False) -> Params:
    ks = jax.random.split(key, 4)
    dt = jnp.dtype(cfg.dtype)
    p: Params = {
        "norm1": jnp.ones((cfg.d_model,), dt),
        "attn": L.init_attention(ks[0], cfg),
        "norm2": jnp.ones((cfg.d_model,), dt),
    }
    if cfg.family == "moe" and not cross:
        p["moe"] = MOE.init_moe(ks[1], cfg)
        if cfg.dense_residual:
            p["dense_mlp"] = L.init_mlp(ks[2], cfg)
    else:
        p["mlp"] = L.init_mlp(ks[1], cfg)
    if cross:
        p["norm_x"] = jnp.ones((cfg.d_model,), dt)
        p["xattn"] = L.init_attention(ks[3], cfg)
    return p


def _init_block(key, cfg: ModelConfig) -> Params:
    dt = jnp.dtype(cfg.dtype)
    if cfg.family in ("dense", "moe", "vlm"):
        return _init_attn_mlp_block(key, cfg)
    if cfg.family == "ssm":
        return {"norm": jnp.ones((cfg.d_model,), dt), "mamba": SSM.init_mamba_block(key, cfg)}
    if cfg.family == "hybrid":
        return {"norm": jnp.ones((cfg.d_model,), dt), "mamba": SSM.init_mamba_block(key, cfg)}
    if cfg.family == "audio":
        return _init_attn_mlp_block(key, cfg, cross=True)
    raise ValueError(cfg.family)


def init_params(key, cfg: ModelConfig) -> Params:
    dt = jnp.dtype(cfg.dtype)
    keys = jax.random.split(key, 8)
    block_keys = jax.random.split(keys[0], cfg.num_layers)
    params: Params = {
        "embed": L.embed_init(keys[1], cfg.vocab_size, cfg.d_model, dt),
        "blocks": jax.vmap(lambda k: _init_block(k, cfg))(block_keys),
        "final_norm": jnp.ones((cfg.d_model,), dt),
        "lm_head": L.dense_init(keys[2], cfg.d_model, cfg.vocab_size, dt),
    }
    if cfg.family == "hybrid":
        # One shared attention block; mlp_type fixed to swiglu for it.
        params["shared_attn"] = {
            "norm1": jnp.ones((cfg.d_model,), dt),
            "attn": L.init_attention(keys[3], cfg),
            "norm2": jnp.ones((cfg.d_model,), dt),
            "mlp": L.init_mlp(keys[4], cfg),
        }
    if cfg.family == "audio":
        enc_keys = jax.random.split(keys[5], cfg.encoder_layers)
        enc_cfg = cfg  # same dims for encoder blocks
        params["encoder"] = {
            "blocks": jax.vmap(
                lambda k: {
                    "norm1": jnp.ones((cfg.d_model,), dt),
                    "attn": L.init_attention(jax.random.fold_in(k, 0), enc_cfg),
                    "norm2": jnp.ones((cfg.d_model,), dt),
                    "mlp": L.init_mlp(jax.random.fold_in(k, 1), enc_cfg),
                }
            )(enc_keys),
            "norm": jnp.ones((cfg.d_model,), dt),
        }
        params["enc_pos"] = (
            jax.random.normal(keys[6], (cfg.encoder_seq, cfg.d_model), jnp.float32) * 0.02
        ).astype(dt)
        params["dec_pos"] = (
            jax.random.normal(keys[7], (cfg.max_target_positions, cfg.d_model), jnp.float32)
            * 0.02
        ).astype(dt)
    return params


# ---------------------------------------------------------------------------
# Full-sequence block application (train / prefill)
# ---------------------------------------------------------------------------


def _attn_full(
    bp: Params,
    cfg: ModelConfig,
    x: jax.Array,
    positions: jax.Array,
    window: Optional[int],
    enc_out: Optional[jax.Array],
    use_pallas: bool,
    constrain=None,
):
    """One attention(+mlp/moe/cross) block over a full sequence.

    Returns (x, aux, (k_rope, v)) — the RoPE'd K and V for cache writing.
    """
    use_rope = cfg.family != "audio"
    h = L.rms_norm(x, bp["norm1"], cfg.norm_eps)
    q, k, v = L.attn_qkv(bp["attn"], cfg, h)
    if use_rope:
        q = L.rope(q, positions, cfg.rope_theta)
        k = L.rope(k, positions, cfg.rope_theta)
    if use_pallas:
        from repro.kernels import ops as kops

        o = kops.flash_attention(q, k, v, causal=True, window=window)
    elif L._ATTN_QTILE:
        o = L.sdpa_qtiled(q, k, v, causal=True, window=window,
                          q_tile=L._ATTN_QTILE)
    else:
        o = L.sdpa(q, k, v, causal=True, q_positions=positions,
                   kv_positions=positions, window=window)
    x = x + L.attn_out(bp["attn"], cfg, o)

    xk = xv = None
    if cfg.is_encoder_decoder and enc_out is not None:
        hx = L.rms_norm(x, bp["norm_x"], cfg.norm_eps)
        qx, _, _ = L.attn_qkv(bp["xattn"], cfg, hx)
        _, xk, xv = L.attn_qkv(bp["xattn"], cfg, enc_out)
        ox = L.sdpa(qx, xk, xv, causal=False)
        x = x + L.attn_out(bp["xattn"], cfg, ox)

    h2 = L.rms_norm(x, bp["norm2"], cfg.norm_eps)
    aux = jnp.zeros((), jnp.float32)
    if cfg.family == "moe":
        y, aux = MOE.moe_block(bp["moe"], cfg, h2, constrain=constrain)
        if cfg.dense_residual:
            y = y + L.mlp(bp["dense_mlp"], cfg, h2)
    else:
        y = L.mlp(bp["mlp"], cfg, h2)
    x = x + y
    return x, aux, (k, v, xk, xv)


def _decoder_window(cfg: ModelConfig, long_context: bool) -> Optional[int]:
    if cfg.sliding_window is not None:
        return cfg.sliding_window
    if long_context:
        return cfg.long_context_window
    return None


def _run_encoder(params: Params, cfg: ModelConfig, frame_embeds: jax.Array) -> jax.Array:
    x = frame_embeds + params["enc_pos"][None]
    Senc = x.shape[1]
    positions = jnp.arange(Senc, dtype=jnp.int32)[None]

    def body(h, bp):
        a = L.rms_norm(h, bp["norm1"], cfg.norm_eps)
        q, k, v = L.attn_qkv(bp["attn"], cfg, a)
        o = L.sdpa(q, k, v, causal=False)
        h = h + L.attn_out(bp["attn"], cfg, o)
        m = L.rms_norm(h, bp["norm2"], cfg.norm_eps)
        h = h + L.mlp(bp["mlp"], cfg, m)
        return h, None

    x, _ = _scan(body, x, params["encoder"]["blocks"])
    return L.rms_norm(x, params["encoder"]["norm"], cfg.norm_eps)


def _embed_inputs(
    params: Params,
    cfg: ModelConfig,
    tokens: jax.Array,
    patch_embeds: Optional[jax.Array],
) -> jax.Array:
    x = params["embed"][tokens]
    if cfg.family == "vlm":
        assert patch_embeds is not None
        x = jnp.concatenate([patch_embeds.astype(x.dtype), x], axis=1)
    return x


def forward_seq(
    params: Params,
    cfg: ModelConfig,
    tokens: jax.Array,
    *,
    patch_embeds: Optional[jax.Array] = None,
    frame_embeds: Optional[jax.Array] = None,
    long_context: bool = False,
    want_cache: bool = False,
    cache_window: Optional[int] = None,
    use_pallas: bool = False,
    remat: bool = False,
    constrain=None,
) -> Tuple[jax.Array, jax.Array, Optional[Dict[str, jax.Array]]]:
    """Full-sequence forward. Returns (logits, aux_loss, cache|None).

    With ``want_cache`` the per-layer K/V (last ``cache_window`` entries)
    / SSM states are collected for subsequent decode.

    ``constrain(name, x)`` — optional activation-sharding hook applied at
    "hidden" (post-embed residual stream) and "logits". The launcher
    installs ``with_sharding_constraint``s here; without them GSPMD may
    e.g. all-reduce fp32 logits over the data axis instead of gathering
    the FSDP-sharded lm_head (a 40 GB vs 40 MB difference, see
    EXPERIMENTS.md §Perf).
    """
    if constrain is None:
        constrain = lambda name, v: v
    x = _embed_inputs(params, cfg, tokens, patch_embeds)
    x = constrain("hidden", x)
    Bsz, S, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (Bsz, S))
    window = _decoder_window(cfg, long_context)
    W = cache_window or S

    enc_out = None
    if cfg.is_encoder_decoder:
        assert frame_embeds is not None
        enc_out = _run_encoder(params, cfg, frame_embeds)
        x = x + params["dec_pos"][None, :S]

    cache: Dict[str, jax.Array] = {}
    shared = params.get("shared_attn")

    if cfg.family in ("dense", "moe", "vlm", "audio"):

        def body(h, bp):
            h, aux, (k, v, xk, xv) = _attn_full(
                bp, cfg, h, positions, window, enc_out, use_pallas,
                constrain=constrain,
            )
            ys = {"aux": aux}
            if want_cache:
                zero = jnp.zeros((Bsz, W) + k.shape[2:], k.dtype)
                ys["k"] = write_prefill(zero, k)
                ys["v"] = write_prefill(zero, v)
                if xk is not None:
                    ys["xk"] = xk
                    ys["xv"] = xv
            return h, ys

        if remat:
            body = _checkpoint(body)
        x, ys = _scan(body, x, params["blocks"])
        aux = jnp.sum(ys["aux"])
        if want_cache:
            cache["k"], cache["v"] = ys["k"], ys["v"]
            if "xk" in ys:
                cache["xk"], cache["xv"] = ys["xk"], ys["xv"]

    elif cfg.family in ("ssm", "hybrid"):
        n_apps = num_attn_layers(cfg)

        def body(h, inp):
            bp, idx = inp
            ys = {}
            if cfg.family == "hybrid":

                def with_attn(h):
                    hh, _, (k, v, _, _) = _attn_full(
                        shared, cfg, h, positions, window, None, use_pallas,
                        constrain=constrain,
                    )
                    return hh, k, v

                def without_attn(h):
                    zk = jnp.zeros(
                        (Bsz, S, cfg.num_kv_heads, cfg.head_dim), h.dtype
                    )
                    return h, zk, zk

                h, k, v = jax.lax.cond(
                    idx % cfg.attn_every == 0, with_attn, without_attn, h
                )
                if want_cache:
                    zero = jnp.zeros((Bsz, W) + k.shape[2:], k.dtype)
                    ys["k"] = write_prefill(zero, k)
                    ys["v"] = write_prefill(zero, v)
            u = L.rms_norm(h, bp["norm"], cfg.norm_eps)
            out, ssd_state, conv_state = SSM.mamba_block(
                bp["mamba"], cfg, u, use_pallas=use_pallas
            )
            h = h + out
            ys["aux"] = jnp.zeros((), jnp.float32)
            if want_cache:
                ys["ssd"] = ssd_state
                ys["conv"] = conv_state
            return h, ys

        if remat:
            body = _checkpoint(body)
        x, ys = _scan(
            body, x, (params["blocks"], jnp.arange(cfg.num_layers, dtype=jnp.int32))
        )
        aux = jnp.sum(ys["aux"])
        if want_cache:
            cache["ssd"], cache["conv"] = ys["ssd"], ys["conv"]
            if cfg.family == "hybrid":
                # Compact the per-layer attn caches down to the fired slots.
                fired = jnp.nonzero(
                    jnp.arange(cfg.num_layers) % cfg.attn_every == 0,
                    size=n_apps,
                )[0]
                cache["k"] = ys["k"][fired]
                cache["v"] = ys["v"][fired]
    else:  # pragma: no cover
        raise ValueError(cfg.family)

    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = constrain("logits", x @ params["lm_head"])
    if want_cache:
        cache["pos"] = jnp.full((Bsz,), S, jnp.int32)
        return logits, aux, cache
    return logits, aux, None


# ---------------------------------------------------------------------------
# Train
# ---------------------------------------------------------------------------


def cross_entropy(logits: jax.Array, labels: jax.Array) -> jax.Array:
    """Vocab-shard-friendly CE: the gold logit is extracted with an iota
    comparison instead of take_along_axis, which GSPMD would otherwise
    implement by all-gathering the full fp32 logits (40 GB/device for the
    nemotron-scale vocabs — see EXPERIMENTS.md §Perf)."""
    logits = logits.astype(jnp.float32)
    m = jax.lax.stop_gradient(jnp.max(logits, axis=-1, keepdims=True))
    shifted = logits - m
    logz = jnp.log(jnp.sum(jnp.exp(shifted), axis=-1)) + m[..., 0]
    iota = jax.lax.broadcasted_iota(jnp.int32, logits.shape, logits.ndim - 1)
    gold = jnp.sum(jnp.where(iota == labels[..., None], logits, 0.0), axis=-1)
    return logz - gold


def forward_train(
    params: Params,
    cfg: ModelConfig,
    tokens: jax.Array,
    labels: jax.Array,
    *,
    patch_embeds: Optional[jax.Array] = None,
    frame_embeds: Optional[jax.Array] = None,
    use_pallas: bool = False,
    remat: bool = True,
    constrain=None,
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Returns (scalar loss, metrics)."""
    logits, aux, _ = forward_seq(
        params,
        cfg,
        tokens,
        patch_embeds=patch_embeds,
        frame_embeds=frame_embeds,
        use_pallas=use_pallas,
        remat=remat,
        constrain=constrain,
    )
    if cfg.family == "vlm":
        logits = logits[:, cfg.frontend_tokens :]
    ce = cross_entropy(logits, labels)
    loss = jnp.mean(ce)
    total = loss + cfg.router_aux_weight * aux
    return total, {"ce_loss": loss, "aux_loss": aux}


# ---------------------------------------------------------------------------
# Prefill
# ---------------------------------------------------------------------------


def forward_prefill(
    params: Params,
    cfg: ModelConfig,
    tokens: jax.Array,
    *,
    patch_embeds: Optional[jax.Array] = None,
    frame_embeds: Optional[jax.Array] = None,
    cache_window: Optional[int] = None,
    long_context: bool = False,
    use_pallas: bool = False,
    constrain=None,
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Returns (last-position logits: (B, V), cache)."""
    logits, _, cache = forward_seq(
        params,
        cfg,
        tokens,
        patch_embeds=patch_embeds,
        frame_embeds=frame_embeds,
        want_cache=True,
        cache_window=cache_window,
        long_context=long_context,
        use_pallas=use_pallas,
        constrain=constrain,
    )
    assert cache is not None
    return logits[:, -1], cache


# ---------------------------------------------------------------------------
# Decode (one token against the cache)
# ---------------------------------------------------------------------------


def _attn_decode(
    bp: Params,
    cfg: ModelConfig,
    x_t: jax.Array,  # (B, D)
    k_buf: jax.Array,  # (B, W, Hkv, hd)
    v_buf: jax.Array,
    pos: jax.Array,  # (B,)
    xk: Optional[jax.Array] = None,
    xv: Optional[jax.Array] = None,
    use_pallas: bool = False,
    constrain=None,
):
    """Single-token attention against a ring-buffer cache.

    Returns (out: (B,D), new_k: (B,Hkv,hd), new_v).
    """
    W = k_buf.shape[1]
    use_rope = cfg.family != "audio"
    h = x_t[:, None]  # (B,1,D)
    q, k, v = L.attn_qkv(bp["attn"], cfg, h)
    if use_rope:
        q = L.rope(q, pos[:, None], cfg.rope_theta)
        k = L.rope(k, pos[:, None], cfg.rope_theta)
    if _DECODE_MODE == "split":
        # partial softmax over the (sharded) cache, exact self term,
        # log-sum-exp merge — no (W+1)-concat resharding of the cache
        o = L.sdpa_decode_split(
            q, k, v, k_buf, v_buf,
            kv_positions=ring_positions(pos, W),
            kv_valid=ring_valid(pos, W),
            q_pos=pos,
            constrain=constrain,
        )
    else:
        kv_pos = jnp.concatenate([ring_positions(pos, W), pos[:, None]], axis=1)
        valid = jnp.concatenate(
            [ring_valid(pos, W), jnp.ones((pos.shape[0], 1), bool)], axis=1
        )
        k_all = jnp.concatenate([k_buf, k], axis=1)
        v_all = jnp.concatenate([v_buf, v], axis=1)
        if use_pallas:
            from repro.kernels import ops as kops

            o = kops.decode_attention(q, k_all, v_all, kv_pos, valid, pos)
        else:
            o = L.sdpa(
                q, k_all, v_all, causal=True,
                q_positions=pos[:, None], kv_positions=kv_pos, kv_valid=valid,
            )
    out = L.attn_out(bp["attn"], cfg, o)[:, 0]
    if cfg.is_encoder_decoder and xk is not None:
        hx = L.rms_norm(x_t + out, bp["norm_x"], cfg.norm_eps)[:, None]
        qx, _, _ = L.attn_qkv(bp["xattn"], cfg, hx)
        ox = L.sdpa(qx, xk, xv, causal=False)
        out = out + L.attn_out(bp["xattn"], cfg, ox)[:, 0]
    return out, k[:, 0], v[:, 0]


def forward_decode(
    params: Params,
    cfg: ModelConfig,
    token: jax.Array,  # (B,) int32
    cache: Dict[str, jax.Array],
    *,
    use_pallas: bool = False,
    constrain=None,
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """One decode step. Returns (logits: (B, V), updated cache)."""
    pos = cache["pos"]
    x = params["embed"][token]  # (B, D)
    Bsz = x.shape[0]
    if cfg.is_encoder_decoder:
        # Learned decoder positions, clamped to the positional cap.
        idx = jnp.clip(pos, 0, cfg.max_target_positions - 1)
        x = x + params["dec_pos"][idx]
    new_cache = dict(cache)
    shared = params.get("shared_attn")

    if cfg.family in ("dense", "moe", "vlm", "audio"):

        def body(h, inp):
            bp, k_buf, v_buf, xk, xv = inp
            a = L.rms_norm(h, bp["norm1"], cfg.norm_eps)
            o, nk, nv = _attn_decode(
                bp, cfg, a, k_buf, v_buf, pos, xk, xv, use_pallas,
                constrain=constrain,
            )
            h = h + o
            h2 = L.rms_norm(h, bp["norm2"], cfg.norm_eps)
            if cfg.family == "moe":
                y = MOE.moe_decode(bp["moe"], cfg, h2)
                if cfg.dense_residual:
                    y = y + L.mlp(bp["dense_mlp"], cfg, h2)
            else:
                y = L.mlp(bp["mlp"], cfg, h2)
            return h + y, (nk, nv)

        if not cfg.is_encoder_decoder:
            def body_noenc(h, inp):
                bp, k_buf, v_buf = inp
                return body(h, (bp, k_buf, v_buf, None, None))

            x, (nk, nv) = _scan(
                body_noenc, x, (params["blocks"], cache["k"], cache["v"])
            )
        else:
            x, (nk, nv) = _scan(
                body, x, (params["blocks"], cache["k"], cache["v"], cache["xk"], cache["xv"])
            )
        new_cache["k"] = jax.vmap(ring_write, in_axes=(0, 0, None))(cache["k"], nk, pos)
        new_cache["v"] = jax.vmap(ring_write, in_axes=(0, 0, None))(cache["v"], nv, pos)

    elif cfg.family in ("ssm", "hybrid"):
        n_apps = num_attn_layers(cfg)

        def body(h, inp):
            bp, conv_s, ssd_s, idx = inp
            ys = {}
            if cfg.family == "hybrid":
                app = idx // cfg.attn_every
                k_buf = jax.lax.dynamic_index_in_dim(cache["k"], app, keepdims=False)
                v_buf = jax.lax.dynamic_index_in_dim(cache["v"], app, keepdims=False)

                def with_attn(h):
                    a = L.rms_norm(h, shared["norm1"], cfg.norm_eps)
                    o, nk, nv = _attn_decode(
                        shared, cfg, a, k_buf, v_buf, pos, None, None,
                        use_pallas, constrain=constrain,
                    )
                    h2in = h + o
                    m = L.rms_norm(h2in, shared["norm2"], cfg.norm_eps)
                    return h2in + L.mlp(shared["mlp"], cfg, m), nk, nv

                def without_attn(h):
                    zk = jnp.zeros((Bsz, cfg.num_kv_heads, cfg.head_dim), h.dtype)
                    return h, zk, zk

                h, nk, nv = jax.lax.cond(
                    idx % cfg.attn_every == 0, with_attn, without_attn, h
                )
                ys["nk"], ys["nv"] = nk, nv
            u = L.rms_norm(h, bp["norm"], cfg.norm_eps)
            out, conv_s, ssd_s = SSM.mamba_decode(bp["mamba"], cfg, u, conv_s, ssd_s)
            h = h + out
            ys["conv"], ys["ssd"] = conv_s, ssd_s
            return h, ys

        x, ys = _scan(
            body,
            x,
            (
                params["blocks"],
                cache["conv"],
                cache["ssd"],
                jnp.arange(cfg.num_layers, dtype=jnp.int32),
            ),
        )
        new_cache["conv"], new_cache["ssd"] = ys["conv"], ys["ssd"]
        if cfg.family == "hybrid":
            fired = jnp.nonzero(
                jnp.arange(cfg.num_layers) % cfg.attn_every == 0, size=n_apps
            )[0]
            nk, nv = ys["nk"][fired], ys["nv"][fired]
            new_cache["k"] = jax.vmap(ring_write, in_axes=(0, 0, None))(cache["k"], nk, pos)
            new_cache["v"] = jax.vmap(ring_write, in_axes=(0, 0, None))(cache["v"], nv, pos)
    else:  # pragma: no cover
        raise ValueError(cfg.family)

    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = x @ params["lm_head"]
    new_cache["pos"] = pos + 1
    return logits, new_cache


# ---------------------------------------------------------------------------
# Parameter counting
# ---------------------------------------------------------------------------


def count_params(params) -> int:
    return sum(int(x.size) for x in jax.tree_util.tree_leaves(params))


def count_params_analytic(cfg: ModelConfig, active_only: bool = False) -> int:
    D = cfg.d_model
    n = cfg.vocab_size * D * 2 + D  # embed + lm_head + final norm
    if cfg.family in ("dense", "vlm"):
        per = 2 * D + L.attn_param_count(cfg) + L.mlp_param_count(cfg)
        n += cfg.num_layers * per
    elif cfg.family == "moe":
        per = 2 * D + L.attn_param_count(cfg) + MOE.moe_param_count(cfg, active_only)
        if cfg.dense_residual:
            per += L.mlp_param_count(cfg)
        n += cfg.num_layers * per
    elif cfg.family == "ssm":
        n += cfg.num_layers * (D + SSM.mamba_param_count(cfg))
    elif cfg.family == "hybrid":
        n += cfg.num_layers * (D + SSM.mamba_param_count(cfg))
        n += 2 * D + L.attn_param_count(cfg) + L.mlp_param_count(cfg)  # shared
    elif cfg.family == "audio":
        enc_per = 2 * D + L.attn_param_count(cfg) + L.mlp_param_count(cfg)
        dec_per = 3 * D + 2 * L.attn_param_count(cfg) + L.mlp_param_count(cfg)
        n += cfg.encoder_layers * enc_per + cfg.num_layers * dec_per + D
        n += cfg.encoder_seq * D + cfg.max_target_positions * D
    return n
