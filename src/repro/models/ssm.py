"""Mamba2 (SSD — state-space duality) blocks in pure JAX.

Follows arXiv:2405.21060: the block projects the residual stream into
(z, x, B, C, dt), applies a short causal depthwise conv to (x, B, C),
then runs the SSD recurrence

    S_t = exp(dt_t * A_h) * S_{t-1} + dt_t * B_t x_t^T        (per head h)
    y_t = C_t . S_t + D_h * x_t

computed in the chunked dual form for train/prefill and as a one-step
recurrence for decode. ``ssd_chunked`` here is the pure-jnp oracle that
``repro.kernels.ssd_scan`` (Pallas) is validated against.

n_groups = 1: B and C are shared across heads.
"""

from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import dense_init
from repro.models.kv_cache import conv_dim

Params = Dict[str, jax.Array]


def init_mamba_block(key, cfg: ModelConfig) -> Params:
    dt = jnp.dtype(cfg.dtype)
    H, P, N = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
    d_in = cfg.d_inner
    proj_out = 2 * d_in + 2 * N + H  # z, x, B, C, dt
    ks = jax.random.split(key, 4)
    dt_init = jnp.exp(
        jax.random.uniform(ks[2], (H,), jnp.float32) * (jnp.log(0.1) - jnp.log(0.001))
        + jnp.log(0.001)
    )
    return {
        "in_proj": dense_init(ks[0], cfg.d_model, proj_out, dt),
        "conv_w": (jax.random.normal(ks[3], (cfg.conv_width, conv_dim(cfg)), jnp.float32) * 0.1).astype(dt),
        "conv_b": jnp.zeros((conv_dim(cfg),), dt),
        "A_log": jnp.log(jnp.arange(1, H + 1, dtype=jnp.float32)),
        "dt_bias": jnp.log(jnp.expm1(dt_init)),  # inverse softplus
        "D": jnp.ones((H,), jnp.float32),
        "norm_w": jnp.ones((d_in,), dt),
        "out_proj": dense_init(ks[1], d_in, cfg.d_model, dt),
    }


def _split_proj(cfg: ModelConfig, zxbcdt: jax.Array):
    d_in, N, H = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    z = zxbcdt[..., :d_in]
    xbc = zxbcdt[..., d_in : 2 * d_in + 2 * N]
    dt_raw = zxbcdt[..., 2 * d_in + 2 * N :]
    assert dt_raw.shape[-1] == H
    return z, xbc, dt_raw


def causal_conv(xbc: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv over (B, S, C) with window len(w)."""
    K = w.shape[0]
    pad = jnp.pad(xbc, ((0, 0), (K - 1, 0), (0, 0)))
    out = jnp.zeros_like(xbc, dtype=jnp.float32)
    for i in range(K):  # K is tiny (4); unrolled adds fuse well
        out = out + pad[:, i : i + xbc.shape[1]].astype(jnp.float32) * w[i].astype(jnp.float32)
    out = out + b.astype(jnp.float32)
    return jax.nn.silu(out).astype(xbc.dtype)


def conv_decode_step(
    xbc_t: jax.Array, conv_state: jax.Array, w: jax.Array, b: jax.Array
) -> Tuple[jax.Array, jax.Array]:
    """One conv step. xbc_t: (B, C); conv_state: (B, K-1, C)."""
    window = jnp.concatenate([conv_state, xbc_t[:, None]], axis=1)  # (B,K,C)
    out = jnp.einsum("bkc,kc->bc", window.astype(jnp.float32), w.astype(jnp.float32))
    out = jax.nn.silu(out + b.astype(jnp.float32)).astype(xbc_t.dtype)
    new_state = window[:, 1:]
    return out, new_state


# ---------------------------------------------------------------------------
# SSD — chunked dual form (pure-jnp oracle for the Pallas kernel)
# ---------------------------------------------------------------------------


def _segsum(loga: jax.Array) -> jax.Array:
    """Lower-triangular pairwise sums: out[..., i, j] = sum_{j<k<=i} loga_k.

    loga: (..., Q). Returns (..., Q, Q) with -inf above the diagonal.
    """
    Q = loga.shape[-1]
    cum = jnp.cumsum(loga, axis=-1)
    diff = cum[..., :, None] - cum[..., None, :]  # sum over (j, i]
    mask = jnp.tril(jnp.ones((Q, Q), bool), k=0)
    return jnp.where(mask, diff, -jnp.inf)


def ssd_chunked(
    x: jax.Array,        # (B, S, H, P)
    dt: jax.Array,       # (B, S, H)  post-softplus, > 0
    A: jax.Array,        # (H,)       negative
    B_: jax.Array,       # (B, S, N)
    C_: jax.Array,       # (B, S, N)
    chunk: int,
    init_state: Optional[jax.Array] = None,  # (B, H, P, N) fp32
) -> Tuple[jax.Array, jax.Array]:
    """Chunked SSD scan. Returns (y: (B,S,H,P), final_state: (B,H,P,N))."""
    Bsz, S, H, P = x.shape
    N = B_.shape[-1]
    Q = chunk
    assert S % Q == 0, (S, Q)
    nc = S // Q

    f32 = jnp.float32
    xw = (x.astype(f32) * dt.astype(f32)[..., None]).reshape(Bsz, nc, Q, H, P)
    loga = (dt.astype(f32) * A.astype(f32)).reshape(Bsz, nc, Q, H)  # log decay
    Bc = B_.astype(f32).reshape(Bsz, nc, Q, N)
    Cc = C_.astype(f32).reshape(Bsz, nc, Q, N)

    # --- intra-chunk (dual / attention-like form) --------------------------
    L = jnp.exp(_segsum(jnp.moveaxis(loga, -1, -2)))  # (B,nc,H,Q,Q)
    scores = jnp.einsum("bcin,bcjn->bcij", Cc, Bc)  # (B,nc,Q,Q)
    y_intra = jnp.einsum("bchij,bcij,bcjhp->bcihp", L, scores, xw)

    # --- chunk-final states -------------------------------------------------
    cum = jnp.cumsum(loga, axis=2)  # (B,nc,Q,H)
    total = cum[:, :, -1]  # (B,nc,H)
    decay_to_end = jnp.exp(total[:, :, None] - cum)  # (B,nc,Q,H)
    chunk_states = jnp.einsum("bcjn,bcjh,bcjhp->bchpn", Bc, decay_to_end, xw)

    # --- inter-chunk recurrence over chunk states ---------------------------
    if init_state is None:
        init_state = jnp.zeros((Bsz, H, P, N), f32)

    def step(carry, inp):
        tot, cs = inp  # tot: (B,H); cs: (B,H,P,N)
        new = carry * jnp.exp(tot)[..., None, None] + cs
        return new, carry  # emit state *entering* the chunk

    total_t = jnp.moveaxis(total, 1, 0)  # (nc,B,H)
    cs_t = jnp.moveaxis(chunk_states, 1, 0)  # (nc,B,H,P,N)
    final_state, entering = jax.lax.scan(step, init_state, (total_t, cs_t))
    entering = jnp.moveaxis(entering, 0, 1)  # (B,nc,H,P,N)

    # --- inter-chunk output contribution ------------------------------------
    decay_from_start = jnp.exp(cum)  # (B,nc,Q,H)
    y_inter = jnp.einsum(
        "bcin,bcih,bchpn->bcihp", Cc, decay_from_start, entering
    )

    y = (y_intra + y_inter).reshape(Bsz, S, H, P)
    return y.astype(x.dtype), final_state


def ssd_decode_step(
    x_t: jax.Array,    # (B, H, P)
    dt_t: jax.Array,   # (B, H)
    A: jax.Array,      # (H,)
    B_t: jax.Array,    # (B, N)
    C_t: jax.Array,    # (B, N)
    state: jax.Array,  # (B, H, P, N) fp32
) -> Tuple[jax.Array, jax.Array]:
    f32 = jnp.float32
    decay = jnp.exp(dt_t.astype(f32) * A.astype(f32))  # (B,H)
    xw = x_t.astype(f32) * dt_t.astype(f32)[..., None]  # (B,H,P)
    new_state = state * decay[..., None, None] + jnp.einsum(
        "bhp,bn->bhpn", xw, B_t.astype(f32)
    )
    y = jnp.einsum("bhpn,bn->bhp", new_state, C_t.astype(f32))
    return y.astype(x_t.dtype), new_state


# ---------------------------------------------------------------------------
# Full Mamba2 block
# ---------------------------------------------------------------------------


def mamba_block(
    p: Params,
    cfg: ModelConfig,
    u: jax.Array,  # (B, S, d_model) — already normed residual stream
    init_state: Optional[jax.Array] = None,
    use_pallas: bool = False,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Train/prefill path.

    Returns (out: (B,S,d_model), final ssd state: (B,H,P,N),
    conv tail: (B, conv_width-1, conv_dim) — raw inputs for decode).
    """
    Bsz, S, _ = u.shape
    H, P, N = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
    zxbcdt = u @ p["in_proj"]
    z, xbc, dt_raw = _split_proj(cfg, zxbcdt)
    conv_tail = xbc[:, S - (cfg.conv_width - 1) :, :]
    xbc = causal_conv(xbc, p["conv_w"], p["conv_b"])
    x = xbc[..., : cfg.d_inner].reshape(Bsz, S, H, P)
    B_ = xbc[..., cfg.d_inner : cfg.d_inner + N]
    C_ = xbc[..., cfg.d_inner + N :]
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])
    if use_pallas:
        from repro.kernels import ops as kops

        y, final_state = kops.ssd_scan(x, dt, A, B_, C_, cfg.ssm_chunk, init_state)
    else:
        y, final_state = ssd_chunked(x, dt, A, B_, C_, cfg.ssm_chunk, init_state)
    y = y + x * p["D"].astype(y.dtype)[None, None, :, None]
    y = y.reshape(Bsz, S, cfg.d_inner)
    from repro.models.layers import gated_rms_norm

    y = gated_rms_norm(y, z, p["norm_w"], cfg.norm_eps)
    return y @ p["out_proj"], final_state, conv_tail


def mamba_decode(
    p: Params,
    cfg: ModelConfig,
    u_t: jax.Array,  # (B, d_model)
    conv_state: jax.Array,
    ssd_state: jax.Array,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """One decode step. Returns (out: (B,d_model), conv_state, ssd_state)."""
    Bsz = u_t.shape[0]
    H, P, N = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
    zxbcdt = u_t @ p["in_proj"]
    z, xbc, dt_raw = _split_proj(cfg, zxbcdt)
    xbc, conv_state = conv_decode_step(xbc, conv_state, p["conv_w"], p["conv_b"])
    x = xbc[..., : cfg.d_inner].reshape(Bsz, H, P)
    B_t = xbc[..., cfg.d_inner : cfg.d_inner + N]
    C_t = xbc[..., cfg.d_inner + N :]
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])
    y, ssd_state = ssd_decode_step(x, dt, A, B_t, C_t, ssd_state)
    y = y + x * p["D"].astype(y.dtype)[None, :, None]
    y = y.reshape(Bsz, cfg.d_inner)
    from repro.models.layers import gated_rms_norm

    y = gated_rms_norm(y, z, p["norm_w"], cfg.norm_eps)
    return y @ p["out_proj"], conv_state, ssd_state


def mamba_param_count(cfg: ModelConfig) -> int:
    H, N = cfg.ssm_heads, cfg.ssm_state
    d_in = cfg.d_inner
    proj_out = 2 * d_in + 2 * N + H
    n = cfg.d_model * proj_out
    n += cfg.conv_width * conv_dim(cfg) + conv_dim(cfg)
    n += H * 3  # A_log, dt_bias, D
    n += d_in  # norm
    n += d_in * cfg.d_model
    return n
