"""Shared building blocks: norms, RoPE, GQA attention, MLPs.

Everything is a pure function over explicit parameter pytrees (dicts of
jnp arrays). Reference attention paths are plain einsums that XLA fuses;
the Pallas kernels in ``repro.kernels`` mirror these and are validated
against them (``use_pallas`` plumbs through ``repro.kernels.ops``).
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig

Params = Dict[str, Any]


# ---------------------------------------------------------------------------
# Initializers
# ---------------------------------------------------------------------------


def dense_init(key, in_dim: int, out_dim: int, dtype, scale: Optional[float] = None):
    scale = scale if scale is not None else in_dim ** -0.5
    return (jax.random.normal(key, (in_dim, out_dim), jnp.float32) * scale).astype(dtype)


def embed_init(key, vocab: int, dim: int, dtype):
    return (jax.random.normal(key, (vocab, dim), jnp.float32) * 0.02).astype(dtype)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------


def rms_norm(x: jax.Array, weight: jax.Array, eps: float = 1e-5) -> jax.Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * weight.astype(jnp.float32)).astype(dtype)


def gated_rms_norm(x: jax.Array, z: jax.Array, weight: jax.Array, eps: float = 1e-5):
    """Mamba2's RMSNorm(x * silu(z)) fused gate-norm."""
    dtype = x.dtype
    x = x.astype(jnp.float32) * jax.nn.silu(z.astype(jnp.float32))
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * weight.astype(jnp.float32)).astype(dtype)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------


def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """Rotary embedding. x: (..., S, H, D); positions: (..., S) int32."""
    d = x.shape[-1]
    half = d // 2
    freq = (theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half))
    angles = positions[..., None].astype(jnp.float32) * freq  # (..., S, half)
    cos = jnp.cos(angles)[..., None, :]  # (..., S, 1, half)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    x1f, x2f = x1.astype(jnp.float32), x2.astype(jnp.float32)
    out = jnp.concatenate([x1f * cos - x2f * sin, x2f * cos + x1f * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Attention (GQA) — reference einsum paths
# ---------------------------------------------------------------------------


def init_attention(key, cfg: ModelConfig) -> Params:
    dt = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 4)
    p: Params = {
        "wq": dense_init(ks[0], cfg.d_model, cfg.q_dim, dt),
        "wk": dense_init(ks[1], cfg.d_model, cfg.kv_dim, dt),
        "wv": dense_init(ks[2], cfg.d_model, cfg.kv_dim, dt),
        "wo": dense_init(ks[3], cfg.q_dim, cfg.d_model, dt),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((cfg.q_dim,), dt)
        p["bk"] = jnp.zeros((cfg.kv_dim,), dt)
        p["bv"] = jnp.zeros((cfg.kv_dim,), dt)
    return p


def attn_qkv(p: Params, cfg: ModelConfig, x: jax.Array):
    """Project x:(B,S,D) -> q:(B,S,H,hd), k/v:(B,S,Hkv,hd)."""
    B, S, _ = x.shape
    q = x @ p["wq"]
    k = x @ p["wk"]
    v = x @ p["wv"]
    if cfg.qkv_bias:
        q = q + p["bq"]
        k = k + p["bk"]
        v = v + p["bv"]
    q = q.reshape(B, S, cfg.num_heads, cfg.head_dim)
    k = k.reshape(B, S, cfg.num_kv_heads, cfg.head_dim)
    v = v.reshape(B, S, cfg.num_kv_heads, cfg.head_dim)
    return q, k, v


def attn_out(p: Params, cfg: ModelConfig, o: jax.Array) -> jax.Array:
    B, S = o.shape[:2]
    return o.reshape(B, S, cfg.q_dim) @ p["wo"]


def _expand_kv(k: jax.Array, num_heads: int) -> jax.Array:
    """Broadcast kv heads to q heads: (B,S,Hkv,D) -> (B,S,H,D)."""
    B, S, Hkv, D = k.shape
    rep = num_heads // Hkv
    if rep == 1:
        return k
    return jnp.repeat(k, rep, axis=2)


# GQA contraction mode. "repeat" (baseline) materializes kv broadcast to
# H heads; "grouped" keeps the kv-head dim intact and folds the q-head
# group into the einsum — no repeat, so a sharded KV cache keeps its
# sharding through attention (GSPMD otherwise all-gathers the whole
# cache; §Perf hillclimb decode iteration 2).
_GQA_MODE = "repeat"


def set_gqa_mode(name: str) -> None:
    global _GQA_MODE
    assert name in ("repeat", "grouped")
    _GQA_MODE = name


def _sdpa_grouped(q, k, v, *, causal, q_positions, kv_positions, window,
                  kv_valid):
    B, Sq, H, D = q.shape
    Skv, Hkv = k.shape[1], k.shape[2]
    g = H // Hkv
    qg = q.reshape(B, Sq, Hkv, g, D)
    scale = D ** -0.5
    logits = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k,
                        preferred_element_type=jnp.float32) * scale
    qp = q_positions[:, None, None, :, None]
    kp = kv_positions[:, None, None, None, :]
    mask = jnp.ones((B, 1, 1, Sq, Skv), dtype=bool)
    if causal:
        mask &= kp <= qp
    if window is not None:
        mask &= kp > qp - window
    if kv_valid is not None:
        mask &= kv_valid[:, None, None, None, :]
    logits = jnp.where(mask, logits, -jnp.inf)
    probs = jax.nn.softmax(logits, axis=-1)
    probs = jnp.where(jnp.any(mask, axis=-1, keepdims=True), probs, 0.0)
    o = jnp.einsum("bhgqk,bkhd->bqhgd", probs.astype(v.dtype), v)
    return o.reshape(B, Sq, H, D)


def sdpa(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    q_positions: Optional[jax.Array] = None,
    kv_positions: Optional[jax.Array] = None,
    window: Optional[int] = None,
    kv_valid: Optional[jax.Array] = None,
) -> jax.Array:
    """Reference scaled-dot-product attention with GQA broadcast.

    q: (B,Sq,H,D); k,v: (B,Skv,Hkv,D). Masking uses absolute positions so
    the same code covers prefill (q_pos == kv_pos grid) and ring-buffer
    decode (arbitrary kv_positions, kv_valid marks live slots).
    """
    B, Sq, H, D = q.shape
    Skv = k.shape[1]
    if q_positions is None:
        q_positions = jnp.arange(Sq, dtype=jnp.int32)[None, :]
    if kv_positions is None:
        kv_positions = jnp.arange(Skv, dtype=jnp.int32)[None, :]
    if _GQA_MODE == "grouped" and H != k.shape[2]:
        return _sdpa_grouped(
            q, k, v, causal=causal, q_positions=q_positions,
            kv_positions=kv_positions, window=window, kv_valid=kv_valid,
        )
    k = _expand_kv(k, H)
    v = _expand_kv(v, H)
    scale = D ** -0.5
    logits = jnp.einsum("bqhd,bkhd->bhqk", q, k, preferred_element_type=jnp.float32)
    logits = logits * scale
    qp = q_positions[:, None, :, None]  # (B,1,Sq,1)
    kp = kv_positions[:, None, None, :]  # (B,1,1,Skv)
    mask = jnp.ones((B, 1, Sq, Skv), dtype=bool)
    if causal:
        mask &= kp <= qp
    if window is not None:
        mask &= kp > qp - window
    if kv_valid is not None:
        mask &= kv_valid[:, None, None, :]
    logits = jnp.where(mask, logits, -jnp.inf)
    probs = jax.nn.softmax(logits, axis=-1)
    # Rows that are fully masked produce NaN from softmax(-inf); zero them.
    probs = jnp.where(jnp.any(mask, axis=-1, keepdims=True), probs, 0.0)
    o = jnp.einsum("bhqk,bkhd->bqhd", probs.astype(v.dtype), v)
    return o


def sdpa_decode_split(
    q: jax.Array,      # (B, 1, H, D) — current token's query
    k_self: jax.Array,  # (B, 1, Hkv, D)
    v_self: jax.Array,
    k_buf: jax.Array,  # (B, W, Hkv, D) — ring cache (may be W-sharded)
    v_buf: jax.Array,
    *,
    kv_positions: jax.Array,  # (B, W)
    kv_valid: jax.Array,      # (B, W)
    q_pos: jax.Array,         # (B,)
    constrain=None,
) -> jax.Array:
    """Flash-decode-style split attention for one token.

    Attends to the cache and to the current token SEPARATELY and merges
    the two partial softmaxes with a log-sum-exp combine. The cache is
    never concatenated with the new entry, so a W-sharded cache keeps its
    sharding (GSPMD otherwise re-materializes all of it every decode
    step — EXPERIMENTS.md §Perf). Exactly equal to full softmax.
    """
    B, _, H, D = q.shape
    if constrain is None:
        constrain = lambda name, v: v
    Hkv = k_buf.shape[2]
    g = H // Hkv
    scale = D ** -0.5
    # ---- cache part: (B,Hkv,g,W) scores, grouped GQA (no kv repeat) ------
    qg = q[:, 0].reshape(B, Hkv, g, D)
    s_c = jnp.einsum("bhgd,bkhd->bhgk", qg, k_buf,
                     preferred_element_type=jnp.float32) * scale
    # keep the score tensor W-sharded: the softmax then reduces over the
    # sharded axis with (B,H)-sized collectives instead of GSPMD gathering
    # the whole KV cache (§Perf decode hillclimb)
    s_c = constrain("scores", s_c)
    mask = (kv_valid & (kv_positions <= q_pos[:, None]))[:, None, None, :]
    s_c = jnp.where(mask, s_c, -jnp.inf)
    m_c = jnp.max(s_c, axis=-1)  # (B,Hkv,g)
    m_c_safe = jnp.where(jnp.isfinite(m_c), m_c, 0.0)
    p = jnp.exp(s_c - m_c_safe[..., None])
    p = jnp.where(mask, p, 0.0)
    l_c = jnp.sum(p, axis=-1)  # (B,Hkv,g)
    o_c = jnp.einsum("bhgk,bkhd->bhgd", p.astype(v_buf.dtype), v_buf)
    # flatten grouped heads back to (B,H)
    m_c_safe = m_c_safe.reshape(B, H)
    l_c = l_c.reshape(B, H)
    o_c = o_c.reshape(B, H, D)
    # ---- self part: scalar score per head --------------------------------
    ks = _expand_kv(k_self, H)
    vs = _expand_kv(v_self, H)
    s_s = jnp.einsum("bqhd,bqhd->bhq", q, ks,
                     preferred_element_type=jnp.float32)[:, :, 0] * scale  # (B,H)
    # ---- merge ------------------------------------------------------------
    # o_c holds sum_k exp(s_k - m_c) v_k; true weights use exp(s_k - m):
    # scale by alpha_c = exp(m_c - m). Self term analogous with weight 1.
    m = jnp.maximum(m_c_safe, s_s)
    alpha_c = jnp.where(l_c > 0, jnp.exp(m_c_safe - m), 0.0)
    alpha_s = jnp.exp(s_s - m)
    denom = l_c * alpha_c + alpha_s
    o = (o_c.astype(jnp.float32) * (alpha_c / denom)[..., None]
         + vs[:, 0].astype(jnp.float32) * (alpha_s / denom)[..., None])
    return o.astype(q.dtype)[:, None]


# Q-tiled attention (§Perf): the reference sdpa materializes the full
# (B,H,S,S) score tensor — at prefill_32k that is the dominant temp-memory
# term (hundreds of GB/device for archs whose heads don't divide the
# model axis). Tiling the query axis bounds live scores at (B,H,qt,S) per
# step with bit-identical results. 0 = off (baseline).
_ATTN_QTILE = 0


def set_attn_qtile(n: int) -> None:
    global _ATTN_QTILE
    _ATTN_QTILE = max(0, int(n))


def sdpa_qtiled(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    window: Optional[int] = None,
    q_tile: int,
) -> jax.Array:
    B, S, H, D = q.shape
    qt = q_tile
    while S % qt:
        qt //= 2  # largest power-of-two tile dividing S
    nt = S // qt
    if nt <= 1:
        positions = jnp.arange(S, dtype=jnp.int32)[None]
        return sdpa(q, k, v, causal=causal, q_positions=positions,
                    kv_positions=positions, window=window)
    kv_pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    q_tiles = jnp.moveaxis(q.reshape(B, nt, qt, H, D), 1, 0)

    from repro.models import model as _model

    def tile(i, q_t):
        q_pos = (i * qt + jnp.arange(qt, dtype=jnp.int32))[None]
        q_pos = jnp.broadcast_to(q_pos, (B, qt))
        return sdpa(q_t, k, v, causal=causal, q_positions=q_pos,
                    kv_positions=kv_pos, window=window)

    if _model._SCAN_UNROLL > 1:
        outs = [tile(i, q_tiles[i]) for i in range(nt)]
        o = jnp.stack(outs, 0)
    else:
        def body(_, inp):
            i, q_t = inp
            return None, tile(i, q_t)

        _, o = jax.lax.scan(
            body, None, (jnp.arange(nt, dtype=jnp.int32), q_tiles)
        )
    return jnp.moveaxis(o, 0, 1).reshape(B, S, H, D)


def attention_block(
    p: Params,
    cfg: ModelConfig,
    x: jax.Array,
    positions: jax.Array,
    *,
    causal: bool = True,
    window: Optional[int] = None,
    use_rope: bool = True,
) -> jax.Array:
    """Full self-attention over x (train / prefill / encoder)."""
    q, k, v = attn_qkv(p, cfg, x)
    if use_rope:
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)
    o = sdpa(q, k, v, causal=causal, q_positions=positions,
             kv_positions=positions, window=window)
    return attn_out(p, cfg, o)


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------


def init_mlp(key, cfg: ModelConfig, d_ff: Optional[int] = None) -> Params:
    dt = jnp.dtype(cfg.dtype)
    d_ff = d_ff or cfg.d_ff
    ks = jax.random.split(key, 3)
    if cfg.mlp_type == "swiglu":
        return {
            "wg": dense_init(ks[0], cfg.d_model, d_ff, dt),
            "wu": dense_init(ks[1], cfg.d_model, d_ff, dt),
            "wd": dense_init(ks[2], d_ff, cfg.d_model, dt),
        }
    if cfg.mlp_type == "squared_relu":
        return {
            "wu": dense_init(ks[0], cfg.d_model, d_ff, dt),
            "wd": dense_init(ks[1], d_ff, cfg.d_model, dt),
        }
    raise ValueError(cfg.mlp_type)


def mlp(p: Params, cfg: ModelConfig, x: jax.Array) -> jax.Array:
    if cfg.mlp_type == "swiglu":
        return (jax.nn.silu(x @ p["wg"]) * (x @ p["wu"])) @ p["wd"]
    if cfg.mlp_type == "squared_relu":
        h = jax.nn.relu(x @ p["wu"])
        return (h * h) @ p["wd"]
    raise ValueError(cfg.mlp_type)


def mlp_param_count(cfg: ModelConfig, d_ff: Optional[int] = None) -> int:
    d_ff = d_ff or cfg.d_ff
    n = 2 if cfg.mlp_type == "squared_relu" else 3
    return n * cfg.d_model * d_ff


def attn_param_count(cfg: ModelConfig) -> int:
    n = 2 * cfg.d_model * cfg.q_dim + 2 * cfg.d_model * cfg.kv_dim
    if cfg.qkv_bias:
        n += cfg.q_dim + 2 * cfg.kv_dim
    return n
