"""Flash-decode — single-token GQA attention against a ring KV cache.

The latency-critical op for decode_32k / long_500k: ONE query token per
sequence attends to a W-deep cache. TPU adaptation (DESIGN.md §5):

* the q-head group sharing one kv head (H/Hkv rows) forms the sublane
  dim of the score tile — a (group x block_kv) MXU matmul per tile
  instead of H separate vector products;
* the kv length is the sequential grid axis; online-softmax statistics
  live in fp32 VMEM scratch across its steps (flash-decode);
* ring-buffer semantics (absolute slot positions + validity from
  ``repro.models.kv_cache``) are applied as int32 tile masks, so the
  kernel works for both the full-context and sliding-window caches.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _decode_kernel(
    q_ref, k_ref, v_ref, kvpos_ref, valid_ref, qpos_ref, o_ref,
    m_scr, l_scr, acc_scr, *, num_kv_blocks: int, window: int,
):
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0].astype(jnp.float32)  # (group, D)
    k = k_ref[0, :, 0, :].astype(jnp.float32)  # (bkv, D)
    v = v_ref[0, :, 0, :].astype(jnp.float32)
    kvpos = kvpos_ref[0]  # (bkv,)
    valid = valid_ref[0]  # (bkv,) int32
    qpos = qpos_ref[0, 0]  # scalar int32

    k_start = ki * k.shape[0]
    live_row = (k_start + jax.lax.broadcasted_iota(jnp.int32, k.shape, 0)
                < window)
    k = jnp.where(live_row, k, 0.0)
    v = jnp.where(live_row, v, 0.0)

    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) * (q.shape[-1] ** -0.5)  # (group, bkv)
    live1 = live_row[:, 0]  # (bkv,) rows inside the real cache window
    mask = (jnp.logical_and(valid > 0, kvpos <= qpos) & live1)[None, :]
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[...]
    m_cur = jnp.max(s, axis=-1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.exp(s - m_new)
    p = jnp.where(mask, p, 0.0)
    alpha = jnp.exp(m_prev - m_new)
    l_scr[...] = alpha * l_scr[...] + jnp.sum(p, axis=-1, keepdims=True)
    acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    m_scr[...] = m_new

    @pl.when(ki == num_kv_blocks - 1)
    def _finalize():
        l = l_scr[...]
        safe = jnp.where(l == 0.0, 1.0, l)
        o_ref[0, 0] = (acc_scr[...] / safe).astype(o_ref.dtype)


def decode_attention(
    q: jax.Array,  # (B, 1, H, D)
    k: jax.Array,  # (B, W, Hkv, D)
    v: jax.Array,
    kv_positions: jax.Array,  # (B, W) int32 — absolute ring positions
    kv_valid: jax.Array,  # (B, W) bool
    q_pos: jax.Array,  # (B,) int32
    *,
    block_kv: int = 512,
    interpret: bool = False,
) -> jax.Array:
    B, _, H, D = q.shape
    W, Hkv = k.shape[1], k.shape[2]
    assert H % Hkv == 0
    group = H // Hkv
    block_kv = min(block_kv, W)
    nkv = math.ceil(W / block_kv)

    qg = q.reshape(B, Hkv, group, D)
    valid_i = kv_valid.astype(jnp.int32)
    qpos2 = q_pos.reshape(B, 1).astype(jnp.int32)

    kernel = functools.partial(
        _decode_kernel, num_kv_blocks=nkv, window=W
    )
    out = pl.pallas_call(
        kernel,
        grid=(B, Hkv, nkv),
        in_specs=[
            pl.BlockSpec((1, 1, group, D), lambda b, h, ki: (b, h, 0, 0)),
            pl.BlockSpec((1, block_kv, 1, D), lambda b, h, ki: (b, ki, h, 0)),
            pl.BlockSpec((1, block_kv, 1, D), lambda b, h, ki: (b, ki, h, 0)),
            pl.BlockSpec((1, block_kv), lambda b, h, ki: (b, ki)),
            pl.BlockSpec((1, block_kv), lambda b, h, ki: (b, ki)),
            pl.BlockSpec((1, 1), lambda b, h, ki: (b, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, group, D), lambda b, h, ki: (b, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Hkv, group, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((group, 1), jnp.float32),
            pltpu.VMEM((group, 1), jnp.float32),
            pltpu.VMEM((group, D), jnp.float32),
        ],
        interpret=interpret,
    )(qg, k, v, kv_positions, valid_i, qpos2)
    return out.reshape(B, 1, H, D)
