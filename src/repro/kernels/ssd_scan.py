"""Mamba2 SSD chunked scan — Pallas TPU kernel.

State-space duality (arXiv:2405.21060): within a chunk of Q tokens the
output is an attention-like quadratic form (two (Q x Q) / (Q x P) MXU
matmuls); across chunks a tiny (P x N) state recurrence carries the
history. TPU mapping (DESIGN.md §5):

* grid = (B, H, n_chunks); the chunk axis is the trailing (sequential)
  grid dim, so the running state lives in fp32 VMEM scratch across its
  steps — the recurrent dependency never leaves the core;
* Q=256, P=64/128, N=64/128 keep every operand MXU-aligned and the
  whole working set (~(QxQ) + 3x(QxN/P) + (PxN) fp32) well under VMEM;
* the intra-chunk decay matrix exp(segsum) is built from a cumulative
  sum over the chunk with an iota lower-triangle mask, all in registers.
"""

from __future__ import annotations

import functools
import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _ssd_kernel(
    x_ref, dt_ref, a_ref, b_ref, c_ref, init_ref, y_ref, state_ref,
    state_scr, *, num_chunks: int, chunk: int,
):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        state_scr[...] = init_ref[0, 0].astype(jnp.float32)

    x = x_ref[0, :, 0, :].astype(jnp.float32)  # (Q, P)
    dt = dt_ref[0, :, 0].astype(jnp.float32)  # (Q,)
    A = a_ref[0].astype(jnp.float32)  # scalar
    Bm = b_ref[0].astype(jnp.float32)  # (Q, N)
    Cm = c_ref[0].astype(jnp.float32)  # (Q, N)

    loga = dt * A  # (Q,)
    cum = jnp.cumsum(loga)  # (Q,)
    xw = x * dt[:, None]  # (Q, P)

    # --- intra-chunk dual form -------------------------------------------
    seg = cum[:, None] - cum[None, :]  # (Q, Q): sum over (j, i]
    row = jax.lax.broadcasted_iota(jnp.int32, seg.shape, 0)
    col = jax.lax.broadcasted_iota(jnp.int32, seg.shape, 1)
    L = jnp.where(col <= row, jnp.exp(seg), 0.0)
    scores = jax.lax.dot_general(
        Cm, Bm, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )  # (Q, Q)
    y = jax.lax.dot_general(
        L * scores, xw, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )  # (Q, P)

    # --- inter-chunk contribution ----------------------------------------
    state = state_scr[...]  # (P, N)
    y += jnp.exp(cum)[:, None] * jax.lax.dot_general(
        Cm, state, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )  # (Q, P)

    # --- state update -------------------------------------------------------
    total = cum[-1]
    decay_to_end = jnp.exp(total - cum)  # (Q,)
    new_state = state * jnp.exp(total) + jax.lax.dot_general(
        xw, Bm * decay_to_end[:, None], (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )  # (P, N)
    state_scr[...] = new_state

    y_ref[0, :, 0, :] = y.astype(y_ref.dtype)

    @pl.when(ci == num_chunks - 1)
    def _emit_state():
        state_ref[0, 0] = new_state


def ssd_scan(
    x: jax.Array,  # (B, S, H, P)
    dt: jax.Array,  # (B, S, H) fp32 post-softplus
    A: jax.Array,  # (H,) fp32 negative
    B_: jax.Array,  # (B, S, N)
    C_: jax.Array,  # (B, S, N)
    chunk: int,
    init_state: Optional[jax.Array] = None,
    *,
    interpret: bool = False,
) -> Tuple[jax.Array, jax.Array]:
    Bsz, S, H, P = x.shape
    N = B_.shape[-1]
    assert S % chunk == 0, (S, chunk)
    nc = S // chunk
    if init_state is None:
        init_state = jnp.zeros((Bsz, H, P, N), jnp.float32)

    kernel = functools.partial(_ssd_kernel, num_chunks=nc, chunk=chunk)
    y, state = pl.pallas_call(
        kernel,
        grid=(Bsz, H, nc),
        in_specs=[
            pl.BlockSpec((1, chunk, 1, P), lambda b, h, c: (b, c, h, 0)),
            pl.BlockSpec((1, chunk, 1), lambda b, h, c: (b, c, h)),
            pl.BlockSpec((1,), lambda b, h, c: (h,)),
            pl.BlockSpec((1, chunk, N), lambda b, h, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, N), lambda b, h, c: (b, c, 0)),
            pl.BlockSpec((1, 1, P, N), lambda b, h, c: (b, h, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, 1, P), lambda b, h, c: (b, c, h, 0)),
            pl.BlockSpec((1, 1, P, N), lambda b, h, c: (b, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct(x.shape, x.dtype),
            jax.ShapeDtypeStruct((Bsz, H, P, N), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((P, N), jnp.float32)],
        interpret=interpret,
    )(x, dt.astype(jnp.float32), A.astype(jnp.float32), B_, C_, init_state)
    return y, state
