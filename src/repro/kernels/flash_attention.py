"""Flash attention (prefill) — Pallas TPU kernel.

Online-softmax tiled attention in the style of the original
FlashAttention, adapted to the TPU memory hierarchy: q/k/v tiles live in
VMEM via BlockSpecs, the (block_q x block_kv) score tile feeds the MXU
(both dims multiples of 128 at full size), and the softmax statistics
(m, l) plus the output accumulator sit in fp32 VMEM scratch carried
across the sequential kv grid dimension (TPU grids execute serially over
the trailing axis, which is what makes cross-block accumulation legal).

GQA is handled in the index_map: query head h reads kv head
h // (H // Hkv) — no materialized broadcast. Causal and sliding-window
masks are applied with iota comparisons inside the tile; fully-masked
kv tiles are skipped via ``@pl.when`` on the block indices.
"""

from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _attn_kernel(
    q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr,
    *, block_q: int, block_kv: int, num_kv_blocks: int,
    causal: bool, window: Optional[int], seq_len: int,
):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_start = qi * block_q
    k_start = ki * block_kv

    # Skip tiles that the causal/window structure fully masks.
    relevant = True
    if causal:
        relevant = k_start <= q_start + block_q - 1
    if window is not None:
        relevant = jnp.logical_and(
            relevant, k_start + block_kv - 1 > q_start - window
        )

    @pl.when(relevant)
    def _compute():
        q = q_ref[0, :, 0, :].astype(jnp.float32)  # (bq, D)
        k = k_ref[0, :, 0, :].astype(jnp.float32)  # (bkv, D)
        v = v_ref[0, :, 0, :].astype(jnp.float32)
        # rows past seq_len are padding (undefined memory) — zero them so
        # 0-probability x garbage cannot poison the accumulator
        kv_row = k_start + jax.lax.broadcasted_iota(jnp.int32, k.shape, 0)
        live = kv_row < seq_len
        k = jnp.where(live, k, 0.0)
        v = jnp.where(live, v, 0.0)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        ) * (q.shape[-1] ** -0.5)  # (bq, bkv)

        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        mask = kpos < seq_len
        if causal:
            mask = jnp.logical_and(mask, kpos <= qpos)
        if window is not None:
            mask = jnp.logical_and(mask, kpos > qpos - window)
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_scr[...]
        l_prev = l_scr[...]
        m_cur = jnp.max(s, axis=-1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_new = alpha * l_prev + jnp.sum(p, axis=-1, keepdims=True)
        acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        m_scr[...] = m_new
        l_scr[...] = l_new

    @pl.when(ki == num_kv_blocks - 1)
    def _finalize():
        l = l_scr[...]
        safe = jnp.where(l == 0.0, 1.0, l)
        o_ref[0, :, 0, :] = (acc_scr[...] / safe).astype(o_ref.dtype)


def flash_attention(
    q: jax.Array,  # (B, S, H, D)
    k: jax.Array,  # (B, S, Hkv, D)
    v: jax.Array,
    *,
    causal: bool = True,
    window: Optional[int] = None,
    block_q: int = 256,
    block_kv: int = 256,
    interpret: bool = False,
) -> jax.Array:
    B, S, H, D = q.shape
    Hkv = k.shape[2]
    assert H % Hkv == 0
    group = H // Hkv
    block_q = min(block_q, S)
    block_kv = min(block_kv, S)
    nq = math.ceil(S / block_q)
    nkv = math.ceil(S / block_kv)

    kernel = functools.partial(
        _attn_kernel,
        block_q=block_q, block_kv=block_kv, num_kv_blocks=nkv,
        causal=causal, window=window, seq_len=S,
    )
    grid = (B, H, nq, nkv)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, 1, D), lambda b, h, qi, ki: (b, qi, h, 0)),
            pl.BlockSpec(
                (1, block_kv, 1, D), lambda b, h, qi, ki: (b, ki, h // group, 0)
            ),
            pl.BlockSpec(
                (1, block_kv, 1, D), lambda b, h, qi, ki: (b, ki, h // group, 0)
            ),
        ],
        out_specs=pl.BlockSpec(
            (1, block_q, 1, D), lambda b, h, qi, ki: (b, qi, h, 0)
        ),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),  # running max
            pltpu.VMEM((block_q, 1), jnp.float32),  # running sum
            pltpu.VMEM((block_q, D), jnp.float32),  # output acc
        ],
        interpret=interpret,
    )(q, k, v)
