"""MoE grouped matmul — Pallas TPU kernel.

Computes out[e] = buf[e] @ w[e] for every expert e over the dispatched
token buffers (E, C, D) x (E, D, F): the compute core of Mixtral/Arctic
layers after dispatch. Blocked (bc x bd) x (bd x bf) MXU tiles with an
fp32 VMEM accumulator carried across the sequential contraction axis;
the expert index is simply the leading grid dim, so expert-sharded
weights keep their layout (experts never mix inside a tile).
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _gmm_kernel(buf_ref, w_ref, o_ref, acc_scr, *, num_k_blocks: int,
                contract_dim: int):
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        acc_scr[...] = jnp.zeros_like(acc_scr)

    a = buf_ref[0].astype(jnp.float32)  # (bc, bd)
    b = w_ref[0].astype(jnp.float32)  # (bd, bf)
    # zero padded contraction columns/rows (undefined memory past D)
    d0 = ki * a.shape[1]
    live_a = d0 + jax.lax.broadcasted_iota(jnp.int32, a.shape, 1) < contract_dim
    live_b = d0 + jax.lax.broadcasted_iota(jnp.int32, b.shape, 0) < contract_dim
    a = jnp.where(live_a, a, 0.0)
    b = jnp.where(live_b, b, 0.0)
    acc_scr[...] += jax.lax.dot_general(
        a, b, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )

    @pl.when(ki == num_k_blocks - 1)
    def _emit():
        o_ref[0] = acc_scr[...].astype(o_ref.dtype)


def moe_gmm(
    buf: jax.Array,  # (E, C, D)
    w: jax.Array,  # (E, D, F)
    *,
    block_c: int = 128,
    block_d: int = 512,
    block_f: int = 512,
    interpret: bool = False,
) -> jax.Array:
    E, C, D = buf.shape
    F = w.shape[-1]
    block_c = min(block_c, C)
    block_d = min(block_d, D)
    block_f = min(block_f, F)
    nc = math.ceil(C / block_c)
    nf = math.ceil(F / block_f)
    nd = math.ceil(D / block_d)

    kernel = functools.partial(_gmm_kernel, num_k_blocks=nd, contract_dim=D)
    return pl.pallas_call(
        kernel,
        grid=(E, nc, nf, nd),
        in_specs=[
            pl.BlockSpec((1, block_c, block_d), lambda e, c, f, d: (e, c, d)),
            pl.BlockSpec((1, block_d, block_f), lambda e, c, f, d: (e, d, f)),
        ],
        out_specs=pl.BlockSpec(
            (1, block_c, block_f), lambda e, c, f, d: (e, c, f)
        ),
        out_shape=jax.ShapeDtypeStruct((E, C, F), buf.dtype),
        scratch_shapes=[pltpu.VMEM((block_c, block_f), jnp.float32)],
        interpret=interpret,
    )(buf, w)
