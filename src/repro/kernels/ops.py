"""Public jit'd wrappers over the Pallas kernels.

``INTERPRET`` flips every kernel to Pallas interpret mode — the kernel
bodies execute in Python/XLA on CPU, which is how this container
validates them (TPU v5e is the compile TARGET, not the runtime). On a
real TPU deployment set ``repro.kernels.ops.INTERPRET = False`` (the
default when a TPU backend is detected).
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import decode_attention as _dec
from repro.kernels import flash_attention as _fa
from repro.kernels import moe_gmm as _gmm
from repro.kernels import ssd_scan as _ssd

# interpret unless a real TPU is present
INTERPRET = jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("causal", "window", "block_q", "block_kv"))
def flash_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    window: Optional[int] = None,
    block_q: int = 256,
    block_kv: int = 256,
) -> jax.Array:
    return _fa.flash_attention(
        q, k, v, causal=causal, window=window,
        block_q=block_q, block_kv=block_kv, interpret=INTERPRET,
    )


@functools.partial(jax.jit, static_argnames=("block_kv",))
def decode_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    kv_positions: jax.Array,
    kv_valid: jax.Array,
    q_pos: jax.Array,
    *,
    block_kv: int = 512,
) -> jax.Array:
    return _dec.decode_attention(
        q, k, v, kv_positions, kv_valid, q_pos,
        block_kv=block_kv, interpret=INTERPRET,
    )


@functools.partial(jax.jit, static_argnames=("chunk",))
def ssd_scan(
    x: jax.Array,
    dt: jax.Array,
    A: jax.Array,
    B_: jax.Array,
    C_: jax.Array,
    chunk: int,
    init_state: Optional[jax.Array] = None,
) -> Tuple[jax.Array, jax.Array]:
    return _ssd.ssd_scan(x, dt, A, B_, C_, chunk, init_state, interpret=INTERPRET)


@functools.partial(jax.jit, static_argnames=("block_c", "block_d", "block_f"))
def moe_gmm(
    buf: jax.Array,
    w: jax.Array,
    *,
    block_c: int = 128,
    block_d: int = 512,
    block_f: int = 512,
) -> jax.Array:
    return _gmm.moe_gmm(
        buf, w, block_c=block_c, block_d=block_d, block_f=block_f,
        interpret=INTERPRET,
    )
