"""Pure-jnp oracles for every Pallas kernel.

These delegate to (or mirror exactly) the reference model code in
``repro.models`` so the kernels are validated against the same math the
models run with ``use_pallas=False``.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.layers import sdpa
from repro.models.ssm import ssd_chunked


def flash_attention_ref(
    q: jax.Array,  # (B, S, H, D)
    k: jax.Array,  # (B, S, Hkv, D)
    v: jax.Array,
    *,
    causal: bool = True,
    window: Optional[int] = None,
) -> jax.Array:
    B, S, H, D = q.shape
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    return sdpa(q, k, v, causal=causal, q_positions=positions,
                kv_positions=positions, window=window)


def decode_attention_ref(
    q: jax.Array,  # (B, 1, H, D)
    k: jax.Array,  # (B, W, Hkv, D)
    v: jax.Array,
    kv_positions: jax.Array,  # (B, W) int32
    kv_valid: jax.Array,  # (B, W) bool
    q_pos: jax.Array,  # (B,) int32
) -> jax.Array:
    return sdpa(
        q, k, v, causal=True,
        q_positions=q_pos[:, None], kv_positions=kv_positions,
        kv_valid=kv_valid,
    )


def ssd_scan_ref(
    x: jax.Array,  # (B, S, H, P)
    dt: jax.Array,  # (B, S, H) fp32, post-softplus
    A: jax.Array,  # (H,) fp32, negative
    B_: jax.Array,  # (B, S, N)
    C_: jax.Array,  # (B, S, N)
    chunk: int,
    init_state: Optional[jax.Array] = None,
) -> Tuple[jax.Array, jax.Array]:
    return ssd_chunked(x, dt, A, B_, C_, chunk, init_state)


def ssd_scan_sequential_ref(
    x: jax.Array, dt: jax.Array, A: jax.Array, B_: jax.Array, C_: jax.Array,
    init_state: Optional[jax.Array] = None,
) -> Tuple[jax.Array, jax.Array]:
    """Step-by-step recurrence — the ground truth the chunked form and the
    kernel must both match (used by property tests)."""
    Bsz, S, H, P = x.shape
    N = B_.shape[-1]
    f32 = jnp.float32
    state = (jnp.zeros((Bsz, H, P, N), f32) if init_state is None
             else init_state.astype(f32))

    def step(state, inp):
        x_t, dt_t, B_t, C_t = inp
        decay = jnp.exp(dt_t.astype(f32) * A.astype(f32))  # (B,H)
        xw = x_t.astype(f32) * dt_t.astype(f32)[..., None]
        state = state * decay[..., None, None] + jnp.einsum(
            "bhp,bn->bhpn", xw, B_t.astype(f32)
        )
        y = jnp.einsum("bhpn,bn->bhp", state, C_t.astype(f32))
        return state, y

    xs = (
        jnp.moveaxis(x, 1, 0),
        jnp.moveaxis(dt, 1, 0),
        jnp.moveaxis(B_, 1, 0),
        jnp.moveaxis(C_, 1, 0),
    )
    state, ys = jax.lax.scan(step, state, xs)
    return jnp.moveaxis(ys, 0, 1).astype(x.dtype), state


def moe_gmm_ref(
    buf: jax.Array,  # (E, C, D) expert input buffers
    w: jax.Array,  # (E, D, F)
) -> jax.Array:
    return jnp.einsum("ecd,edf->ecf", buf, w,
                      preferred_element_type=jnp.float32).astype(buf.dtype)
