"""Pallas TPU kernels for the serving hot spots.

Shabari itself is a scheduling paper; the serving substrate it manages
has four TPU compute hot spots, implemented here (DESIGN.md §5):
flash_attention (prefill), decode_attention (flash-decode vs a ring KV
cache), ssd_scan (Mamba2 SSD chunk scan), moe_gmm (expert grouped
matmul). Each module provides ``pl.pallas_call`` + explicit BlockSpec
VMEM tiling (MXU-aligned 128-multiples); ``ops.py`` holds the jit'd
public wrappers with an ``interpret`` escape hatch (CPU validation) and
``ref.py`` the pure-jnp oracles the tests assert against.
"""
