"""Launchers: production mesh, step functions, multi-pod dry-run, train/serve."""
