"""Training launcher: train a reduced arch on CPU with the full
substrate (data pipeline, AdamW, checkpointing).

  PYTHONPATH=src python -m repro.launch.train --arch qwen2.5-3b \
      [--steps 100] [--batch 4] [--seq 128] [--ckpt-dir DIR] [--resume CKPT]

The production train_step for the FULL configs is exercised by the
multi-pod dry-run (repro.launch.dryrun); this driver runs real steps at
reduced scale.
"""

from __future__ import annotations

import argparse

from repro.configs import canonical_id, get_reduced_config
from repro.training.data import DataConfig
from repro.training.optimizer import AdamWConfig
from repro.training.train_loop import TrainLoopConfig, train


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-3b")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=6e-4)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--resume", default=None)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_reduced_config(canonical_id(args.arch))
    seq = args.seq
    if cfg.family in ("ssm", "hybrid"):
        seq = max(cfg.ssm_chunk, seq // cfg.ssm_chunk * cfg.ssm_chunk)
    if cfg.is_encoder_decoder:
        seq = min(seq, cfg.max_target_positions)
    print(f"training {cfg.name} (reduced, {cfg.family}) seq={seq}")

    def extra(step):
        import jax.numpy as jnp

        out = {}
        if cfg.family == "vlm":
            out["patch_embeds"] = jnp.zeros(
                (args.batch, cfg.frontend_tokens, cfg.d_model), cfg.dtype)
        if cfg.is_encoder_decoder:
            out["frame_embeds"] = jnp.zeros(
                (args.batch, cfg.encoder_seq, cfg.d_model), cfg.dtype)
        return out

    h = train(
        cfg,
        data_cfg=DataConfig(vocab_size=cfg.vocab_size, seq_len=seq,
                            batch_size=args.batch, seed=args.seed),
        opt_cfg=AdamWConfig(lr=args.lr, warmup_steps=max(args.steps // 10, 5),
                            total_steps=args.steps),
        loop=TrainLoopConfig(steps=args.steps, log_every=10,
                             ckpt_every=max(args.steps // 2, 50),
                             ckpt_dir=args.ckpt_dir, seed=args.seed),
        resume_from=args.resume,
        extra_batch_fn=extra if cfg.family in ("vlm",) or cfg.is_encoder_decoder else None,
    )
    print(f"loss {h['loss'][0]:.3f} -> {h['loss'][-1]:.3f}")


if __name__ == "__main__":
    main()
