"""Post-compile HLO analysis: collective traffic + roofline terms.

``cost_analysis()`` supplies FLOPs and HBM bytes of the partitioned
(per-device) module; collective bytes are NOT included there, so we parse
the optimized HLO text and sum traffic over every all-gather /
all-reduce / reduce-scatter / all-to-all / collective-permute.

Per-device traffic factors (ring algorithms, group size n):
    all-gather        result R: R * (n-1)/n
    all-reduce        tensor T: 2 * T * (n-1)/n
    reduce-scatter    result R (=T/n): R * (n-1)
    all-to-all        result R: R * (n-1)/n
    collective-permute result R: R
"""

from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLLECTIVES = (
    "all-reduce",
    "all-gather",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)

# e.g.:  %ag = bf16[16,1024]{1,0} all-gather(bf16[1,1024]{1,0} %x), ...
_OP_RE = re.compile(
    r"=\s*(?:\()?\s*(\w+)\[([\d,]*)\][^ ]*\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(",
)
_TUPLE_OP_RE = re.compile(
    r"=\s*\(([^)]*)\)\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(",
)
_GROUPS_RE = re.compile(r"replica_groups=\{\{([\d,]+)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims.strip():
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def _group_size(line: str, default: int) -> int:
    m = _GROUPS_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        # replica_groups=[G,n]<=[...] — n participants per group
        return int(m.group(2))
    return default


def _traffic(op: str, result_bytes: int, n: int) -> float:
    if n <= 1:
        return 0.0
    if op == "all-gather":
        return result_bytes * (n - 1) / n
    if op == "all-reduce":
        return 2.0 * result_bytes * (n - 1) / n
    if op == "reduce-scatter":
        return float(result_bytes) * (n - 1)
    if op == "all-to-all":
        return result_bytes * (n - 1) / n
    if op == "collective-permute":
        return float(result_bytes)
    return 0.0


@dataclasses.dataclass
class CollectiveStats:
    per_device_traffic_bytes: float
    op_counts: Dict[str, int]
    op_bytes: Dict[str, float]


def collective_stats(hlo_text: str, default_group: int) -> CollectiveStats:
    """Sum per-device collective traffic over an optimized HLO module.

    ``-start`` ops are counted; their ``-done`` halves are skipped to
    avoid double counting.
    """
    total = 0.0
    counts: Dict[str, int] = {}
    op_bytes: Dict[str, float] = {}
    for line in hlo_text.splitlines():
        if "-done(" in line or "-done.clone(" in line:
            continue
        m = _OP_RE.search(line)
        result_bytes = 0
        op = None
        if m:
            op = m.group(3)
            result_bytes = _shape_bytes(m.group(1), m.group(2))
        else:
            mt = _TUPLE_OP_RE.search(line)
            if mt:
                op = mt.group(2)
                for sm in _SHAPE_RE.finditer(mt.group(1)):
                    result_bytes += _shape_bytes(sm.group(1), sm.group(2))
        if not op:
            continue
        n = _group_size(line, default_group)
        t = _traffic(op, result_bytes, n)
        total += t
        counts[op] = counts.get(op, 0) + 1
        op_bytes[op] = op_bytes.get(op, 0.0) + t
    return CollectiveStats(total, counts, op_bytes)


def count_hlo_ops(hlo_text: str, opname: str) -> int:
    return len(re.findall(rf"\b{re.escape(opname)}\(", hlo_text))


# ---------------------------------------------------------------------------
# Roofline terms
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class Roofline:
    compute_s: float
    memory_s: float
    collective_s: float
    hlo_flops_global: float
    hlo_bytes_global: float
    collective_bytes_global: float
    model_flops: float
    useful_flops_ratio: float
    dominant: str

    def to_dict(self) -> Dict:
        return dataclasses.asdict(self)


def roofline_terms(
    *,
    per_device_flops: float,
    per_device_bytes: float,
    per_device_collective_bytes: float,
    chips: int,
    model_flops: float,
    peak_flops: float,
    hbm_bw: float,
    link_bw: float,
) -> Roofline:
    compute_s = per_device_flops / peak_flops
    memory_s = per_device_bytes / hbm_bw
    collective_s = per_device_collective_bytes / link_bw
    terms = {
        "compute": compute_s,
        "memory": memory_s,
        "collective": collective_s,
    }
    dominant = max(terms, key=terms.get)
    g_flops = per_device_flops * chips
    return Roofline(
        compute_s=compute_s,
        memory_s=memory_s,
        collective_s=collective_s,
        hlo_flops_global=g_flops,
        hlo_bytes_global=per_device_bytes * chips,
        collective_bytes_global=per_device_collective_bytes * chips,
        model_flops=model_flops,
        useful_flops_ratio=model_flops / g_flops if g_flops else 0.0,
        dominant=dominant,
    )


def model_flops_estimate(cfg, shape) -> float:
    """6·N·D for training, 2·N·D for inference (N = active params,
    D = tokens processed by the step)."""
    from repro.configs.base import decoder_seq_len

    n_active = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * decoder_seq_len(cfg, shape)
        return 6.0 * n_active * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * decoder_seq_len(cfg, shape)
        return 2.0 * n_active * tokens
    # decode: one token per sequence
    return 2.0 * n_active * shape.global_batch
