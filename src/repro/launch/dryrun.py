import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) combo.

The two lines above MUST run before any other import (jax locks the
device count on first init); 512 placeholder host devices back both the
single-pod (16,16) and multi-pod (2,16,16) production meshes.

For each combination this:
  1. builds the production mesh and the sharding spec trees,
  2. ``jax.jit(step, in_shardings, out_shardings, donate...)``
     ``.lower(**input_specs)`` — ShapeDtypeStructs only, no allocation,
  3. ``.compile()`` — any sharding mismatch / OOM-at-compile /
     unsupported collective fails HERE, which is the point,
  4. records ``memory_analysis()`` / ``cost_analysis()`` / parsed
     collective traffic to a JSON blob for EXPERIMENTS.md §Dry-run and
     the roofline table (§Roofline).

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2.5-3b \
      --shape train_4k [--multi-pod] [--out experiments/dryrun]
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod]
"""

import argparse
import json
import time
import traceback
from pathlib import Path

import jax
from jax.sharding import PartitionSpec as P

from repro.configs import (
    ARCH_IDS,
    SHAPES,
    canonical_id,
    get_config,
    input_specs,
    shape_applicable,
)
from repro.distributed import sharding as sh
from repro.launch import hlo_analysis as ha
from repro.launch.mesh import (
    HBM_BW,
    ICI_LINK_BW,
    PEAK_FLOPS_BF16,
    make_production_mesh,
)
from repro.launch.steps import (
    adamw_config_for,
    eval_opt_shapes,
    eval_param_shapes,
    make_prefill_step,
    make_serve_step,
    make_train_step,
)


def _metric_specs():
    return None  # metrics replicate; let jit infer


def lower_combo(cfg, shape, mesh, *, opt: bool = False, xla_options=None):
    """Lower + compile one (arch, shape, mesh). Returns (lowered, compiled).

    ``opt`` enables the beyond-baseline optimizations that won the §Perf
    hillclimb: activation/score/MoE-buffer sharding constraints + the
    split-softmax decode. The baseline table is recorded with opt=False;
    EXPERIMENTS.md §Perf records both.
    """
    from repro.models.model import set_decode_mode

    # The split decode + score constraint fix the W-sharded-cache gather;
    # when kv heads divide the model axis the cache is head-sharded and
    # the baseline concat path is already shard-local (the split variant
    # only adds work — measured regressions on phi3/codeqwen long_500k).
    mi0 = sh.mesh_info(mesh)
    w_sharded_cache = (
        cfg.uses_attention and cfg.num_kv_heads % mi0.model_size != 0
    )
    set_decode_mode("split" if (opt and w_sharded_cache) else "concat")
    mi = sh.mesh_info(mesh)
    specs = input_specs(cfg, shape)
    in_raw = sh.input_spec_tree(cfg, mesh, shape, specs)
    in_spec_tree = sh.named(mesh, in_raw)
    pshapes = eval_param_shapes(cfg)
    praw = sh.param_spec_tree(
        cfg, mesh, "train" if shape.kind == "train" else "serve", pshapes
    )
    pspecs = sh.named(mesh, praw)

    with mesh:
        if shape.kind == "train":
            opt_cfg = adamw_config_for(cfg)
            oshapes = eval_opt_shapes(cfg, pshapes, opt_cfg)
            ospecs = sh.named(mesh, sh.opt_state_specs(praw))
            step = make_train_step(cfg, opt_cfg, mesh=mesh if opt else None)
            jitted = jax.jit(
                step,
                in_shardings=(pspecs, ospecs, in_spec_tree),
                out_shardings=(pspecs, ospecs, None),
                donate_argnums=(0, 1),
            )
            lowered = jitted.lower(pshapes, oshapes, specs)
        elif shape.kind == "prefill":
            step = make_prefill_step(cfg, shape, mesh=mesh if opt else None)
            jitted = jax.jit(
                step,
                in_shardings=(pspecs, in_spec_tree),
                out_shardings=None,
            )
            lowered = jitted.lower(pshapes, specs)
        else:  # decode
            step = make_serve_step(cfg, mesh=mesh if opt else None)
            cache_sds = specs["cache"]
            cache_specs_tree = in_spec_tree["cache"]
            token_spec = in_spec_tree["token"]
            batch_axis = in_raw["token"][0] if in_raw["token"] else None
            logits_spec = sh.named(
                mesh,
                P(
                    batch_axis,
                    "model" if cfg.vocab_size % mi.model_size == 0 else None,
                ),
            )
            jitted = jax.jit(
                step,
                in_shardings=(pspecs, cache_specs_tree, token_spec),
                out_shardings=(logits_spec, cache_specs_tree),
                donate_argnums=(1,),
            )
            lowered = jitted.lower(pshapes, cache_sds, specs["token"])
        compiled = lowered.compile()
    return lowered, compiled


def analyze(cfg, shape, mesh, lowered, compiled, elapsed_s, cost_override=None):
    chips = mesh.devices.size
    mi = sh.mesh_info(mesh)
    try:
        mem = compiled.memory_analysis()
        mem_d = {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "alias_bytes": getattr(mem, "alias_size_in_bytes", None),
            "generated_code_bytes": getattr(mem, "generated_code_size_in_bytes", None),
        }
    except Exception as e:  # pragma: no cover
        mem_d = {"error": repr(e)}
    try:
        cost = compiled.cost_analysis()
        if isinstance(cost, list):
            cost = cost[0]
    except Exception as e:  # pragma: no cover
        cost = {"error": repr(e)}
    hlo = compiled.as_text()
    cstats = ha.collective_stats(hlo, default_group=chips)
    if cost_override is not None:
        flops = cost_override["flops"]
        bytes_accessed = cost_override["bytes"]
        coll_bytes = cost_override["coll"]
    else:
        flops = float(cost.get("flops", 0.0) or 0.0)
        bytes_accessed = float(cost.get("bytes accessed", 0.0) or 0.0)
        coll_bytes = cstats.per_device_traffic_bytes
    model_flops = ha.model_flops_estimate(cfg, shape)
    rf = ha.roofline_terms(
        per_device_flops=flops,
        per_device_bytes=bytes_accessed,
        per_device_collective_bytes=coll_bytes,
        chips=chips,
        model_flops=model_flops,
        peak_flops=PEAK_FLOPS_BF16,
        hbm_bw=HBM_BW,
        link_bw=ICI_LINK_BW,
    )
    return {
        "arch": cfg.name,
        "shape": shape.name,
        "mesh": f"{'x'.join(str(s) for s in mesh.devices.shape)}",
        "axes": list(mesh.axis_names),
        "chips": int(chips),
        "compile_s": elapsed_s,
        "memory_analysis": mem_d,
        "cost_analysis_flops_per_device": flops,
        "cost_analysis_bytes_per_device": bytes_accessed,
        "collectives": {
            "per_device_traffic_bytes": coll_bytes,
            "scan_hlo_traffic_bytes": cstats.per_device_traffic_bytes,
            "op_counts": cstats.op_counts,
            "op_bytes": cstats.op_bytes,
        },
        "cost_extrapolation": cost_override,
        "roofline": rf.to_dict(),
        "param_count": cfg.param_count(),
        "active_param_count": cfg.active_param_count(),
    }


def _reduced_depth_cfg(cfg, n_layers: int):
    """Same architecture at a shallower depth (for cost extrapolation)."""
    import dataclasses

    changes = {"num_layers": n_layers}
    if cfg.encoder_layers:
        changes["encoder_layers"] = min(cfg.encoder_layers, n_layers)
    return dataclasses.replace(cfg, **changes)


def extrapolate_costs(cfg, shape, mesh, *, opt: bool):
    """Exact per-layer cost extrapolation.

    XLA's cost analysis counts a while-loop (scan) body ONCE, so the
    full-depth scan compile under-reports FLOPs/bytes/collectives by ~L.
    We compile the SAME architecture at depths L1 and L2 (fully unrolled
    — they're tiny) and extrapolate linearly: total(L) = c(L1) +
    (L - L1)/(L2 - L1) * (c(L2) - c(L1)). The layer stack is homogeneous
    within a family, so this is exact up to compiler noise; for the
    hybrid (zamba2) L1/L2 are multiples of attn_every so the shared-attn
    block amortizes correctly. Validated against fully-unrolled compiles
    in EXPERIMENTS.md §Dry-run (calibration table).
    """
    from repro.models.model import set_scan_unroll

    chips = mesh.devices.size
    step_l = cfg.attn_every if cfg.family == "hybrid" else 1
    # Depths 2x/3x (not 1x): a single-layer scan lowers structurally
    # differently (no while loop, different remat elision) and sits off
    # the per-layer cost line — calibrated L=1..4 in EXPERIMENTS.md.
    L1, L2 = 2 * step_l, 3 * step_l
    L = cfg.num_layers
    vals = {}
    for n in (L1, L2):
        rcfg = _reduced_depth_cfg(cfg, n)
        set_scan_unroll(max(n, rcfg.encoder_layers))
        lowered, compiled = lower_combo(rcfg, shape, mesh, opt=opt)
        ca = compiled.cost_analysis()
        ca = ca[0] if isinstance(ca, list) else ca
        cstats = ha.collective_stats(compiled.as_text(), default_group=chips)
        vals[n] = {
            "flops": float(ca.get("flops", 0.0) or 0.0),
            "bytes": float(ca.get("bytes accessed", 0.0) or 0.0),
            "coll": cstats.per_device_traffic_bytes,
        }
    out = {}
    for k in ("flops", "bytes", "coll"):
        slope = (vals[L2][k] - vals[L1][k]) / (L2 - L1)
        out[k] = vals[L1][k] + slope * (L - L1)
    out["per_layer"] = {
        k: (vals[L2][k] - vals[L1][k]) / (L2 - L1) for k in ("flops", "bytes", "coll")
    }
    out["base"] = {k: vals[L1][k] - out["per_layer"][k] * L1
                   for k in ("flops", "bytes", "coll")}
    return out


def run_one(arch: str, shape_name: str, multi_pod: bool, out_dir: Path,
            verbose=True, opt: bool = False):
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    if not shape_applicable(cfg, shape):
        rec = {
            "arch": cfg.name,
            "shape": shape.name,
            "skipped": True,
            "reason": "long_500k inapplicable (see DESIGN.md §4)",
        }
        _write(out_dir, cfg.name, shape.name, multi_pod, rec, opt)
        if verbose:
            print(f"SKIP  {cfg.name} x {shape.name}: {rec['reason']}")
        return rec
    mesh = make_production_mesh(multi_pod=multi_pod)
    from repro.models.model import set_scan_unroll

    # 1) THE dry-run artifact: the full config, scan-over-layers (the
    #    production form). Compile success/memory_analysis come from here.
    set_scan_unroll(1)
    t0 = time.time()
    lowered, compiled = lower_combo(cfg, shape, mesh, opt=opt)
    dt = time.time() - t0
    # 2) exact cost extrapolation from shallow unrolled compiles
    extra = extrapolate_costs(cfg, shape, mesh, opt=opt)
    rec = analyze(cfg, shape, mesh, lowered, compiled, dt,
                  cost_override=extra)
    rec["opt"] = opt
    _write(out_dir, cfg.name, shape.name, multi_pod, rec, opt)
    if verbose:
        ma = rec["memory_analysis"]
        print(
            f"OK    {cfg.name} x {shape.name} mesh={rec['mesh']} "
            f"compile={dt:.1f}s flops/dev={rec['cost_analysis_flops_per_device']:.3e} "
            f"argbytes/dev={ma.get('argument_bytes')} "
            f"dominant={rec['roofline']['dominant']}"
        )
        print("  memory_analysis:", {k: v for k, v in ma.items()})
        print(
            "  roofline: compute=%.4fs memory=%.4fs collective=%.4fs useful=%.3f"
            % (
                rec["roofline"]["compute_s"],
                rec["roofline"]["memory_s"],
                rec["roofline"]["collective_s"],
                rec["roofline"]["useful_flops_ratio"],
            )
        )
    return rec


def _write(out_dir: Path, arch: str, shape: str, multi_pod: bool, rec,
           opt: bool = False):
    out_dir.mkdir(parents=True, exist_ok=True)
    suffix = "pod2" if multi_pod else "pod1"
    if opt:
        suffix += "_opt"
    path = out_dir / f"{arch.replace('.', '_')}__{shape}__{suffix}.json"
    path.write_text(json.dumps(rec, indent=2, default=str))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--opt", action="store_true",
                    help="enable the beyond-baseline §Perf optimizations")
    ap.add_argument("--out", default="experiments/dryrun")
    args = ap.parse_args()
    out_dir = Path(args.out)

    combos = []
    if args.all:
        for a in ARCH_IDS:
            for s in SHAPES:
                combos.append((a, s))
    else:
        assert args.arch and args.shape, "--arch and --shape (or --all)"
        combos.append((canonical_id(args.arch), args.shape))

    failures = []
    for arch, shape_name in combos:
        try:
            run_one(arch, shape_name, args.multi_pod, out_dir, opt=args.opt)
        except Exception as e:
            failures.append((arch, shape_name, repr(e)))
            print(f"FAIL  {arch} x {shape_name}: {e}")
            traceback.print_exc()
    if failures:
        raise SystemExit(f"{len(failures)} combos failed: {failures}")


if __name__ == "__main__":
    main()
