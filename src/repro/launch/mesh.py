"""Production mesh construction.

``make_production_mesh`` is a FUNCTION (not a module-level constant) so
importing this module never touches jax device state. The dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
import; everything else sees the real single CPU device.

Target: TPU v5e, 256 chips/pod, 2 pods. Single-pod mesh (16, 16) with
axes ("data", "model"); multi-pod (2, 16, 16) with ("pod", "data",
"model") — the pod axis is a pure data-parallel outer axis crossing DCN.
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


# TPU v5e hardware constants used by the roofline analysis (per chip).
PEAK_FLOPS_BF16 = 197e12      # 197 TFLOP/s bf16
HBM_BW = 819e9                # 819 GB/s
ICI_LINK_BW = 50e9            # ~50 GB/s per link
CHIP_HBM_BYTES = 16 * 1024**3  # 16 GiB
