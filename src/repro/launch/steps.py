"""Step functions lowered by the dry-run and used by the real drivers.

* ``train_step``   — loss, grads, AdamW update (donated params/opt state).
* ``prefill_step`` — full-sequence forward building the decode cache.
* ``serve_step``   — ONE new token against a seq_len-deep cache (what the
  decode_32k / long_500k shapes lower).
"""

from __future__ import annotations

import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import (
    ModelConfig,
    ShapeConfig,
    effective_decode_window,
)
from repro.models.model import (
    forward_decode,
    forward_prefill,
    forward_train,
    init_params,
)
from repro.training.optimizer import AdamWConfig, adamw_update, init_opt_state

# bf16 moments for the >=100B-param archs (DESIGN.md §6).
BF16_MOMENT_ARCHS = {"internvl2-76b", "arctic-480b"}


def make_constrain(cfg: ModelConfig, mesh):
    """Activation-sharding hook: keeps the residual stream batch-sharded
    and the logits (batch, model-on-vocab)-sharded so GSPMD gathers FSDP
    weights instead of moving giant fp32 activations (EXPERIMENTS.md
    §Perf, hillclimb #1)."""
    if mesh is None:
        return None
    import jax
    from jax.sharding import NamedSharding, PartitionSpec as P

    axes = mesh.axis_names
    dp = tuple(a for a in axes if a != "model")
    dp = dp if len(dp) > 1 else dp[0]
    msize = mesh.shape["model"]
    dsize = 1
    for a in (dp if isinstance(dp, tuple) else (dp,)):
        dsize *= mesh.shape[a]

    def constrain(name, x):
        if name == "hidden":
            spec = P(dp, *([None] * (x.ndim - 1)))
        elif name == "logits":
            v = x.shape[-1]
            spec = P(dp, *([None] * (x.ndim - 2)),
                     "model" if v % msize == 0 else None)
        elif name in ("moe_buf", "moe_h"):
            # (E, C, D|F): experts over model when divisible; capacity
            # carries the data axes so buffers never replicate
            e = "model" if x.shape[0] % msize == 0 else None
            c = dp if x.shape[1] % dsize == 0 else None
            spec = P(e, c, None)
        elif name == "moe_tokens":
            spec = P(dp if x.shape[0] % dsize == 0 else None, None)
        elif name == "scores":
            # decode attention scores (B, Hkv, g, W): keep W model-sharded
            # when heads can't carry the model axis, so the softmax
            # reduces shard-wise instead of gathering the cache
            if x.shape[1] % msize == 0:
                spec = P(dp, "model", *([None] * (x.ndim - 2)))
            elif x.shape[-1] % msize == 0:
                spec = P(dp, *([None] * (x.ndim - 2)), "model")
            else:
                return x
        else:
            return x
        return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))

    return constrain


def adamw_config_for(cfg: ModelConfig) -> AdamWConfig:
    mdt = "bfloat16" if cfg.name in BF16_MOMENT_ARCHS else "float32"
    return AdamWConfig(moment_dtype=mdt)


def make_train_step(
    cfg: ModelConfig, opt_cfg: Optional[AdamWConfig] = None,
    use_pallas: bool = False, mesh=None,
) -> Callable:
    opt_cfg = opt_cfg or adamw_config_for(cfg)
    constrain = make_constrain(cfg, mesh)

    def train_step(params, opt_state, batch):
        def loss_fn(p):
            loss, metrics = forward_train(
                p,
                cfg,
                batch["tokens"],
                batch["labels"],
                patch_embeds=batch.get("patch_embeds"),
                frame_embeds=batch.get("frame_embeds"),
                use_pallas=use_pallas,
                remat=True,
                constrain=constrain,
            )
            return loss, metrics

        (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(params)
        new_params, new_opt, opt_metrics = adamw_update(
            opt_cfg, params, grads, opt_state
        )
        metrics = dict(metrics, loss=loss, **opt_metrics)
        return new_params, new_opt, metrics

    return train_step


def make_prefill_step(
    cfg: ModelConfig, shape: ShapeConfig, use_pallas: bool = False, mesh=None
) -> Callable:
    W = effective_decode_window(cfg, shape)
    long_ctx = shape.name == "long_500k"
    constrain = make_constrain(cfg, mesh)

    def prefill_step(params, batch):
        logits, cache = forward_prefill(
            params,
            cfg,
            batch["tokens"],
            patch_embeds=batch.get("patch_embeds"),
            frame_embeds=batch.get("frame_embeds"),
            cache_window=W or None,
            long_context=long_ctx,
            use_pallas=use_pallas,
            constrain=constrain,
        )
        return logits, cache

    return prefill_step


def make_serve_step(cfg: ModelConfig, use_pallas: bool = False, mesh=None) -> Callable:
    constrain = make_constrain(cfg, mesh)

    def serve_step(params, cache, token):
        logits, new_cache = forward_decode(
            params, cfg, token, cache, use_pallas=use_pallas,
            constrain=constrain,
        )
        return logits, new_cache

    return serve_step


def eval_param_shapes(cfg: ModelConfig):
    """Parameter ShapeDtypeStructs without allocating (for the dry-run)."""
    return jax.eval_shape(lambda: init_params(jax.random.PRNGKey(0), cfg))


def eval_opt_shapes(cfg: ModelConfig, param_shapes, opt_cfg: Optional[AdamWConfig] = None):
    opt_cfg = opt_cfg or adamw_config_for(cfg)
    return jax.eval_shape(lambda: init_opt_state(opt_cfg, param_shapes))
