"""Serving launcher: run the Shabari-managed engine on a reduced arch
(CPU) or emit the production serve_step for a full arch (dry lowering).

  PYTHONPATH=src python -m repro.launch.serve --arch qwen2.5-3b \
      [--requests 8] [--max-new 16] [--seed 0]

On a TPU deployment the same entry point would hold the per-slice
executables that Shabari's scheduler treats as warm containers; on this
CPU container it serves the REDUCED variant end-to-end and prints
latency/throughput, demonstrating the full request path.
"""

from __future__ import annotations

import argparse

import numpy as np

from repro.configs import canonical_id, get_reduced_config
from repro.core import Featurizer, ResourceAllocator
from repro.core.cost_functions import Observation
from repro.serving.engine import ServingEngine


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-3b")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--slo-ms", type=float, default=500.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_reduced_config(canonical_id(args.arch))
    print(f"serving {cfg.name} (reduced, {cfg.family}) on CPU")
    engine = ServingEngine(cfg, cache_window=128, seed=args.seed)
    rng = np.random.default_rng(args.seed)
    feat = Featurizer()
    alloc = ResourceAllocator(vcpu_confidence=2, mem_confidence=4)

    for i in range(args.requests):
        n = int(rng.choice([8, 24, 48]))
        prompt = list(rng.integers(1, cfg.vocab_size, size=n))
        x = feat.extract(cfg.name, "request", {
            "prompt_tokens": n, "batch": 1, "max_new_tokens": args.max_new,
            "image_tiles": 0, "audio_seconds": 0,
        })
        a = alloc.allocate(cfg.name, x)
        res = engine.generate([prompt], max_new_tokens=args.max_new)
        lat = res.prefill_s + res.decode_s
        slo = args.slo_ms / 1e3
        alloc.feedback(cfg.name, x, Observation(
            exec_time_s=lat, slo_s=slo, alloc_vcpus=a.vcpus,
            max_vcpus_used=min(a.vcpus, max(n // 16, 1)),
            alloc_mem_mb=a.mem_mb, max_mem_used_mb=64 + 0.5 * n,
        ))
        print(f"req {i}: prompt={n:3d} -> slices={a.vcpus:2d} "
              f"mem={a.mem_mb:4d}MB latency={lat*1e3:7.1f}ms "
              f"({res.tokens_per_s:,.0f} tok/s) "
              f"{'OK' if lat <= slo else 'SLO-MISS'}")


if __name__ == "__main__":
    main()
